"""Exporters: JSONL event logs and Chrome/Perfetto ``trace_event`` traces.

JSONL is the durable format (one flat JSON object per line, ``seq``/
``t``/``type`` envelope + event fields, closed by one ``metrics.summary``
record) -- ``python -m repro.obs.report`` replays it into a decision
trace, and ``perfetto_trace`` converts it into a JSON trace that loads
in https://ui.perfetto.dev:

  * pid 1, "tuner + tiering (step domain)": one thread per tuner whose
    PROFILE/TRIAL/HOLD phases render as named spans (ts = step, 1 step
    = 1 us), one thread per tiering manager whose inter-tier windows
    render as ``window(p=N)`` spans, plus a ``period`` counter track.
  * pid 2, "serving (wall clock)": macro-step launches and admission
    batches as duration spans at their measured wall times, plus a
    ``queue_depth`` counter track.

Guard trips / window extensions / retirements are instant events on
their thread, so a poisoned sweep is visible as markers inside the TRIAL
span that aborts it.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.obs.telemetry import Recorder

__all__ = ["write_jsonl", "read_jsonl", "perfetto_trace", "write_perfetto"]

SCHEMA = "repro-obs/v1"


def write_jsonl(path, recorder: Recorder) -> pathlib.Path:
    """Dump the recorder's event ring (oldest surviving event first) plus
    a closing ``metrics.summary`` record to ``path``."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as f:
        for ev in recorder.events():
            f.write(json.dumps(ev, default=float) + "\n")
        f.write(json.dumps({"type": "metrics.summary", "schema": SCHEMA,
                            **recorder.summary()}, default=float) + "\n")
    return path


def read_jsonl(path) -> List[Dict[str, Any]]:
    out = []
    for line in pathlib.Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# Perfetto / chrome://tracing trace_event export
# ---------------------------------------------------------------------------

_STEP_PID, _WALL_PID = 1, 2


def _meta(pid: int, tid: Optional[int], name: str) -> Dict[str, Any]:
    ev: Dict[str, Any] = {
        "ph": "M", "pid": pid,
        "name": "thread_name" if tid is not None else "process_name",
        "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def _tids(events: Sequence[dict], key: str) -> Dict[str, int]:
    """Stable small thread ids for each distinct emitter (tuner/manager)."""
    ids: Dict[str, int] = {}
    for ev in events:
        who = str(ev.get(key, "?"))
        if who not in ids:
            ids[who] = len(ids) + 1
    return ids


def perfetto_trace(events: Iterable[dict]) -> Dict[str, Any]:
    """Convert a JSONL event stream (``read_jsonl`` output or
    ``Recorder.events()``) into a ``trace_event`` JSON dict."""
    events = [e for e in events if e.get("type") != "metrics.summary"]
    te: List[Dict[str, Any]] = [
        _meta(_STEP_PID, None, "tuner + tiering (step domain)"),
        _meta(_WALL_PID, None, "serving (wall clock)"),
    ]

    tuner_tids = _tids([e for e in events
                        if e["type"].startswith("tuner.")], "tuner")
    mgr_tids = {m: 100 + i for m, i in _tids(
        [e for e in events if e["type"] == "tier.move"], "manager").items()}
    for who, tid in tuner_tids.items():
        te.append(_meta(_STEP_PID, tid, f"tuner {who}"))
    for who, tid in mgr_tids.items():
        te.append(_meta(_STEP_PID, tid, f"tiering {who}"))
    te.append(_meta(_WALL_PID, 1, "scheduler"))

    # -- tuner phase spans: each transition closes the previous phase -------
    open_phase: Dict[str, tuple] = {}        # tuner -> (state, since_step)
    last_step: Dict[str, int] = {}
    for ev in events:
        typ = ev["type"]
        if not typ.startswith("tuner."):
            continue
        who = str(ev.get("tuner", "?"))
        tid = tuner_tids[who]
        step = int(ev.get("step", last_step.get(who, 0)))
        last_step[who] = step
        if typ == "tuner.transition":
            frm, to = ev.get("frm", "?"), ev.get("to", "?")
            if who in open_phase:
                state, since = open_phase[who]
                te.append({"name": state.upper(), "ph": "X", "ts": since,
                           "dur": max(1, step - since), "pid": _STEP_PID,
                           "tid": tid, "args": {"closed_by": ev["reason"]}})
            elif step > 0:
                # log started mid-run: render the unobserved prefix
                te.append({"name": frm.upper(), "ph": "X", "ts": 0,
                           "dur": step, "pid": _STEP_PID, "tid": tid,
                           "args": {"closed_by": ev["reason"]}})
            open_phase[who] = (to, step)
            args = {k: v for k, v in ev.items()
                    if k not in ("seq", "t", "type", "tuner")}
            te.append({"name": f"-> {to.upper()} [{ev['reason']}]",
                       "ph": "i", "ts": step, "pid": _STEP_PID, "tid": tid,
                       "s": "t", "args": args})
        elif typ == "tuner.period":
            te.append({"name": f"period[{who}]", "ph": "C", "ts": step,
                       "pid": _STEP_PID,
                       "args": {"period": ev.get("period", 0)}})
        elif typ in ("tuner.guard", "tuner.extend", "tuner.trial",
                     "tuner.baseline"):
            args = {k: v for k, v in ev.items()
                    if k not in ("seq", "t", "type", "tuner")}
            name = {"tuner.guard": "guard "
                    + str(ev.get("verdict", "trip")),
                    "tuner.extend": "window extend",
                    "tuner.trial": f"trial p={ev.get('period')}",
                    "tuner.baseline": "baseline"}[typ]
            te.append({"name": name, "ph": "i", "ts": step, "pid": _STEP_PID,
                       "tid": tid, "s": "t", "args": args})
    for who, (state, since) in open_phase.items():
        end = last_step.get(who, since) + 1
        te.append({"name": state.upper(), "ph": "X", "ts": since,
                   "dur": max(1, end - since), "pid": _STEP_PID,
                   "tid": tuner_tids[who], "args": {"closed_by": "eof"}})

    # -- tiering windows: a span between consecutive tier boundaries --------
    last_tier: Dict[str, int] = {}
    for ev in events:
        if ev["type"] != "tier.move":
            continue
        who = str(ev.get("manager", "?"))
        step = int(ev.get("step", 0))
        since = last_tier.get(who, max(0, step - int(ev.get("period", 1))))
        te.append({"name": f"window(p={ev.get('period')})", "ph": "X",
                   "ts": since, "dur": max(1, step - since),
                   "pid": _STEP_PID, "tid": mgr_tids[who],
                   "args": {"promoted": ev.get("promoted"),
                            "evicted": ev.get("evicted"),
                            "pages_moved": ev.get("pages_moved")}})
        last_tier[who] = step

    # -- serving spans (wall clock, us) --------------------------------------
    for ev in events:
        typ = ev["type"]
        ts = float(ev.get("t", 0.0)) * 1e6
        if typ in ("serve.macro", "serve.admit"):
            dur = max(1.0, float(ev.get("wall_ms", 0.0)) * 1e3)
            name = (f"macro x{ev.get('n_steps')}" if typ == "serve.macro"
                    else f"admit x{ev.get('joiners')}")
            args = {k: v for k, v in ev.items()
                    if k not in ("seq", "t", "type")}
            te.append({"name": name, "ph": "X", "ts": ts - dur, "dur": dur,
                       "pid": _WALL_PID, "tid": 1, "args": args})
        elif typ == "serve.retire":
            te.append({"name": f"retire rid={ev.get('rid')}", "ph": "i",
                       "ts": ts, "pid": _WALL_PID, "tid": 1, "s": "t",
                       "args": {"tokens": ev.get("tokens")}})
        elif typ == "ft.straggler":
            te.append({"name": f"straggler {ev.get('timer')}", "ph": "i",
                       "ts": ts, "pid": _WALL_PID, "tid": 1, "s": "p",
                       "args": {"dt_s": ev.get("dt_s"),
                                "ema_s": ev.get("ema_s")}})
        if typ == "serve.admit" and "queue_depth" in ev:
            te.append({"name": "queue_depth", "ph": "C", "ts": ts,
                       "pid": _WALL_PID,
                       "args": {"depth": ev["queue_depth"]}})
    return {"traceEvents": te, "displayTimeUnit": "ms",
            "otherData": {"schema": SCHEMA}}


def write_perfetto(path, events: Iterable[dict]) -> pathlib.Path:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(perfetto_trace(events)))
    return path
