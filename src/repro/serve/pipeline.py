"""Background decision worker for the pipelined macro serving loop.

The pipelined ``ContinuousBatcher`` (docs/serving.md, "Pipelined macro
loop") moves the per-boundary control work -- the ``TieringManager``
accounting, the tiering *plan* and the ``OnlineTuner`` update -- off the
dispatch path onto this worker thread, so it runs concurrently with the
next in-flight device scan.  The hand-off is deterministic by
construction:

  * the dispatch thread ``submit``s exactly one mass snapshot per macro
    boundary and later blocks in ``wait`` for that generation's result;
  * the worker consumes submissions strictly in order and publishes
    exactly one result per generation;
  * between ``wait(g)`` returning and the next ``submit(g+1)`` the
    worker is provably idle (it finished generation ``g`` and has
    nothing queued), so the dispatch thread may touch the shared
    manager/tuner state in that window without locks.

That strict alternation is the documented **stale-by-one contract**: the
decision computed from macro ``k``'s masses is waited on -- and applied
-- in the overlap window of macro ``k+1``, i.e. it takes effect for
macro ``k+2``'s launch.  The dispatch path never blocks on the tuner at
launch time; it blocks only behind an already-launched scan.

The worker is deliberately generic (it runs any ``fn(payload)``), so the
hand-off protocol is testable without a model (tests/test_pipeline.py
hammers it from a fake dispatch thread).

Watchdog support: each worker maintains a :class:`Pulse` (an in-memory
heartbeat it touches around every ``fn`` call), so a supervisor blocking
in ``wait(generation, timeout=...)`` can tell a *hung* worker (pulse
stale -- ``fn`` never returned) from a merely *slow* one, and
``abandon()`` lets it walk away from a wedged thread without the 30s
``close`` join: the thread is daemonic and its inbox is poisoned, so a
zombie that eventually wakes finds nothing to do and exits.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Optional, Tuple

from repro.ft.monitor import Pulse

__all__ = ["DecisionWorker"]


class DecisionWorker:
    """One background thread turning boundary snapshots into decisions.

    ``submit(payload)`` enqueues a snapshot and returns its generation
    number; ``wait(generation)`` blocks until that generation's
    ``fn(payload)`` result is published and returns ``(result,
    waited_seconds)``.  Exceptions raised by ``fn`` are re-raised in
    ``wait`` (the dispatch thread is the error domain; the worker never
    dies silently).  ``close()`` drains and joins the thread.
    """

    def __init__(self, fn: Callable[[Any], Any], *,
                 name: str = "decision-worker"):
        self._fn = fn
        self._inbox: "queue.Queue[Optional[Tuple[int, Any]]]" = queue.Queue()
        self._results: dict = {}
        self._errors: dict = {}
        self._cv = threading.Condition()
        self._next_gen = 0
        self._closed = False
        #: in-memory heartbeat: touched around every ``fn`` call, so a
        #: watchdog can tell a hung worker (stale pulse) from a slow one
        self.pulse = Pulse()
        self.pulse.touch()
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._thread.start()

    # -- dispatch-thread API -------------------------------------------------
    def submit(self, payload: Any) -> int:
        """Enqueue one boundary snapshot; returns its generation number."""
        if self._closed:
            raise RuntimeError("DecisionWorker is closed")
        gen = self._next_gen
        self._next_gen += 1
        self._inbox.put((gen, payload))
        return gen

    def wait(self, generation: int,
             timeout: Optional[float] = None) -> Tuple[Any, float]:
        """Block until ``generation``'s decision is published.  Returns
        ``(result, waited_seconds)``; re-raises the worker's exception if
        ``fn`` failed on that generation."""
        t0 = time.monotonic()
        with self._cv:
            while (generation not in self._results
                   and generation not in self._errors):
                if not self._cv.wait(timeout=timeout):
                    raise TimeoutError(
                        f"decision generation {generation} not published "
                        f"within {timeout}s")
            if generation in self._errors:
                raise self._errors.pop(generation)
            return self._results.pop(generation), time.monotonic() - t0

    def close(self, timeout: float = 30.0) -> None:
        """Stop the worker: no further submits; pending work is drained."""
        if self._closed:
            return
        self._closed = True
        self._inbox.put(None)
        self._thread.join(timeout=timeout)

    def abandon(self) -> None:
        """Walk away from a wedged worker WITHOUT joining it: mark the
        worker closed and poison its inbox so the (daemonic) thread exits
        whenever it wakes up.  The watchdog uses this after a ``wait``
        timeout -- a hung ``fn`` would make ``close()``'s join block for
        its full timeout -- then builds a fresh worker.  Results the
        zombie eventually publishes land in its own orphaned dicts and
        are never observed."""
        if self._closed:
            return
        self._closed = True
        self._inbox.put(None)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive() and not self._closed

    # -- worker thread -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            item = self._inbox.get()
            if item is None:
                return
            gen, payload = item
            self.pulse.touch()
            try:
                result, err = self._fn(payload), None
            except BaseException as e:          # published, not swallowed
                result, err = None, e
            self.pulse.touch()
            with self._cv:
                if err is None:
                    self._results[gen] = result
                else:
                    self._errors[gen] = err
                self._cv.notify_all()

    def __enter__(self) -> "DecisionWorker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
