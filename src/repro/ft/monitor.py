"""Fault-tolerance runtime pieces that run *inside* the training process.

``StepTimer``   -- EMA step-time tracker with straggler detection (a step
                   slower than ``threshold x EMA`` is flagged; at scale the
                   flag feeds the supervisor / scheduler to hot-swap the
                   slow host -- here it increments counters and callbacks).
``Heartbeat``   -- background thread touching a file every ``interval``;
                   the supervisor treats a stale heartbeat as a hang (the
                   failure mode checkpoint-restart alone cannot catch).
``Pulse``       -- Heartbeat's in-memory, in-process twin: a worker
                   *thread* touches it around units of work and a watcher
                   thread reads ``age()``; same staleness contract, no
                   filesystem (the DecisionWorker watchdog uses it to
                   tell hung from slow).
``FailureInjector`` -- deterministic fault injection (env
                   ``REPRO_FAIL_AT_STEP``) used by the restart tests.
"""
from __future__ import annotations

import os
import pathlib
import threading
import time
from typing import Callable, List, Optional

from repro.obs import telemetry as _obs

__all__ = ["StepTimer", "Heartbeat", "Pulse", "FailureInjector"]


class StepTimer:
    """EMA step-time tracker with straggler detection.

    When ``name`` is given the timer reports into the flight recorder: a
    straggler emits an ``ft.straggler`` event and every stop observes the
    ``<name>.step_s`` histogram (the training loop and the serving macro
    loop share this path)."""

    def __init__(self, ema_alpha: float = 0.1, threshold: float = 3.0,
                 warmup: int = 3,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None, name: Optional[str] = None):
        self.ema_alpha = ema_alpha
        self.threshold = threshold
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.name = name
        self.ema: Optional[float] = None
        self.count = 0
        self.stragglers: List[int] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        dt = time.monotonic() - self._t0
        self.count += 1
        straggler = False
        ema_ref = self.ema
        if self.ema is None:
            self.ema = dt
        elif self.count <= self.warmup:
            self.ema = 0.5 * self.ema + 0.5 * dt
        else:
            if dt > self.threshold * self.ema:
                straggler = True
                self.stragglers.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt, self.ema)
            self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * dt
        if self.name is not None and (r := _obs.RECORDER).enabled:
            r.observe(f"{self.name}.step_s", dt)
            if straggler:
                r.emit("ft.straggler", timer=self.name, step=int(step),
                       dt_s=dt, ema_s=float(ema_ref))
                r.count("ft.stragglers")
        return dt


class Heartbeat:
    def __init__(self, path, interval: float = 1.0):
        self.path = pathlib.Path(path)
        self.interval = interval
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        while not self._stop.is_set():
            self.path.write_text(str(time.time()))
            self._stop.wait(self.interval)

    def __enter__(self):
        self._thread.start()
        return self

    def __exit__(self, *a):
        self._stop.set()
        self._thread.join(timeout=2)

    @staticmethod
    def age(path) -> float:
        try:
            return time.time() - float(pathlib.Path(path).read_text())
        except (OSError, ValueError):
            return float("inf")


class Pulse:
    """In-memory heartbeat between two threads of one process.

    The worked thread calls ``touch()`` around each unit of work (the
    DecisionWorker touches before and after every ``fn`` call); a watcher
    reads ``age()`` -- seconds since the last touch, ``inf`` before the
    first.  Same staleness contract as :meth:`Heartbeat.age`, minus the
    filesystem: a watcher with a timeout distinguishes *hung* (age keeps
    growing past the deadline) from *slow but alive*.  Writes and reads
    of a float are atomic under the GIL, so there is no lock."""

    def __init__(self):
        self._last: Optional[float] = None

    def touch(self) -> None:
        self._last = time.monotonic()

    def age(self) -> float:
        last = self._last
        return float("inf") if last is None else time.monotonic() - last


class FailureInjector:
    """Crash deterministically at REPRO_FAIL_AT_STEP (once, flagged by a
    sentinel file so the restarted process survives)."""

    ENV = "REPRO_FAIL_AT_STEP"

    def __init__(self, workdir):
        self.fail_at = int(os.environ.get(self.ENV, "-1"))
        self.sentinel = pathlib.Path(workdir) / ".failure_injected"

    def check(self, step: int):
        if (self.fail_at >= 0 and step == self.fail_at
                and not self.sentinel.exists()):
            self.sentinel.write_text(str(step))
            raise RuntimeError(f"injected failure at step {step}")
