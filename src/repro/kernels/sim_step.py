"""Pallas TPU kernel: the hybrid-memory simulator's period scan, fused.

``core.sim._sim_scan_batch`` vmaps ``_scan_one`` over a [C, P, num_pages]
candidate stack -- one ``lax.scan`` whose body does a top-k placement
decision plus elementwise cost/state updates.  This kernel is the TPU port
of that inner step: the candidate axis is the outer grid dimension, the
period axis the inner one, and the scan carry (placement, hotness,
recency, running totals) lives in VMEM scratch across the period axis --
the same accumulator idiom as ``paged_attention``.  One launch evaluates
the whole candidate ladder without leaving the device.

The paper's placement rule needs the top-``capacity`` pages by score.
``lax.top_k`` does not lower to Pallas, so selection is reformulated as a
*rank* computation with a [n, n] compare matrix (the TPU-native trick
``page_hist`` uses for histograms -- VPU compares, no sort):

    rank_i = #{j : score_j > score_i}  +  #{j < i : score_j == score_i}
    new_fast_i = rank_i < capacity

which selects exactly ``lax.top_k``'s membership set (score descending,
index ascending on ties), so the kernel is bit-identical to the jax path
-- all cost arithmetic is the same float32 expressions in the same order.

VMEM bound: the compare matrix is [num_pages, num_pages] f32; footprints
up to ~1.5k pages fit comfortably.  The batched jax path remains the
default on larger footprints (``core.sim.sweep(impl=...)`` selects).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(num_reals, hist_ref, init_ref, rt_ref, sw_ref, fh_ref,
            fast_scr, hot_scr, last_scr, acc_scr, *, capacity: int,
            predictive: bool, lat_fast: float, lat_slow: float,
            bw_slow: float, bw_penalty: float, mig_cost: float,
            period_overhead: float, ema_alpha: float, n_periods: int):
    c = pl.program_id(0)
    i = pl.program_id(1)
    n = hot_scr.shape[0]

    @pl.when(i == 0)
    def _init():
        fast_scr[...] = init_ref[...].astype(jnp.float32)
        hot_scr[...] = jnp.zeros_like(hot_scr)
        last_scr[...] = jnp.full_like(last_scr, -1.0)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    counts = hist_ref[0, 0]                       # [n] this period's hist
    valid = i < num_reals[c]
    in_fast = fast_scr[...]
    hotness = hot_scr[...]
    last_access = last_scr[...]

    # --- scheduler decision at period start (same f32 expressions, same
    # order, as core.sim._scan_one) ----------------------------------------
    rank = counts if predictive else hotness
    recency = (last_access + 1.0) / (jnp.float32(i) + 2.0)
    score = rank * 1e6 + recency + 0.5 * in_fast

    # top-`capacity` membership via rank (exact lax.top_k tie semantics)
    beats = (score[None, :] > score[:, None]).astype(jnp.float32)
    idx = jax.lax.iota(jnp.int32, n)
    ties = ((score[None, :] == score[:, None])
            & (idx[None, :] < idx[:, None])).astype(jnp.float32)
    r = jnp.sum(beats + ties, axis=1)
    new_fast = (r < capacity).astype(jnp.float32)
    new_fast = jnp.where(valid, new_fast, in_fast)

    swaps = jnp.sum(new_fast * (1.0 - in_fast))

    # --- service this period's accesses ------------------------------------
    total = jnp.sum(counts)
    n_fast = jnp.sum(counts * new_fast)
    n_slow = total - n_fast
    latency = n_fast * lat_fast + n_slow * lat_slow
    bw_extra = jnp.maximum(0.0, n_slow - bw_slow * total) * bw_penalty
    period_rt = latency + bw_extra + swaps * mig_cost + period_overhead
    period_rt = jnp.where(valid, period_rt, 0.0)
    swaps = jnp.where(valid, swaps, 0.0)
    n_fast = jnp.where(valid, n_fast, 0.0)

    # --- post-period state updates -----------------------------------------
    hot_scr[...] = jnp.where(valid,
                             ema_alpha * counts + (1 - ema_alpha) * hotness,
                             hotness)
    last_scr[...] = jnp.where(valid & (counts > 0), jnp.float32(i),
                              last_access)
    fast_scr[...] = new_fast
    acc_scr[...] = acc_scr[...] + jnp.stack([period_rt, swaps, n_fast])

    @pl.when(i == n_periods - 1)
    def _flush():
        rt_ref[0] = acc_scr[0]
        sw_ref[0] = acc_scr[1]
        fh_ref[0] = acc_scr[2]


def sim_scan(period_hists, num_reals, init_fast, *, predictive: bool,
             capacity: int, lat_fast, lat_slow, bw_slow, bw_penalty,
             mig_cost, period_overhead, ema_alpha,
             interpret: bool = False):
    """Fused candidate sweep.  period_hists: f32[C, P, num_pages];
    num_reals: int32[C]; init_fast: bool[num_pages].
    Returns (runtime [C], swaps [C], fast_hits [C])."""
    c, p, n = period_hists.shape
    kernel = functools.partial(
        _kernel, capacity=int(capacity), predictive=bool(predictive),
        lat_fast=float(lat_fast), lat_slow=float(lat_slow),
        bw_slow=float(bw_slow), bw_penalty=float(bw_penalty),
        mig_cost=float(mig_cost), period_overhead=float(period_overhead),
        ema_alpha=float(ema_alpha), n_periods=p)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c, p),
        in_specs=[
            pl.BlockSpec((1, 1, n), lambda ci, pi, nr: (ci, pi, 0)),
            pl.BlockSpec((n,), lambda ci, pi, nr: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda ci, pi, nr: (ci,)),
            pl.BlockSpec((1,), lambda ci, pi, nr: (ci,)),
            pl.BlockSpec((1,), lambda ci, pi, nr: (ci,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((n,), jnp.float32),    # placement (0/1)
            pltpu.VMEM((n,), jnp.float32),    # hotness EMA
            pltpu.VMEM((n,), jnp.float32),    # last access period
            pltpu.VMEM((3,), jnp.float32),    # (runtime, swaps, hits)
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((c,), jnp.float32)] * 3,
        interpret=interpret,
    )(jnp.asarray(num_reals, jnp.int32), period_hists,
      jnp.asarray(init_fast))
