"""Cori core: the paper's contribution.

Reuse collection (``reuse``), frequency generation + tuning (``cori``),
the trace-driven hybrid-memory simulator with reactive/predictive page
schedulers (``sim``), application trace generators (``traces``), prior-work
baselines (``baselines``) and the end-to-end pipeline (``pipeline``).
"""
from repro.core.baselines import (BASELINE_ORDERS, TABLE_I_PERIODS,
                                  base_candidates, ordered_candidates,
                                  table_i_periods_for)
from repro.core.cori import (OnlineTuner, Tuner, TuneResult,
                             candidate_periods, dominant_reuse,
                             trials_to_best)
from repro.core.pipeline import (AppStudy, CoriRun, baseline_trials,
                                 baseline_trials_all,
                                 optimal_runtime, run_cori, study,
                                 table_i_runtimes)
from repro.core.reuse import (ReuseHistogram, StreamingReuseCollector,
                              loop_duration_histogram, prune_insignificant,
                              reuse_distance_histogram, reuse_distances)
from repro.core.sim import (SCHEDULERS, SimConfig, SimResult, TraceBins,
                            bin_trace, exhaustive_periods, simulate,
                            simulate_reference, sweep, sweep_loop)
from repro.core.traces import TRACE_GENERATORS, Trace, available_traces, generate
from repro.core.traffic import (RequestSpec, correlated_burst_stream,
                                diurnal_stream, flash_crowd_stream,
                                invert_kinds, mix_inversion_stream,
                                modulated_request_stream,
                                poisson_request_stream, shifting_mix_stream)

__all__ = [
    "AppStudy", "BASELINE_ORDERS", "CoriRun", "OnlineTuner", "RequestSpec",
    "ReuseHistogram",
    "SCHEDULERS", "SimConfig", "SimResult", "StreamingReuseCollector",
    "TRACE_GENERATORS", "Trace", "TraceBins",
    "Tuner", "TuneResult", "available_traces", "base_candidates",
    "baseline_trials", "baseline_trials_all", "bin_trace", "candidate_periods", "dominant_reuse",
    "correlated_burst_stream", "diurnal_stream", "flash_crowd_stream",
    "invert_kinds", "mix_inversion_stream", "modulated_request_stream",
    "exhaustive_periods", "generate", "loop_duration_histogram",
    "optimal_runtime", "ordered_candidates", "poisson_request_stream",
    "prune_insignificant", "reuse_distance_histogram",
    "shifting_mix_stream",
    "reuse_distances", "run_cori", "simulate", "simulate_reference", "study",
    "sweep", "sweep_loop", "table_i_periods_for", "table_i_runtimes",
    "trials_to_best",
    "TABLE_I_PERIODS",
]
