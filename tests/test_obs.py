"""Flight recorder: ring buffer, metrics, decision-trace regression,
exporters, report, and the zero-cost-when-disabled contract.

Covers the observability PR: the bounded event ring and its closed
taxonomy, streaming-quantile histograms, the exact tuner state-transition
sequences on deterministic streams (converge, poisoned TRIAL, regime
change, HOLD escalation) reconstructed *from the event log alone*, the
JSONL round-trip and Perfetto structural validity, the report CLI, the
StepTimer straggler path, and behavioral identity of the traffic
scheduler with telemetry on vs off."""
import json
import math

import numpy as np
import pytest

from repro import obs
from repro.core import OnlineTuner
from repro.core.traffic import poisson_request_stream
from repro.ft.monitor import StepTimer
from repro.memtier import SharedPagedPools, TierConfig, TieringManager
from repro.obs import telemetry
from repro.obs import report as obs_report
from repro.serve.sched import TrafficMonitor, TrafficScheduler


@pytest.fixture()
def rec():
    """Fresh recorder installed process-wide; the previous one restored
    afterwards so tests never leak events into each other."""
    prev = telemetry.get()
    r = obs.install(obs.Recorder(enabled=True))
    yield r
    obs.install(prev)


# ---------------------------------------------------------------------------
# Recorder: ring buffer, taxonomy, metrics
# ---------------------------------------------------------------------------


def test_ring_is_bounded_ordered_and_counts_drops():
    r = obs.Recorder(capacity=8, enabled=True)
    for i in range(20):
        r.emit("serve.retire", step=i, rid=i, tokens=1)
    evs = r.events()
    assert len(evs) == 8, "ring must cap at capacity"
    assert [e["step"] for e in evs] == list(range(12, 20)), \
        "ring keeps the newest events in emission order"
    assert [e["seq"] for e in evs] == list(range(12, 20))
    assert r.dropped == 12
    assert r.summary()["events_dropped"] == 12


def test_unregistered_event_type_raises():
    r = obs.Recorder(enabled=True)
    with pytest.raises(KeyError, match="unregistered"):
        r.emit("tuner.bogus", step=0)
    # disabled recorder short-circuits before the registry check
    r.enabled = False
    r.emit("tuner.bogus", step=0)


def test_disabled_recorder_collects_nothing():
    r = obs.Recorder(enabled=False)
    r.emit("serve.retire", step=0, rid=0, tokens=1)
    r.count("x")
    r.gauge("y", 1.0)
    r.observe("z", 1.0)
    assert r.events() == []
    s = r.summary()
    assert s["counters"] == {} and s["gauges"] == {} and s["hists"] == {}


def test_events_filter_by_type_and_prefix():
    r = obs.Recorder(enabled=True)
    r.emit("serve.retire", step=0, rid=0, tokens=1)
    r.emit("serve.admit", step=0, joiners=1, pages=2, queue_depth=0,
           wall_ms=0.1)
    r.emit("tier.move", manager="m0", step=4, period=4, promoted=1,
           evicted=0, pages_moved=2, cost=1.0)
    assert len(r.events("serve.admit")) == 1
    assert len(r.events(prefix="serve.")) == 2
    assert len(r.events(prefix="tier.")) == 1


def test_install_swaps_recorder_for_module_attribute_readers(rec):
    """The hot-path idiom reads telemetry.RECORDER per call, so install()
    must redirect everyone at once -- including the obs package alias."""
    assert telemetry.RECORDER is rec and obs.RECORDER is rec
    r2 = obs.install(obs.Recorder(enabled=True))
    assert telemetry.RECORDER is r2 and obs.RECORDER is r2


def test_histogram_quantiles_within_bucket_error():
    h = obs.Histogram()
    xs = np.linspace(1e-3, 10.0, 5000)
    for x in xs:
        h.observe(float(x))
    # geometric buckets at ratio 2**0.25 bound relative error by ~9%
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        assert h.quantile(q) == pytest.approx(exact, rel=0.10)
    assert h.count == 5000
    assert h.vmin == pytest.approx(1e-3) and h.vmax == pytest.approx(10.0)
    assert h.mean == pytest.approx(float(xs.mean()), rel=1e-6)


def test_histogram_nonfinite_and_extremes_stay_out_of_quantiles():
    h = obs.Histogram()
    for v in (1.0, 2.0, math.nan, math.inf, -5.0, 0.0):
        h.observe(v)
    assert h.nonfinite == 2
    assert h.count == 4                      # finite ones only
    assert h.vmin == -5.0 and h.vmax == 2.0
    assert math.isfinite(h.quantile(0.99))
    s = h.summary()
    assert s["nonfinite"] == 2 and s["count"] == 4


# ---------------------------------------------------------------------------
# Decision-trace regression: exact transition sequences from the log alone
# ---------------------------------------------------------------------------


def _converge(rec, **kw):
    """Drive a tuner to HOLD at period 8 (mirrors test_hostile's helper);
    returns (tuner, ids)."""
    params = dict(default_period=2, profile_steps=32, trial_steps=32,
                  horizon_steps=64, bin_width=1, patience=3)
    params.update(kw)
    tuner = OnlineTuner(64, **params)
    ids = lambda t: np.array([t % 4])
    for t in range(600):
        tuner.on_step(accessed_ids=ids(t), cost=abs(tuner.period - 8) + 1.0)
    assert tuner.state == OnlineTuner.HOLD and tuner.period == 8
    return tuner, ids


def _transitions(rec, tuner):
    return [(e["frm"], e["to"], e["reason"])
            for e in rec.events("tuner.transition")
            if e["tuner"] == tuner.obs_id]


def test_trace_converge_pins_profile_trial_hold_sequence(rec):
    tuner, _ = _converge(rec)
    ts = _transitions(rec, tuner)
    assert ts[0] == ("profile", "trial", "profile-complete")
    assert ts[1] == ("trial", "hold", "sweep-complete")
    assert len(ts) == 2, f"steady convergence must not churn: {ts}"
    # the trial phase switched periods: every change is in the log
    periods = [e for e in rec.events("tuner.period")
               if e["tuner"] == tuner.obs_id]
    assert periods, "candidate switches must emit tuner.period"
    assert all(e["period"] != e["prev"] for e in periods)
    trials = [e for e in rec.events("tuner.trial")
              if e["tuner"] == tuner.obs_id]
    assert trials and trials[-1]["best_period"] == 8
    base = [e for e in rec.events("tuner.baseline")
            if e["tuner"] == tuner.obs_id]
    assert base, "HOLD must attest a baseline"


def test_trace_poisoned_trial_records_burst_verdict_and_revert(rec):
    tuner, ids = _converge(rec)
    rec.clear()
    tuner._reprofile()                        # warm manual re-tune
    for i in range(200):
        if tuner.state != OnlineTuner.TRIAL:
            break
        tuner.on_step(accessed_ids=ids(i),
                      cost=300.0 if (i // 8) % 2 == 0 else 1.0)
    assert _transitions(rec, tuner) == [
        ("hold", "trial", "warm-manual"),
        ("trial", "hold", "guard-abort"),
    ]
    guards = [e for e in rec.events("tuner.guard")
              if e["tuner"] == tuner.obs_id]
    assert len(guards) == 1
    assert guards[0]["where"] == "trial" and guards[0]["verdict"] == "burst"
    # warm sweeps start at the previous winner and the abort reverts to
    # it, so a clean revert means NO period change ever hit the log
    assert tuner.period == 8
    assert [e for e in rec.events("tuner.period")
            if e["tuner"] == tuner.obs_id] == []


def test_trace_uniform_regime_change_records_cold_reprofile(rec):
    tuner, ids = _converge(rec)
    rec.clear()
    tuner._reprofile()
    for i in range(200):
        if tuner.state != OnlineTuner.TRIAL:
            break
        tuner.on_step(accessed_ids=ids(i), cost=300.0)
    assert _transitions(rec, tuner) == [
        ("hold", "trial", "warm-manual"),
        ("trial", "profile", "cold-guard-regime"),
    ]
    g = [e for e in rec.events("tuner.guard")
         if e["tuner"] == tuner.obs_id]
    assert g and g[-1]["verdict"] == "regime"


def test_trace_hold_escalation_records_discard_then_cold(rec):
    tuner, ids = _converge(rec, drift_patience=3)
    rec.clear()
    i = 0
    while tuner.state == OnlineTuner.HOLD and i < 3000:
        tuner.on_step(accessed_ids=ids(i), cost=100.0)
        i += 1
    assert tuner.state == OnlineTuner.PROFILE
    assert _transitions(rec, tuner) == [
        ("hold", "profile", "cold-guard-escalate")]
    kinds = [e["kind"] for e in rec.events("tuner.hold_window")
             if e["tuner"] == tuner.obs_id]
    assert kinds.count("discard-guard") >= 1, \
        "guard windows before escalation must be logged as discarded"
    verdicts = [e["verdict"] for e in rec.events("tuner.guard")
                if e["tuner"] == tuner.obs_id]
    assert verdicts[:-1].count("discard") >= 1
    assert verdicts[-1] == "escalate"


def test_trace_drift_records_strikes_then_warm_retune(rec):
    tuner, ids = _converge(rec, drift_ratio=1.5, drift_patience=2)
    rec.clear()
    i = 0
    # sustained 2x cost: drift strikes accumulate, then a warm re-tune
    while tuner.state == OnlineTuner.HOLD and i < 3000:
        tuner.on_step(accessed_ids=ids(i),
                      cost=2.0 * (abs(tuner.period - 8) + 1.0))
        i += 1
    assert tuner.state == OnlineTuner.TRIAL
    ts = _transitions(rec, tuner)
    assert ts == [("hold", "trial", "warm-drift")]
    kinds = [e["kind"] for e in rec.events("tuner.hold_window")
             if e["tuner"] == tuner.obs_id]
    assert kinds.count("drift-strike") >= 2, \
        "each drifting window before the re-tune must log a strike"


def test_cost_log_and_recorder_histogram_agree(rec):
    tuner, _ = _converge(rec)
    h = rec.hists["tuner.cost_per_step"]
    assert h.count == 600, "every on_step cost lands in the histogram"
    # cost_log is the bounded working window of the same series
    assert list(tuner.cost_log)[-1] == 1.0
    assert h.vmin == pytest.approx(min(tuner.cost_log))


# ---------------------------------------------------------------------------
# Exporters: JSONL round-trip, Perfetto structure
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_and_metrics_summary(rec, tmp_path):
    tuner, _ = _converge(rec)
    path = obs.write_jsonl(tmp_path / "log.jsonl", rec)
    back = obs.read_jsonl(path)
    assert back[-1]["type"] == "metrics.summary"
    assert back[-1]["schema"] == obs.SCHEMA
    assert "tuner.cost_per_step" in back[-1]["hists"]
    evs = back[:-1]
    assert [e["type"] for e in evs] == [e["type"] for e in rec.events()]
    assert all(set(("seq", "t", "type")) <= set(e) for e in evs)
    # every line is independently parseable (flat records, no nesting
    # beyond the closing summary)
    for line in path.read_text().splitlines():
        json.loads(line)


def test_perfetto_trace_has_phase_spans_windows_and_counters(rec, tmp_path):
    tuner, ids = _converge(rec)
    mgr = TieringManager(32, TierConfig(page_size=4, hbm_pages=4,
                                        period_steps=4))
    resident = np.zeros(32, bool)
    for t in range(16):
        mass = np.zeros(32, np.float32)
        mass[t % 8] = 1.0
        mgr.on_step(mass, resident)
        mgr.maybe_tier_symbolic(resident)
    trace = obs.perfetto_trace(rec.events())
    te = trace["traceEvents"]
    assert trace["otherData"]["schema"] == obs.SCHEMA
    names = {e["name"] for e in te}
    spans = [e for e in te if e["ph"] == "X"]
    assert {"PROFILE", "TRIAL", "HOLD"} <= {e["name"] for e in spans}, \
        "tuner phases must render as duration spans"
    assert any(e["name"].startswith("window(p=") for e in spans), \
        "tiering windows must render as spans"
    assert any(e["ph"] == "C" and e["name"].startswith("period")
               for e in te), "period counter track missing"
    assert any(e["ph"] == "M" for e in te), "process/thread names missing"
    for e in spans:
        assert e["dur"] >= 1
    # file form loads as JSON
    p = obs.write_perfetto(tmp_path / "trace.json", rec.events())
    assert json.loads(p.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# Report: the replay CLI
# ---------------------------------------------------------------------------


def test_report_reconstructs_decision_trace_from_log_alone(rec, tmp_path,
                                                           capsys):
    tuner, ids = _converge(rec)
    tuner._reprofile()
    for i in range(200):
        if tuner.state != OnlineTuner.TRIAL:
            break
        tuner.on_step(accessed_ids=ids(i),
                      cost=300.0 if (i // 8) % 2 == 0 else 1.0)
    path = obs.write_jsonl(tmp_path / "log.jsonl", rec)

    obs_report.main([str(path)])
    out = capsys.readouterr().out
    assert "PROFILE -> TRIAL" in out.upper().replace("  ", " ") or \
        "profile -> trial" in out.lower()
    assert "sweep-complete" in out
    assert "warm-manual" in out
    assert "guard-abort" in out
    assert "burst" in out
    assert "tuner.cost_per_step" in out, "metrics table missing"

    trace = obs_report.decision_trace(obs.read_jsonl(path))
    states = ("PROFILE", "TRIAL", "HOLD")
    trans_lines = [ln for ln in trace if any(
        f"{a} -> {b}" in ln for a in states for b in states)]
    assert len(trans_lines) == 4, \
        "converge (2) + warm re-tune + guard-abort (2) transitions"


def test_report_writes_perfetto_sidecar(rec, tmp_path, capsys):
    _converge(rec)
    log = obs.write_jsonl(tmp_path / "log.jsonl", rec)
    out = tmp_path / "trace.json"
    obs_report.main([str(log), "--perfetto", str(out)])
    assert json.loads(out.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# StepTimer -> recorder
# ---------------------------------------------------------------------------


def test_step_timer_reports_histogram_and_straggler_event(rec, monkeypatch):
    t = StepTimer(threshold=3.0, warmup=1, name="serve.macro")
    now = [0.0]
    monkeypatch.setattr("repro.ft.monitor.time",
                        type("T", (), {"monotonic":
                                       staticmethod(lambda: now[0])}))
    for step, dt in enumerate((0.1, 0.1, 0.1, 1.0)):
        t.start()
        now[0] += dt
        t.stop(step)
    assert t.stragglers == [3]
    ev = rec.events("ft.straggler")
    assert len(ev) == 1
    assert ev[0]["timer"] == "serve.macro" and ev[0]["step"] == 3
    assert ev[0]["dt_s"] == pytest.approx(1.0)
    assert ev[0]["dt_s"] > 3.0 * ev[0]["ema_s"]
    assert rec.counters["ft.stragglers"] == 1
    assert rec.hists["serve.macro.step_s"].count == 4


def test_unnamed_step_timer_stays_silent(rec):
    t = StepTimer(warmup=1)
    for step in range(4):
        t.start()
        t.stop(step)
    assert rec.events("ft.straggler") == []
    assert "None.step_s" not in rec.hists and not rec.hists


# ---------------------------------------------------------------------------
# Telemetry must never change behavior: scheduler identity on vs off
# ---------------------------------------------------------------------------


def _run_traffic(enabled: bool):
    prev = telemetry.get()
    r = obs.install(obs.Recorder(enabled=enabled))
    try:
        specs = poisson_request_stream(
            40, 0.3, {"sink": 0.5, "random": 0.5}, prompt_len=(4, 60),
            new_tokens=(8, 40), seed=7)
        pools = SharedPagedPools.create(128, 16)
        mgr = TieringManager(128, TierConfig(page_size=16, hbm_pages=16,
                                             period_steps=4))
        tuner = OnlineTuner(128, default_period=4)
        sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr, tuner),
                                 page_size=16, max_active=6)
        sched.run(400)
        return (sched.admitted, sched.completed, tuner.period, tuner.state,
                mgr.modeled_time, r)
    finally:
        obs.install(prev)


def test_scheduler_behavior_identical_with_telemetry_on_and_off():
    a_on = _run_traffic(True)
    a_off = _run_traffic(False)
    assert a_on[:5] == a_off[:5], \
        "recording must be a pure observer of the serving/tuning path"
    r_on, r_off = a_on[5], a_off[5]
    assert r_off.events() == [] and r_off.summary()["counters"] == {}
    # the enabled run captured the full decision path end to end
    types = {e["type"] for e in r_on.events()}
    assert {"serve.admit", "serve.retire", "tier.move",
            "tuner.transition"} <= types
    c = r_on.summary()["counters"]
    assert c["serve.admitted"] == a_on[0]
    assert c["serve.retired"] == a_on[1]
    assert c["tier.pages_moved"] >= 0
    g = r_on.summary()["gauges"]
    assert 0.0 <= g["pool.hbm_resident_frac"] <= 1.0
    assert 0.0 <= g["pool.allocated_frac"] <= 1.0
