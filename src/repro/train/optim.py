"""AdamW with configurable state precision (fp32 / bf16 / int8-blockwise).

State-precision ladder (distributed-optimization trick for the 340B/671B
configs -- see EXPERIMENTS.md memory table):
    fp32: 8 bytes/param of optimizer state
    bf16: 4 bytes/param
    int8: ~2.06 bytes/param (blockwise 128 with fp32 scales, error kept by
          re-quantising after each update; same recipe as 8-bit Adam)

Pure-pytree implementation (no optax dependency in the container).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"       # float32 | bfloat16 | int8


# ---------------------------------------------------------------------------
# int8 blockwise quantisation
# ---------------------------------------------------------------------------


class _QLeaf(NamedTuple):
    """Pytree-registered quantised leaf (blockwise int8).

    Linear mode (signed data, e.g. Adam m):  x ~ q * scale,        zero == 0
    Log mode (positive data, e.g. Adam v):   x ~ exp(zero + (q+127)*scale)
    Log-domain quantisation is essential for v: linear int8 zeroes small
    second moments within a block and the update m/sqrt(v) explodes."""
    q: jnp.ndarray      # int8 [nblocks, QBLOCK]
    scale: jnp.ndarray  # f32  [nblocks, 1]
    zero: jnp.ndarray   # f32  [nblocks, 1]


def _blocks(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK)


def _quantize_linear(x) -> _QLeaf:
    b = _blocks(x)
    scale = jnp.maximum(jnp.max(jnp.abs(b), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    return _QLeaf(q, scale.astype(jnp.float32),
                  jnp.zeros_like(scale, jnp.float32))


def _quantize_log(x) -> _QLeaf:
    lx = jnp.log(_blocks(x) + 1e-30)
    lo = jnp.min(lx, axis=1, keepdims=True)
    hi = jnp.max(lx, axis=1, keepdims=True)
    scale = jnp.maximum((hi - lo) / 254.0, 1e-8)
    q = jnp.clip(jnp.round((lx - lo) / scale) - 127, -127, 127).astype(jnp.int8)
    return _QLeaf(q, scale.astype(jnp.float32), lo.astype(jnp.float32))


def _pack(x: jnp.ndarray, dtype: str, mode: str = "linear"):
    if dtype == "int8":
        return (_quantize_log(x) if mode == "log" else _quantize_linear(x))
    return x.astype(jnp.dtype(dtype))


def _unpack(leaf, shape, dtype: str, mode: str = "linear") -> jnp.ndarray:
    if dtype == "int8":
        n = int(np.prod(shape))
        if mode == "log":
            flat = jnp.exp(leaf.zero
                           + (leaf.q.astype(jnp.float32) + 127.0)
                           * leaf.scale).reshape(-1)
            flat = jnp.where(flat <= 2e-30, 0.0, flat)
        else:
            flat = (leaf.q.astype(jnp.float32) * leaf.scale).reshape(-1)
        return flat[:n].reshape(shape)
    return leaf.astype(jnp.float32)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(np.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params, cfg: OptConfig):
    m0 = jax.tree.map(lambda p: _pack(jnp.zeros_like(p, jnp.float32),
                                      cfg.state_dtype, "linear"), params)
    v0 = jax.tree.map(lambda p: _pack(jnp.zeros_like(p, jnp.float32),
                                      cfg.state_dtype, "log"), params)
    return {"m": m0, "v": v0, "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state, params, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, state["count"])
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    is_q = lambda x: isinstance(x, _QLeaf)

    def one(p, g, m_leaf, v_leaf):
        g = g.astype(jnp.float32) * clip
        m = _unpack(m_leaf, p.shape, cfg.state_dtype, "linear")
        v = _unpack(v_leaf, p.shape, cfg.state_dtype, "log")
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (upd + cfg.weight_decay
                                              * p.astype(jnp.float32))
        return (new_p.astype(p.dtype), _pack(m, cfg.state_dtype, "linear"),
                _pack(v, cfg.state_dtype, "log"))

    # explicit flatten: quantised m/v leaves are themselves pytrees, so a
    # single tree.map over `params` would see a structure mismatch.
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    v_leaves = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
    outs = [one(p, g, m, v) for p, g, m, v in
            zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_state = {"m": jax.tree.unflatten(treedef, [o[1] for o in outs]),
                 "v": jax.tree.unflatten(treedef, [o[2] for o in outs]),
                 "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_specs(param_specs, cfg: OptConfig):
    """Logical-axis spec tree for the optimizer state.  fp32/bf16 states
    mirror the param specs; int8 leaves are blockwise-flat [nblocks, 128]
    and shard their block dim over "data" when divisible ("qblocks")."""
    is_axes = lambda x: x is None or (isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x))
    if cfg.state_dtype == "int8":
        wrap = lambda ax: _QLeaf(("qblocks", None), ("qblocks", None),
                                 ("qblocks", None))
    else:
        wrap = lambda ax: ax
    m = jax.tree.map(wrap, param_specs, is_leaf=is_axes)
    return {"m": m, "v": m, "count": None}
