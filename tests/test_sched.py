"""Continuous-batching scheduler: shared page pool, traffic-fed tuning.

Covers the PR-2 tentpole: SharedPagedPools allocation/eviction across
requests, multi-request tiering with free slots and active masks, global
page-ID reuse collection (including ID recycling), the TrafficScheduler's
admission/retire path, the end-state acceptance vs a fixed-period sweep,
and the model-backed ContinuousBatcher's token parity with per-request
generate over the shared pool."""
import dataclasses

import numpy as np
import pytest

from repro.core import OnlineTuner, StreamingReuseCollector, RequestSpec
from repro.core.traffic import poisson_request_stream, shifting_mix_stream
from repro.memtier import (SharedPagedPools, TierConfig, TieringManager)
from repro.serve.sched import (TrafficMonitor, TrafficScheduler,
                               WORKLOAD_KINDS)

CFG = TierConfig(page_size=16, hbm_pages=8, period_steps=4)


# ---------------------------------------------------------------------------
# SharedPagedPools: allocation, eviction, recycling
# ---------------------------------------------------------------------------


def test_shared_pool_alloc_free_recycle():
    pools = SharedPagedPools.create(8, 4)
    a = pools.alloc(3, owner=0)
    b = pools.alloc(3, owner=1)
    np.testing.assert_array_equal(a, [0, 1, 2])
    np.testing.assert_array_equal(b, [3, 4, 5])
    assert pools.alloc(3, owner=2) is None, "over-capacity must queue"
    assert pools.free_pages == 2
    pools.free(a)
    c = pools.alloc(4, owner=2)
    np.testing.assert_array_equal(c, [0, 1, 2, 6])  # freed ids recycle
    assert (pools.owner_of[c] == 2).all()


def test_shared_pool_free_evicts_slots():
    pools = SharedPagedPools.create(8, 4)
    gids = pools.alloc(4, owner=0)
    pools.ensure_resident(gids)
    assert (pools.slot_of[gids] >= 0).all()
    assert len(pools.free_slots()) == 0
    pools.free(gids)
    assert (pools.slot_of[gids] == -1).all()
    assert len(pools.free_slots()) == 4, "retired pages release their slots"


def test_ensure_resident_demand_fetch_counts_and_evicts():
    pools = SharedPagedPools.create(16, 4)
    a = pools.alloc(4, owner=0)
    b = pools.alloc(4, owner=1)
    assert pools.ensure_resident(a) == 4
    assert pools.ensure_resident(a) == 0, "already resident: no fetch"
    assert pools.ensure_resident(b[:2]) == 2, "evicts a's LRU slots"
    resident_b = pools.slot_of[b[:2]]
    assert (resident_b >= 0).all()
    assert (pools.slot_of[a] >= 0).sum() == 2
    with pytest.raises(ValueError, match="cannot fit"):
        pools.ensure_resident(np.arange(5))


def test_multi_request_tiering_fills_freed_slots_without_evicting():
    """After a retirement, maybe_tier brings new hot pages into the freed
    slots and keeps still-useful residents (lazy eviction)."""
    pools = SharedPagedPools.create(16, 4)
    mgr = TieringManager(16, dataclasses.replace(CFG, hbm_pages=4,
                                                 period_steps=1))
    a = pools.alloc(4, owner=0)
    mass = np.zeros(16, np.float32)
    mass[a] = 1.0
    for _ in range(4):
        mgr.on_step(mass, pools.resident_mask)
        mgr.maybe_tier(pools, active=pools.allocated_mask)
    assert (pools.slot_of[a] >= 0).all()
    # request 0 retires two pages; request 1 arrives hot
    mgr.release(a[2:])
    pools.free(a[2:])
    b = pools.alloc(2, owner=1)
    migs = mgr.migrations
    mass = np.zeros(16, np.float32)
    mass[a[:2]] = 1.0
    mass[b] = 1.0
    for _ in range(4):
        mgr.on_step(mass, pools.resident_mask)
        mgr.maybe_tier(pools, active=pools.allocated_mask)
    assert (pools.slot_of[b] >= 0).all(), "new request's pages tier in"
    assert (pools.slot_of[a[:2]] >= 0).all(), "live residents not evicted"
    assert mgr.migrations - migs == 2, "exactly the freed slots were filled"


def test_active_mask_keeps_unallocated_pages_out():
    """Pages no request owns must never enter the working set even when
    capacity exceeds the allocated footprint."""
    pools = SharedPagedPools.create(32, 8)
    mgr = TieringManager(32, dataclasses.replace(CFG, hbm_pages=8,
                                                 period_steps=1))
    gids = pools.alloc(3, owner=0)
    mass = np.zeros(32, np.float32)
    mass[gids] = 1.0
    for _ in range(6):
        mgr.on_step(mass, pools.resident_mask)
        mgr.maybe_tier(pools, active=pools.allocated_mask)
    resident = np.nonzero(pools.resident_mask)[0]
    assert set(resident.tolist()) <= set(gids.tolist())


# ---------------------------------------------------------------------------
# global page-ID reuse collection and recycling
# ---------------------------------------------------------------------------


def test_collector_forget_blocks_cross_owner_gaps():
    col = StreamingReuseCollector(8, bin_width=1)
    col.observe(np.array([3]))          # owner A touches page 3 at t=0
    col.forget(np.array([3]))           # A retires, id 3 recycled
    col.observe(np.array([3]))          # owner B touches page 3 at t=1
    assert col.num_samples == 0, "cross-owner gap must not be recorded"
    col.observe(np.array([3]))          # B re-touches: a real gap
    assert col.num_samples == 1


def test_tuner_forget_pages_delegates():
    tuner = OnlineTuner(8, bin_width=1)
    tuner.on_step(accessed_ids=np.array([2]), cost=1.0)
    tuner.forget_pages(np.array([2]))
    tuner.on_step(accessed_ids=np.array([2]), cost=1.0)
    assert tuner.collector.num_samples == 0


def test_monitor_release_clears_everything():
    pools = SharedPagedPools.create(16, 4)
    mgr = TieringManager(16, dataclasses.replace(CFG, hbm_pages=4,
                                                 period_steps=1))
    tuner = OnlineTuner(16, bin_width=1)
    mon = TrafficMonitor(pools, mgr, tuner)
    gids = pools.alloc(3, owner=7)
    mass = np.zeros(16, np.float32)
    mass[gids] = 1.0
    for _ in range(3):
        mon.on_step(mass, n_active=1)
    assert mgr.hotness[gids].sum() > 0
    mon.release(gids)
    assert mgr.hotness[gids].sum() == 0
    assert (mgr.last_access[gids] == -1).all()
    assert (tuner.collector.last_access[gids] == -1).all()
    assert pools.free_pages == 16
    assert (pools.slot_of[gids] == -1).all()


def test_monitor_merge_is_max_per_page():
    pools = SharedPagedPools.create(8, 4)
    mgr = TieringManager(8, CFG)
    mon = TrafficMonitor(pools, mgr)
    m = mon.merge([(np.array([0, 1]), np.array([0.5, 0.2], np.float32)),
                   (np.array([1, 2]), np.array([0.9, 0.1], np.float32))])
    np.testing.assert_allclose(m[:4], [0.5, 0.9, 0.1, 0.0])


# ---------------------------------------------------------------------------
# traffic stream + scheduler
# ---------------------------------------------------------------------------


def test_poisson_stream_reproducible_and_phased():
    a = poisson_request_stream(100, 0.2, {"sink": 1.0}, seed=3)
    b = poisson_request_stream(100, 0.2, {"sink": 1.0}, seed=3)
    assert a == b
    mix = shifting_mix_stream([(50, 0.2, {"random": 1.0}),
                               (50, 0.2, {"sink": 1.0})], seed=1)
    assert all(s.kind == "random" for s in mix if s.arrival < 50)
    assert all(s.kind == "sink" for s in mix if s.arrival >= 50)
    assert [s.rid for s in mix] == list(range(len(mix)))
    spec = RequestSpec(rid=0, arrival=0, prompt_len=17, new_tokens=30,
                       kind="sink", seed=0)
    assert spec.n_pages(16) == 3, "page-aligned allocation rounds up"


def _traffic(specs, steps, *, period=8, tuner=None, n_logical=128,
             hbm=16, page=16, max_active=6, probe_at=None):
    pools = SharedPagedPools.create(n_logical, hbm)
    mgr = TieringManager(n_logical, TierConfig(
        page_size=page, hbm_pages=hbm, period_steps=period))
    sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr, tuner),
                             page_size=page, max_active=max_active)
    probe = 0.0
    for t in range(steps):
        if t == probe_at:
            probe = mgr.modeled_time
        sched.step()
    return sched, mgr, probe


def test_traffic_scheduler_admits_and_retires():
    specs = poisson_request_stream(120, 0.15, {"sink": 0.5, "random": 0.5},
                                   prompt_len=(8, 32), new_tokens=(16, 40),
                                   seed=2)
    sched, mgr, _ = _traffic(specs, 400)
    assert sched.admitted == len(specs)
    assert sched.completed == len(specs), "all requests must drain"
    assert sched.monitor.pools.free_pages == 128, "all pages returned"
    assert mgr.hits + mgr.misses > 0


def test_traffic_scheduler_head_of_line_admission_order():
    """Admission is FIFO even when a later, smaller request would fit."""
    specs = [RequestSpec(0, 0, 40 * 16 - 8, 8, "sink", 0),    # 40 pages
             RequestSpec(1, 0, 40 * 16 - 8, 8, "sink", 1),    # 40 pages
             RequestSpec(2, 0, 8, 8, "sink", 2)]              # 1 page
    sched, _, _ = _traffic(specs, 3, n_logical=64, hbm=16)
    assert sched.admitted == 1, "head-of-line blocks; order is preserved"


def test_impossible_requests_rejected_not_deadlocked():
    """A request larger than the whole logical space can never admit; it is
    dropped (TrafficScheduler) or refused at submit (ContinuousBatcher)
    instead of blocking the queue forever."""
    specs = [RequestSpec(0, 0, 100 * 16 - 8, 8, "sink", 0),   # 100 pages
             RequestSpec(1, 0, 8, 8, "sink", 1)]              # 1 page
    sched, _, _ = _traffic(specs, 3, n_logical=64, hbm=16)
    assert sched.rejected == 1
    assert sched.admitted == 1, "the queue keeps moving"


def test_traffic_replay_deterministic():
    specs = poisson_request_stream(80, 0.2, {"sink": 1.0}, seed=5)
    _, m1, _ = _traffic(specs, 200)
    _, m2, _ = _traffic(specs, 200)
    assert m1.modeled_time == m2.modeled_time
    assert m1.migrations == m2.migrations


def test_admission_independent_of_period():
    """Fixed-period replays of one stream admit/retire identically -- the
    property that makes the brute-force sweep comparable."""
    specs = poisson_request_stream(100, 0.2, {"sink": 1.0}, seed=4)
    s1, _, _ = _traffic(specs, 300, period=1)
    s2, _, _ = _traffic(specs, 300, period=64)
    assert (s1.admitted, s1.completed) == (s2.admitted, s2.completed)


# ---------------------------------------------------------------------------
# the acceptance: scheduler-fed tuner vs brute-force sweep
# ---------------------------------------------------------------------------


def test_traffic_online_tuner_within_5pct_of_best_fixed():
    """PR-2 acceptance: on a Poisson stream whose mix shifts mid-run, the
    scheduler-fed OnlineTuner's end-state modeled cost is within 5% of the
    best fixed period found by sweeping."""
    phase = 700
    steps, window = 2 * phase, 150
    lo = steps - window
    specs = shifting_mix_stream(
        [(phase, 0.10, {"random": 1.0}), (phase, 0.10, {"sink": 1.0})],
        prompt_len=(16, 48), new_tokens=(40, 100), seed=0)
    kw = dict(n_logical=256, hbm=32, page=16, max_active=8)

    tuner = OnlineTuner(256, default_period=8, drift_ratio=1.5,
                        drift_patience=3)
    _, mgr, probe = _traffic(specs, steps, tuner=tuner, probe_at=lo, **kw)
    online_steady = (mgr.modeled_time - probe) / window
    assert tuner.retunes >= 2, "the mix shift must trigger a re-tune"

    best = np.inf
    for p in (1, 2, 4, 8, 16, 32, 64):
        _, m, pr = _traffic(specs, steps, period=p, probe_at=lo, **kw)
        best = min(best, (m.modeled_time - pr) / window)
    assert online_steady <= 1.05 * best, \
        f"online {online_steady:.1f} vs best fixed {best:.1f}"


# ---------------------------------------------------------------------------
# model-backed ContinuousBatcher (token parity over the shared pool)
# ---------------------------------------------------------------------------


def _tiny_serving_stack(cfg, params, *, n_logical=48, hbm=16, page=4):
    pools = SharedPagedPools.create(n_logical, hbm, page_size=page,
                                    kv_heads=cfg.num_kv_heads,
                                    head_dim=cfg.head_dim)
    mgr = TieringManager(n_logical, TierConfig(page_size=page,
                                               hbm_pages=hbm,
                                               period_steps=2))
    tuner = OnlineTuner(n_logical, default_period=2, profile_steps=8,
                        trial_steps=4)
    return TrafficMonitor(pools, mgr, tuner)


def test_batcher_token_parity_with_generate():
    """Multi-request decode over SharedPagedPools emits token-identical
    output to per-request generate (greedy and temperature sampling),
    across staggered admission and row reuse."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 9, 5)]
    keys = [jax.random.PRNGKey(10 + i) for i in range(3)]
    steps = [6, 4, 7]
    temps = [0.0, 0.7, 0.7]

    mon = _tiny_serving_stack(cfg, params)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon, mirror_pages=True)
    b.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=steps[0],
                     key=keys[0], temperature=temps[0]))
    b.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=steps[1],
                     key=keys[1], temperature=temps[1]))
    events = []
    for t in range(40):
        if t == 2:   # joins mid-flight, lands in a recycled row
            b.submit(Request(rid=2, prompt=prompts[2],
                             max_new_tokens=steps[2], key=keys[2],
                             temperature=temps[2]))
        events.extend(b.step())
        if not b.queue and not b.active:
            break
    got = {r.rid: r.tokens for r in b.completed}
    for i in range(3):
        ref = np.asarray(generate(params, cfg, jnp.asarray(prompts[i])[None],
                                  steps=steps[i], temperature=temps[i],
                                  key=keys[i]))[0].tolist()
        assert got[i] == ref, f"request {i} diverged from generate"
        streamed = [tok for rid, tok in events if rid == i]
        assert streamed == ref, \
            f"step()'s emitted stream must carry request {i}'s full output"
    assert mon.pools.free_pages == mon.pools.n_logical


def test_batcher_retires_on_eos():
    """A sampled EOS retires the request early (pages released, row
    recycled), truncating exactly at the EOS token of the generate-
    equivalent stream."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    key = jax.random.PRNGKey(5)
    ref = np.asarray(generate(params, cfg, jnp.asarray(prompt)[None],
                              steps=8, key=key))[0].tolist()
    eos = ref[2]       # make the third greedy token the EOS

    mon = _tiny_serving_stack(cfg, params)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon, mirror_pages=True)
    b.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, key=key,
                     eos_id=eos))
    got = b.run()
    k = ref.index(eos) + 1
    assert got[0] == ref[:k], "EOS must truncate the generate stream"
    assert mon.pools.free_pages == mon.pools.n_logical, \
        "early retirement must release the pages"
    assert b.rows_free == list(range(b.max_active - 1, -1, -1)) or \
        sorted(b.rows_free) == list(range(b.max_active))


def test_batcher_paged_kernel_gathers_shared_pool():
    """kernels.paged_attention over the shared HBM pool (slot_of
    indirection through a request's page table) matches the host-pool
    reference for an in-flight request with interleaved allocations.  In
    fully-paged mode the host copy lives in the monitor slot's layered
    leaf (the pool IS the KV store)."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.kernels import ops
    from repro.models import model as mdl
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    mon = _tiny_serving_stack(cfg, params)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon)
    assert b.paged, "gemma3 (all-attention) must take the fully-paged path"
    for i in range(2):
        prompt = rng.integers(0, cfg.vocab_size, size=7 + i).astype(np.int32)
        b.submit(Request(rid=i, prompt=prompt, max_new_tokens=8,
                         key=jax.random.PRNGKey(i)))
    for _ in range(4):
        b.step()
    page = b.page_size
    li = mdl.attn_slot_index(cfg, b._si, b._sj)
    k_host = mon.pools.kv_layers["k_host"][li][-1]
    v_host = mon.pools.kv_layers["v_host"][li][-1]
    for req in list(b.active.values()):
        q = jax.random.normal(jax.random.PRNGKey(40 + req.rid),
                              (1, cfg.num_heads, cfg.head_dim))
        out, _ = b.paged_context(req.rid, q)
        length = int(np.asarray(b.pos)[req.row])
        n = -(-length // page)
        tbl = jnp.asarray(req.gids[:n], jnp.int32)[None]
        ref = ops.paged_attention(q, k_host, v_host,
                                  tbl, jnp.asarray([length], jnp.int32),
                                  impl="reference")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


def test_batcher_dense_and_paged_paths_token_identical():
    """The fully-paged decode (every layer off the shared slot pool) and
    the dense per-request-row path emit bit-identical token streams for
    the same request set -- the tentpole parity bar.  Includes a prompt
    with plen % window >= 2 (the window-ring case) and temperature
    sampling."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (10, 6, 9)]

    def run(paged):
        b = ContinuousBatcher(params, cfg, max_active=2, max_len=32,
                              page_size=4,
                              monitor=_tiny_serving_stack(cfg, params),
                              mirror_pages=not paged, paged=paged)
        assert b.paged == paged
        for i, p in enumerate(prompts):
            b.submit(Request(rid=i, prompt=p, max_new_tokens=5 + i,
                             key=jax.random.PRNGKey(20 + i),
                             temperature=0.0 if i == 0 else 0.8))
        return b.run()

    dense, paged = run(False), run(True)
    assert dense == paged, "dense and fully-paged decode must agree"


def test_paged_decode_multi_repeat_layer_order():
    """With repeats > 1 the paged decode must execute the whole pattern
    per repeat (matching decode_step's scan), not each slot across all
    its repeats -- pinned against per-request generate on a 2-repeat
    variant of the gemma3 pattern (stacked [R, ...] pool leaves driven
    through lax.scan)."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    cfg = dataclasses.replace(
        cfg, segments=tuple((pat, 2) for pat, _ in cfg.segments))
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (10, 6)]
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=_tiny_serving_stack(cfg, params))
    assert b.paged
    for i, p in enumerate(prompts):
        b.submit(Request(rid=i, prompt=p, max_new_tokens=6,
                         key=jax.random.PRNGKey(i), temperature=0.5 * i))
    got = b.run()
    for i, p in enumerate(prompts):
        ref = np.asarray(generate(params, cfg, jnp.asarray(p)[None], steps=6,
                                  temperature=0.5 * i,
                                  key=jax.random.PRNGKey(i)))[0].tolist()
        assert got[i] == ref, f"request {i} diverged with repeats=2"


def test_admission_prefills_in_one_packed_pass(monkeypatch):
    """Joiners of one scheduler step share a single batched prefill
    forward pass (no per-request prefill loop)."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve import sched as S

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    calls = {"batched": 0, "single": 0}
    orig_b, orig_1 = mdl.prefill_batched, mdl.prefill

    def count_b(*a, **k):
        calls["batched"] += 1
        return orig_b(*a, **k)

    def count_1(*a, **k):
        calls["single"] += 1
        return orig_1(*a, **k)

    monkeypatch.setattr(mdl, "prefill_batched", count_b)
    monkeypatch.setattr(mdl, "prefill", count_1)
    b = S.ContinuousBatcher(params, cfg, max_active=3, max_len=32,
                            page_size=4,
                            monitor=_tiny_serving_stack(cfg, params,
                                                        n_logical=64,
                                                        hbm=16))
    for i in range(3):
        prompt = rng.integers(0, cfg.vocab_size, size=5 + i).astype(np.int32)
        b.submit(S.Request(rid=i, prompt=prompt, max_new_tokens=3))
    b.step()
    assert len(b.active) + sum(r.done for r in b.completed) == 3
    assert calls == {"batched": 1, "single": 0}, \
        "three same-step joiners must share one packed prefill"


# ---------------------------------------------------------------------------
# shape-bucketed allocation (property tests)
# ---------------------------------------------------------------------------


def test_bucket_pages_rounding():
    from repro.memtier import bucket_pages
    assert [bucket_pages(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]
    assert bucket_pages(9, cap=10) == 10
    assert bucket_pages(10, cap=10) == 10
    with pytest.raises(ValueError):
        bucket_pages(0)
    with pytest.raises(ValueError):
        bucket_pages(11, cap=10)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_bucketed_allocation_never_exceeds_bucket_sum(seed):
    """Property: at every scheduler step, the pages held by the pool
    equal the sum of the in-flight requests' bucket-rounded footprints --
    never more -- and the peak never exceeds the bucket-rounded sum of
    any co-resident set."""
    from repro.memtier import bucket_pages
    specs = poisson_request_stream(
        60, 0.3, {"sink": 0.5, "random": 0.5}, prompt_len=(4, 90),
        new_tokens=(8, 70), seed=seed)
    pools = SharedPagedPools.create(256, 16)
    mgr = TieringManager(256, CFG)
    sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr),
                             page_size=16, max_active=6)
    cap = sched.row_pages
    for _ in range(300):
        sched.step()
        expect = sum(bucket_pages(a.pattern.shape[1], cap=max(cap,
                                                              a.pattern.shape[1]))
                     for a in sched.active)
        held = pools.n_logical - pools.free_pages
        assert held == expect == pools.allocated_pages
    assert sched.completed == sched.admitted
    assert pools.peak_allocated <= sum(
        bucket_pages(s.n_pages(16), cap=max(cap, s.n_pages(16)))
        for s in specs)


@pytest.mark.parametrize("seed", [0, 5])
def test_bucketed_retire_readmit_recycles_without_leak(seed):
    """Property: draining the stream returns every bucket-rounded page
    (allocated_pages == 0, free_pages == n_logical), and a second stream
    over the same pool admits cleanly from recycled IDs."""
    pools = SharedPagedPools.create(128, 16)
    mgr = TieringManager(128, CFG)
    mon = TrafficMonitor(pools, mgr)
    for round_ in range(2):
        specs = poisson_request_stream(
            40, 0.4, {"sink": 1.0}, prompt_len=(4, 60), new_tokens=(8, 40),
            seed=seed + round_)
        sched = TrafficScheduler(specs, mon, page_size=16, max_active=5)
        sched.run(400)
        assert sched.completed == sched.admitted == len(specs)
        assert pools.free_pages == pools.n_logical, "bucket pages leaked"
        assert pools.allocated_pages == 0


def test_macro_step_token_parity_with_per_token_paged():
    """Macro-step decode (one device launch per movement period, on-device
    sampling/EOS/length masking) emits bit-identical streams to the
    per-token paged loop AND per-request generate -- across staggered
    admission, temperature sampling and the window-ring prompt case."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (10, 6, 9)]          # 10 % window(8) == 2: ring case

    def run(macro):
        b = ContinuousBatcher(params, cfg, max_active=2, max_len=32,
                              page_size=4,
                              monitor=_tiny_serving_stack(cfg, params),
                              macro=macro)
        assert b.paged and b.macro == macro
        for i, p in enumerate(prompts[:2]):
            b.submit(Request(rid=i, prompt=p, max_new_tokens=5 + i,
                             key=jax.random.PRNGKey(30 + i),
                             temperature=0.0 if i == 0 else 0.8))
        out = {}
        for t in range(60):
            if t == 1:                      # staggered join
                b.submit(Request(rid=2, prompt=prompts[2],
                                 max_new_tokens=7,
                                 key=jax.random.PRNGKey(32),
                                 temperature=0.8))
            b.step()
            if not b.queue and not b.active:
                break
        return {r.rid: list(r.tokens) for r in b.completed}

    per_token, macro = run(False), run(True)
    assert per_token == macro, "macro-step diverged from per-token paged"
    for i, p in enumerate(prompts):
        steps = [5, 6, 7][i]
        temp = 0.0 if i == 0 else 0.8
        ref = np.asarray(generate(params, cfg, jnp.asarray(p)[None],
                                  steps=steps, temperature=temp,
                                  key=jax.random.PRNGKey(30 + i)))[0].tolist()
        assert macro[i] == ref, f"request {i} diverged from generate"


def test_macro_step_eos_retires_mid_macro():
    """A sampled EOS stops a row inside the macro launch: the emitted
    stream truncates exactly at the EOS token and the row's pages are
    released at the macro boundary."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    key = jax.random.PRNGKey(5)
    ref = np.asarray(generate(params, cfg, jnp.asarray(prompt)[None],
                              steps=8, key=key))[0].tolist()
    eos = ref[3]                 # stops inside the first macro launch

    mon = _tiny_serving_stack(cfg, params)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon, macro=True, macro_steps=8)
    b.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, key=key,
                     eos_id=eos))
    got = b.run()
    assert got[0] == ref[: ref.index(eos) + 1]
    assert mon.pools.free_pages == mon.pools.n_logical


def test_macro_step_merges_once_per_period(monkeypatch):
    """The host-side mass merge collapses to ONE call per movement period
    (vs one per token on the per-token path), and the monitor is fed
    through on_macro_step with a forced tier at the boundary."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    counts = {"merge": 0}

    mon = _tiny_serving_stack(cfg, params)
    orig = mon.merge

    def counting_merge(contribs):
        counts["merge"] += 1
        return orig(contribs)

    monkeypatch.setattr(mon, "merge", counting_merge)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon, macro=True, macro_steps=8)
    b.submit(Request(rid=0,
                     prompt=rng.integers(0, cfg.vocab_size, size=8)
                     .astype(np.int32), max_new_tokens=16))
    got = b.run()
    assert len(got[0]) == 16
    # 16 tokens = 1 prefill sample + 15 decode steps in ceil(15/8) = 2
    # macro launches -> 2 merges, not 15
    assert counts["merge"] == 2, counts
    assert mon.tuner.collector.num_samples > 0
    assert mon.manager.hits > 0


def test_collector_dt_records_gaps_in_token_steps():
    """Macro feeding (one observe per movement period, dt = macro length)
    must leave reuse gaps denominated in TOKEN steps -- the same unit the
    derived period is actuated in -- not in observe calls."""
    col = StreamingReuseCollector(4, bin_width=1)
    col.observe(np.array([1]), dt=8)
    col.observe(np.array([1]), dt=8)
    assert col.step == 16, "the clock advances by dt, not by calls"
    assert col.num_samples == 1
    assert col._gaps[-1][1] == 8, "gap == the macro span in tokens"


def test_tuner_dt_advances_windows_in_token_steps():
    """OnlineTuner windows (profile/trial) count token-steps under macro
    feeding: a 16-token profile completes after two 8-token macros."""
    tuner = OnlineTuner(8, profile_steps=16, trial_steps=4, bin_width=1)
    mass = np.zeros(8, np.float32)
    mass[2] = 1.0
    tuner.on_step(page_mass=mass, cost=8.0, dt=8)
    assert tuner.state == tuner.PROFILE
    tuner.on_step(page_mass=mass, cost=8.0, dt=8)
    assert tuner.state == tuner.TRIAL, \
        "16 token-steps profiled in 2 macro feeds must start trials"


def test_paged_attention_window_and_softcap_match_reference():
    """The Pallas kernel's sliding-window mask and tanh softcap (the
    local-layer path of fully-paged decode) match the jnp oracle."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    key = jax.random.PRNGKey(3)
    n, page, kvh, d, h = 8, 4, 2, 8, 4
    k = jax.random.normal(key, (n, page, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, page, kvh, d))
    q = jax.random.normal(jax.random.fold_in(key, 2), (2, h, d))
    tbl = jnp.asarray([[2, 0, 4, 6], [5, 1, -1, -1]], jnp.int32)
    lengths = jnp.asarray([4 * page - 1, 2 * page], jnp.int32)
    for window in (3, 8):
        for softcap in (0.0, 5.0):
            out = ops.paged_attention(q, k, v, tbl, lengths, window=window,
                                      softcap=softcap, impl="interpret")
            ref = ops.paged_attention(q, k, v, tbl, lengths, window=window,
                                      softcap=softcap, impl="reference")
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-5)
            assert not np.isnan(np.asarray(out)).any()


def test_paged_masses_reach_tuner_from_all_layers():
    """In fully-paged mode the reuse signal comes from the decode step
    itself (all attention layers, head-normalised): the tuner's collector
    must accumulate samples without engine.make_monitor ever running."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    mon = _tiny_serving_stack(cfg, params)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon)
    assert b.paged and b._mon_fn is None
    b.submit(Request(rid=0,
                     prompt=rng.integers(0, cfg.vocab_size, size=8)
                     .astype(np.int32), max_new_tokens=10))
    b.run()
    assert mon.tuner.collector.num_samples > 0, \
        "all-layer masses never reached the reuse collector"
    assert mon.manager.hits > 0


def test_relative_mass_threshold_is_occupancy_stable():
    """`OnlineTuner(rel_threshold=True)` cuts accessed sets at a fraction
    of the step's peak mass: scaling every mass down (more layers / more
    in-flight requests diluting the normalised signal) must not change
    which pages count as accessed, while the absolute cut loses them."""
    from repro.core import OnlineTuner, StreamingReuseCollector

    mass = np.zeros(16, np.float32)
    mass[[2, 5]] = [1.0, 0.4]
    for scale in (1.0, 0.01):
        rel = StreamingReuseCollector(16, bin_width=1)
        rel.observe_mass(mass * scale, 0.2, relative=True)
        rel.observe_mass(mass * scale, 0.2, relative=True)
        assert rel.num_samples == 2, f"relative cut drifted at x{scale}"
    absd = StreamingReuseCollector(16, bin_width=1)
    absd.observe_mass(mass * 0.01, 0.2)
    absd.observe_mass(mass * 0.01, 0.2)
    assert absd.num_samples == 0, "absolute cut should lose diluted masses"

    tuner = OnlineTuner(16, rel_threshold=True, access_threshold=0.2,
                        bin_width=1)
    tuner.on_step(page_mass=mass * 0.01, cost=1.0)
    tuner.on_step(page_mass=mass * 0.01, cost=1.0)
    assert tuner.collector.num_samples == 2


def test_layered_only_pool_rejects_legacy_mirror():
    """A pool with only layered leaves (no legacy k_host pair) is
    physical, but the dense write-through mirror must not engage on it --
    mirror_pages quietly stays off instead of crashing in write_page."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    pools = SharedPagedPools.create(48, 16)      # bare: no legacy arrays
    mgr = TieringManager(48, dataclasses.replace(CFG, page_size=4,
                                                 hbm_pages=16))
    mon = TrafficMonitor(pools, mgr)
    paged = ContinuousBatcher(params, cfg, max_active=1, max_len=32,
                              page_size=4, monitor=mon)
    assert paged.paged and pools.physical
    dense = ContinuousBatcher(params, cfg, max_active=1, max_len=32,
                              page_size=4, monitor=mon, mirror_pages=True,
                              paged=False)
    assert not dense.mirror_pages, "no legacy arrays: mirror must not arm"
    dense.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                         max_new_tokens=2))
    dense.run()          # would crash in write_page without the guard
    assert pools.free_pages == pools.n_logical


def test_paged_attention_tolerates_ragged_minus_one_padding():
    """Ragged multi-request page tables pad short rows with -1; the kernel
    wrapper clamps them (they are masked by lengths) instead of gathering
    out of bounds."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    n, page, kvh, d, h = 6, 4, 2, 8, 4
    k = jax.random.normal(key, (n, page, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, page, kvh, d))
    q = jax.random.normal(jax.random.fold_in(key, 2), (2, h, d))
    # row 0 uses 3 pages, row 1 only 1 -- padded with -1
    tbl = jnp.asarray([[2, 0, 4], [5, -1, -1]], jnp.int32)
    lengths = jnp.asarray([3 * page, page], jnp.int32)
    out = ops.paged_attention(q, k, v, tbl, lengths, impl="interpret")
    ref = ops.paged_attention(q, k, v, jnp.maximum(tbl, 0), lengths,
                              impl="reference")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    assert not np.isnan(np.asarray(out)).any()
