#!/usr/bin/env python
"""Docs link checker (CI): fail on broken intra-repo references.

Checks every markdown file under docs/ plus the repo-root markdown files
for:

  * relative markdown links ``[text](path)`` whose target file does not
    exist (external http(s)/mailto links are skipped, ``#fragment``-only
    links are skipped, a trailing ``#section`` is stripped before the
    existence check);
  * backticked code references that look like repo paths
    (``src/...``, ``docs/...``, ``benchmarks/...``, ``tests/...``,
    ``examples/...``, ``scripts/...``) and point at a missing file;
  * dotted module references like ``repro.serve.sched`` that no longer
    resolve to a module under ``src/``.

    python scripts/check_docs.py [root]
"""
from __future__ import annotations

import pathlib
import re
import sys

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH = re.compile(
    r"`((?:src|docs|benchmarks|tests|examples|scripts)/[A-Za-z0-9_./-]+)`")
MODULE_REF = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`")

#: Runtime-generated (gitignored) locations: docs may legitimately point
#: at benchmark outputs that do not exist in a fresh checkout.
GENERATED = ("benchmarks/out/",)


def md_files(root: pathlib.Path):
    yield from sorted(root.glob("*.md"))
    yield from sorted((root / "docs").glob("*.md"))


def module_ref_ok(root: pathlib.Path, dotted: str) -> bool:
    """True iff the dotted reference resolves under src/: either the full
    path is a package/module, or some prefix is a module *file* (the
    remaining segments are then attributes of it).  A prefix that is only
    a package directory does NOT rescue a missing submodule -- that is
    exactly the stale-rename case this check exists for."""
    parts = dotted.split(".")
    base = root / "src"
    for i in range(len(parts), 0, -1):
        prefix = base / pathlib.Path(*parts[:i])
        if prefix.with_suffix(".py").is_file():
            return True                      # rest are attributes
        if prefix.is_dir():
            if i == len(parts):
                return True                  # the package itself
            # something *inside* this package that is not a submodule:
            # accept only names the package __init__ actually re-exports
            init = prefix / "__init__.py"
            return init.is_file() and re.search(
                rf"\b{re.escape(parts[i])}\b", init.read_text()) is not None
    return False


def check(root: pathlib.Path) -> int:
    errors = []
    for md in md_files(root):
        text = md.read_text()
        for m in MD_LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(root)}: broken link "
                              f"-> {target}")
        for m in CODE_PATH.finditer(text):
            if m.group(1).startswith(GENERATED):
                continue
            path = m.group(1).rstrip("/")
            if not (root / path).exists():
                errors.append(f"{md.relative_to(root)}: missing path "
                              f"reference `{m.group(1)}`")
        for m in MODULE_REF.finditer(text):
            if not module_ref_ok(root, m.group(1)):
                errors.append(f"{md.relative_to(root)}: unresolvable module "
                              f"reference `{m.group(1)}`")
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"checked {len(list(md_files(root)))} markdown files: "
          f"{len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    root = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 \
        else pathlib.Path(__file__).resolve().parent.parent
    raise SystemExit(check(root))
