"""Cori-tuned KV-page tiering runtime (the paper's technique on TPU).

``replay`` drives a TieringManager over a per-step page-access workload
(real attention masses from ``repro.serve``'s monitor, or synthetic
patterns from ``workload``); ``cori_tune_period`` runs the full Cori loop
(profile -> DR -> candidate ladder -> trial windows) against it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core import cori
from repro.memtier.tiering import PagedPools, TierConfig, TieringManager

__all__ = ["PagedPools", "TierConfig", "TieringManager", "replay",
           "cori_tune_period", "resident_mask"]


def resident_mask(mgr: TieringManager, pools: Optional[PagedPools]):
    if pools is None:
        return np.zeros(mgr.n, bool)
    return pools.slot_of >= 0


def replay(page_mass_seq: np.ndarray, cfg: TierConfig,
           pools: Optional[PagedPools] = None) -> TieringManager:
    """Run the tiering loop over a [steps, n_logical] attention-mass
    sequence.  When `pools` is None, residency is tracked symbolically
    (no physical copies) -- used for fast period trials; the physical
    gather/scatter path is exercised by tests/serve."""
    steps, n = page_mass_seq.shape
    mgr = TieringManager(n, cfg)
    symbolic = pools is None
    resident = np.zeros(n, bool)
    if symbolic:
        # interleaved initial residency (paper SII-B)
        idx = (np.arange(cfg.hbm_pages) * n) // max(1, cfg.hbm_pages)
        resident[idx] = True
        slot_of = np.full(n, -1, np.int32)
        slot_of[idx] = np.arange(cfg.hbm_pages)
    for t in range(steps):
        if symbolic:
            mgr.on_step(page_mass_seq[t], resident)
            if (t + 1) % cfg.period_steps == 0:
                _symbolic_tier(mgr, resident)
        else:
            mgr.on_step(page_mass_seq[t], resident_mask(mgr, pools))
            pools = mgr.maybe_tier(pools)
    return mgr


def _symbolic_tier(mgr: TieringManager, resident: np.ndarray):
    cfg = mgr.cfg
    a = cfg.ema_alpha
    mgr.hotness = a * mgr.counts_since_tier + (1 - a) * mgr.hotness
    mgr.counts_since_tier[:] = 0.0
    score = (mgr.hotness * 1e6 + (mgr.last_access + 1) / (mgr.step + 1)
             + 0.5 * resident)
    desired = np.argsort(-score, kind="stable")[: cfg.hbm_pages]
    new_res = np.zeros(mgr.n, bool)
    new_res[desired] = True
    n_mig = int((new_res & ~resident).sum())
    mgr.migrations += n_mig
    mgr.data_moved_pages += 2 * n_mig
    mgr.modeled_time += n_mig * cfg.mig_cost + cfg.wakeup_cost
    resident[:] = new_res


def cori_tune_period(page_mass_seq: np.ndarray, cfg: TierConfig,
                     patience: int = 2,
                     max_trials: Optional[int] = None):
    """Full Cori loop over the tiering runtime.

    1. Reuse Collector: one profiling window (tiering at the default
       period) collects the access log.
    2. Frequency Generator: DR + candidate ladder in the step domain.
    3. Tuner: trial windows at each candidate period, stop on
       no-improvement.

    Returns (TuneResult, dominant_reuse)."""
    profile = replay(page_mass_seq, cfg)
    cands = profile.cori_candidates(horizon_steps=page_mass_seq.shape[0])

    def evaluate(period: float) -> float:
        p = max(1, int(round(period)))
        mgr = replay(page_mass_seq,
                     dataclasses.replace(cfg, period_steps=p))
        return mgr.modeled_time

    tuner = cori.Tuner(evaluate, patience=patience, max_trials=max_trials)
    hist = profile.reuse_histogram()
    return tuner.run(cands), cori.dominant_reuse(hist)


class AdaptiveTuner:
    """Online re-tuning (the paper's SIV-D extension): monitor the working
    set's hit rate; when it drifts below ``retune_ratio`` x the rate
    observed right after tuning, the access pattern has changed -- rerun
    the Cori loop (profile window -> DR -> ladder -> trials) on the recent
    window.  Static Cori tunes once; this closes the loop for phase-changing
    workloads (e.g. a serving mix shifting from RAG loops to random
    retrieval)."""

    def __init__(self, cfg: TierConfig, window: int = 64,
                 retune_ratio: float = 0.7):
        self.cfg = cfg
        self.window = window
        self.retune_ratio = retune_ratio
        self.period = cfg.period_steps
        self.baseline_hit = None
        self.retunes = 0
        self._buf = []

    def _hitrate(self, masses: "np.ndarray") -> float:
        import dataclasses as _dc
        mgr = replay(masses, _dc.replace(self.cfg, period_steps=self.period))
        return mgr.hits / max(mgr.hits + mgr.misses, 1)

    def observe(self, page_mass) -> int:
        """Feed one decode step's page masses; returns the current period."""
        import dataclasses as _dc
        self._buf.append(page_mass)
        if len(self._buf) >= self.window:
            import numpy as _np
            masses = _np.stack(self._buf)
            self._buf = []
            hit = self._hitrate(masses)
            if self.baseline_hit is None:
                self.baseline_hit = hit
            elif hit < self.retune_ratio * self.baseline_hit:
                res, _dr = cori_tune_period(
                    masses, _dc.replace(self.cfg, period_steps=self.period))
                self.period = max(1, int(round(res.chosen_period)))
                self.baseline_hit = self._hitrate(masses)
                self.retunes += 1
        return self.period
