"""The flight recorder: typed ring-buffer events + counters/gauges/histograms.

One process-global ``Recorder`` (module attribute ``RECORDER``; swap it
with ``install``) collects everything the instrumented stack emits:

  * **Events** -- typed records (``emit``) appended to a bounded ring
    buffer: O(1) append, fixed memory, oldest events overwritten (the
    flight-recorder property: the tail of history is always available,
    however long the run).  Every type must be registered in
    ``repro.obs.events.EVENTS`` -- the taxonomy CI keeps in lockstep with
    ``docs/observability.md``.
  * **Counters** -- monotonically accumulated floats (``count``).
  * **Gauges** -- last-value floats (``gauge``).
  * **Histograms** -- streaming fixed-geometric-bucket quantile sketches
    (``observe``): bounded memory, ~9% relative quantile error
    (``ratio = 2**0.25`` buckets), exact count/sum/min/max.

Hot-path contract: instrumented code guards every emission with
``if (r := RECORDER).enabled:`` so a disabled recorder costs one
attribute load and one branch -- no kwargs dict, no event record, zero
allocations.  ``emit`` itself also checks, so un-guarded call sites are
merely slower, never wrong.

The recorder is multi-writer: the pipelined serving loop emits from both
the dispatch thread and the background decision worker, so every mutation
(``emit``/``count``/``gauge``/``observe``) takes one shared lock.  The
lock is uncontended in the common case (a handful of emissions per macro
boundary) and sits behind the ``enabled`` fast-path check, so the
disabled cost is still one attribute load and one branch.  Exporters read
snapshots (``events()``/``summary()``), so a reader racing a writer sees
a consistent prefix at worst.
"""
from __future__ import annotations

import math
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.events import EVENTS

__all__ = ["Histogram", "Recorder", "RECORDER", "install", "get"]


class Histogram:
    """Streaming quantiles over fixed geometric buckets.

    Bucket ``i`` spans ``[lo * ratio**i, lo * ratio**(i+1))``; quantiles
    interpolate linearly inside the crossing bucket, so the relative
    error is bounded by ``ratio - 1`` (~9% at the default quarter-octave
    buckets).  Non-positive observations land in bucket 0, non-finite
    ones in the overflow bucket; count/sum/min/max are exact over finite
    observations."""

    __slots__ = ("lo", "ratio", "counts", "count", "total", "vmin", "vmax",
                 "nonfinite", "_inv_log_ratio", "_log_lo")

    def __init__(self, lo: float = 1e-9, ratio: float = 2.0 ** 0.25,
                 n_buckets: int = 256):
        self.lo = float(lo)
        self.ratio = float(ratio)
        self.counts = np.zeros(n_buckets + 1, np.int64)  # [+overflow]
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.nonfinite = 0
        self._inv_log_ratio = 1.0 / math.log(self.ratio)
        self._log_lo = math.log(self.lo)

    def observe(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            self.nonfinite += 1
            self.counts[-1] += 1
            return
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= self.lo:
            i = 0
        else:
            i = int((math.log(v) - self._log_lo) * self._inv_log_ratio)
            if i >= self.counts.shape[0] - 1:
                i = self.counts.shape[0] - 2
        self.counts[i] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (clamped to the exact min/max)."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts[:-1]):
            if c == 0:
                continue
            if cum + c >= target:
                frac = (target - cum) / c
                b_lo = self.lo * self.ratio ** i
                b_hi = b_lo * self.ratio
                est = b_lo + frac * (b_hi - b_lo)
                return float(min(max(est, self.vmin), self.vmax))
            cum += c
        return float(self.vmax)

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "nonfinite": self.nonfinite}
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin, "max": self.vmax,
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99), "sum": self.total,
                "nonfinite": self.nonfinite}


class Recorder:
    """Process-global flight recorder (see module docstring).

    ``capacity`` bounds the event ring; ``dropped`` counts overwritten
    events so a truncated log is detectable.  ``enabled`` is a plain
    attribute: flip it to pause/resume recording (hot paths re-read it
    per emission)."""

    def __init__(self, capacity: int = 65536, enabled: Optional[bool] = None):
        if capacity <= 0:
            raise ValueError(f"ring capacity must be positive: {capacity}")
        self.capacity = int(capacity)
        self.enabled = (os.environ.get("REPRO_OBS", "1") != "0"
                        if enabled is None else bool(enabled))
        self._ring: List[Optional[Tuple[int, float, str, dict]]] = \
            [None] * self.capacity
        self._seq = 0
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Histogram] = {}
        self._t0 = time.monotonic()
        # serialises writers: the pipelined serving loop emits from the
        # dispatch thread AND the background decision worker
        self._lock = threading.Lock()

    # -- events --------------------------------------------------------------
    def emit(self, etype: str, **fields: Any) -> None:
        """Append one typed event (no-op when disabled).  ``etype`` must
        be registered in ``repro.obs.events.EVENTS``."""
        if not self.enabled:
            return
        if etype not in EVENTS:
            raise KeyError(f"unregistered event type {etype!r}: add it to "
                           "repro.obs.events.EVENTS (and the docs taxonomy)")
        with self._lock:
            seq = self._seq
            self._ring[seq % self.capacity] = (
                seq, time.monotonic() - self._t0, etype, fields)
            self._seq = seq + 1

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wrap-around."""
        return max(0, self._seq - self.capacity)

    def events(self, etype: Optional[str] = None,
               prefix: Optional[str] = None) -> List[Dict[str, Any]]:
        """Snapshot the ring in emission order as flat dicts
        (``seq``/``t``/``type`` envelope + the event's fields)."""
        n = min(self._seq, self.capacity)
        start = self._seq - n
        out = []
        for s in range(start, self._seq):
            rec = self._ring[s % self.capacity]
            if rec is None:
                continue
            seq, t, typ, fields = rec
            if etype is not None and typ != etype:
                continue
            if prefix is not None and not typ.startswith(prefix):
                continue
            out.append({"seq": seq, "t": t, "type": typ, **fields})
        return out

    # -- metrics -------------------------------------------------------------
    def count(self, name: str, delta: float = 1.0) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + delta

    def gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = Histogram()
            h.observe(value)

    def summary(self) -> Dict[str, Any]:
        """Counters, gauges and histogram summaries as one JSON-ready
        dict (the ``metrics.summary`` record of the JSONL export; the
        benchmark JSON schema embeds it verbatim)."""
        return {
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "hists": {k: self.hists[k].summary()
                      for k in sorted(self.hists)},
            "events_recorded": self._seq,
            "events_dropped": self.dropped,
        }

    def clear(self) -> None:
        """Drop all events and metrics (the ring keeps its capacity)."""
        with self._lock:
            self._ring = [None] * self.capacity
            self._seq = 0
            self.counters.clear()
            self.gauges.clear()
            self.hists.clear()
            self._t0 = time.monotonic()


#: The process-global recorder every instrumented module reads through
#: module-attribute access (``telemetry.RECORDER``), so ``install`` swaps
#: it everywhere at once.  ``REPRO_OBS=0`` disables recording at import.
RECORDER = Recorder()


def install(recorder: Recorder) -> Recorder:
    """Replace the process-global recorder (tests/benchmarks isolate
    their event streams with a fresh one); returns it."""
    global RECORDER
    RECORDER = recorder
    return recorder


def get() -> Recorder:
    return RECORDER
