"""Continuous-batching serving scheduler over one shared KV page pool.

The paper's tuner wants the *aggregate* workload, not one request: this
module is the layer that owns a shared hybrid-memory pool across many
in-flight requests and feeds online Cori from the merged traffic.

  * ``ContinuousBatcher`` -- the model-backed scheduler: requests join the
    running batch between decode steps (admission is per-step, and each
    request's KV occupies whole pages of the shared pool, so joins are
    page-aligned by construction), decode runs over the whole request
    set, and requests retire on EOS or length, returning their pages.
  * ``TrafficScheduler`` -- the model-free twin for traffic simulation:
    each request is a synthetic per-step page-mass pattern
    (``repro.memtier.workload``), so thousands of scheduler steps replay
    without touching KV bytes.  Same admission, allocation, merge and
    retirement path.
  * ``TrafficMonitor`` -- the traffic-level monitor: merges per-request
    page masses into the global logical-page ID space and drives ONE
    ``TieringManager`` (+ optional ``OnlineTuner``) for the whole mix.

Global page IDs are allocated by ``memtier.SharedPagedPools``; a retiring
request's IDs are released everywhere (pool slots, manager hotness, the
tuner's reuse collector) so a recycled ID starts cold.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cori
from repro.core.traffic import RequestSpec
from repro.kernels import ops
from repro.memtier import workload as W
from repro.memtier.tiering import SharedPagedPools, TieringManager
from repro.models import model as mdl
from repro.serve import engine as E

__all__ = ["Request", "TrafficMonitor", "ContinuousBatcher",
           "TrafficScheduler", "WORKLOAD_KINDS"]


# ---------------------------------------------------------------------------
# traffic-level monitor: merged masses -> one manager/tuner
# ---------------------------------------------------------------------------


class TrafficMonitor:
    """Merges per-request page masses into the global page-ID space and
    feeds one ``TieringManager`` + optional ``OnlineTuner`` for the whole
    traffic mix -- the aggregation point between the scheduler and Cori."""

    def __init__(self, pools: SharedPagedPools, manager: TieringManager,
                 tuner: Optional[cori.OnlineTuner] = None):
        if manager.n != pools.n_logical:
            raise ValueError("manager and pools disagree on the logical "
                             f"page space ({manager.n} vs {pools.n_logical})")
        self.pools = pools
        self.manager = manager
        self.tuner = tuner

    def merge(self, contributions: Sequence[Tuple[np.ndarray, np.ndarray]]
              ) -> np.ndarray:
        """Scatter per-request (gids, local_mass) rows into one global
        f32[n_logical] mass vector (max-merge: a page is as hot as its
        hottest accessor, matching the engine's batch reduction)."""
        mass = np.zeros(self.pools.n_logical, np.float32)
        for gids, local in contributions:
            np.maximum.at(mass, np.asarray(gids, np.int64),
                          np.asarray(local, np.float32)[: len(gids)])
        return mass

    def on_step(self, global_mass: np.ndarray,
                n_active: Optional[int] = None) -> int:
        """Feed one scheduler step's merged masses: accounting, periodic
        tiering over the shared pool, and the closed tuning loop.  Returns
        the tiering period now in force.

        With ``n_active`` the tuner is fed the *per-request* step cost.
        Aggregate cost scales with however many requests happen to be in
        flight, so a burst of arrivals (or a drain of retirements) looks
        exactly like workload drift and makes the tuner churn through
        re-profiles on a perfectly stable mix; per-request cost is the
        load-invariant serving metric the drift detector should watch."""
        mgr = self.manager
        before = mgr.modeled_time
        mgr.on_step(global_mass, self.pools.resident_mask)
        mgr.maybe_tier(self.pools, active=self.pools.allocated_mask)
        if self.tuner is not None:
            cost = mgr.modeled_time - before
            if n_active is not None:
                cost /= max(1, n_active)
            mgr.set_period(self.tuner.on_step(global_mass, cost=cost))
        return mgr.period

    def release(self, gids: np.ndarray) -> None:
        """Retire a request's pages everywhere: pool slots freed, manager
        hotness cleared, reuse-collector entries invalidated (a recycled
        global ID must not inherit the old owner's reuse chain)."""
        self.manager.release(gids)
        if self.tuner is not None:
            self.tuner.forget_pages(gids)
        self.pools.free(gids)


# ---------------------------------------------------------------------------
# model-backed continuous batcher
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One serving request and its in-flight state."""

    rid: int
    prompt: np.ndarray                 # int32[plen]
    max_new_tokens: int
    eos_id: Optional[int] = None
    temperature: float = 0.0
    key: Optional[jax.Array] = None    # defaults to PRNGKey(0), as generate()
    # -- runtime state (owned by the batcher) --
    row: int = -1
    gids: Optional[np.ndarray] = None
    n_pages: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    _key: Optional[jax.Array] = None
    _i: int = 0                        # decode iterations done

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.max_new_tokens


class ContinuousBatcher:
    """Continuous batching: a fixed-capacity request-set decoded together.

    ``max_active`` rows share one packed cache of ``max_len`` positions;
    requests are admitted into free rows between decode steps (their KV
    pages allocated from the shared pool at page-aligned positions) and
    retired on EOS or length (pages released).  Per-request sampling keys
    follow exactly ``engine.generate``'s schedule, so a request's token
    stream is identical to running ``generate`` alone with the same
    prompt/key -- the property the traffic benchmark pins down.

    With a ``TrafficMonitor``, each step recomputes the monitor layer's
    per-request page masses (``engine.make_monitor``), merges them into
    the global page-ID space, and lets the manager/tuner tier the shared
    pool; with ``mirror_pages=True`` (physical pools) the monitor layer's
    KV pages are write-through mirrored so ``kernels.paged_attention``
    can gather a request's context straight from the shared HBM pool
    (``paged_context``).
    """

    def __init__(self, params, cfg, *, max_active: int = 4,
                 max_len: int = 128, page_size: int = 16,
                 monitor: Optional[TrafficMonitor] = None,
                 mirror_pages: bool = False):
        self.params, self.cfg = params, cfg
        self.page_size = page_size
        self.max_len = -(-max_len // page_size) * page_size
        self.max_active = max_active
        self.prefix = cfg.prefix_len or 0
        self.monitor = monitor
        self.mirror_pages = mirror_pages and monitor is not None \
            and monitor.pools.physical
        self.n_row_pages = self.max_len // page_size

        # prefill produces float32 caches on this substrate; the packed
        # cache must match or row writes would silently downcast
        self.cache = mdl.init_cache(cfg, max_active, self.max_len,
                                    dtype=jnp.float32)
        self.tok = jnp.zeros((max_active, 1), jnp.int32)
        self.pos = jnp.zeros((max_active,), jnp.int32)
        self.rows_free = list(range(max_active - 1, -1, -1))
        self.active: Dict[int, Request] = {}
        self.queue: "collections.deque[Request]" = collections.deque()
        self.step_idx = 0
        self.completed: List[Request] = []

        self._step_fn = jax.jit(
            lambda c, t, p: mdl.decode_step(params, cfg, c, t, p))
        self._mon_fn = (E.make_monitor(params, cfg, page_size,
                                       self.n_row_pages)
                        if monitor is not None else None)
        if self.monitor is not None:
            self._si, self._sj = E.monitor_slot(cfg)

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if self.prefix + req.total_len > self.max_len:
            raise ValueError(f"request {req.rid} needs "
                             f"{self.prefix + req.total_len} positions, "
                             f"cache rows hold {self.max_len}")
        if self.monitor is not None:
            n_pages = -(-(self.prefix + req.total_len) // self.page_size)
            if n_pages > self.monitor.pools.n_logical:
                # would head-of-line-block the queue forever: alloc can
                # never succeed, not even with the pool fully drained
                raise ValueError(
                    f"request {req.rid} needs {n_pages} pages, the logical "
                    f"space holds {self.monitor.pools.n_logical}")
        self.queue.append(req)

    def _admit(self) -> List[Tuple[int, int]]:
        emitted: List[Tuple[int, int]] = []
        while self.queue and self.rows_free:
            req = self.queue[0]
            n_pages = -(-(self.prefix + req.total_len) // self.page_size)
            gids = None
            if self.monitor is not None:
                gids = self.monitor.pools.alloc(n_pages, req.rid)
                if gids is None:       # head-of-line: keep arrival order
                    return emitted
            self.queue.popleft()
            row = self.rows_free.pop()
            req.row, req.gids, req.n_pages = row, gids, n_pages

            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, cache1 = mdl.prefill(self.params, self.cfg, prompt)
            cache1 = mdl.pad_cache(cache1, self.cfg, self.max_len)
            self.cache = jax.tree.map(
                lambda full, one: full.at[:, row].set(one[:, 0]),
                self.cache, cache1)
            req._key = req.key if req.key is not None else jax.random.PRNGKey(0)
            tok = E._sample(logits[:, 0], req._key, req.temperature)
            req.tokens.append(int(tok[0]))
            emitted.append((req.rid, int(tok[0])))
            self.tok = self.tok.at[row].set(tok)
            self.pos = self.pos.at[row].set(self.prefix + len(req.prompt))
            self.active[row] = req
            if self.mirror_pages:
                plen = self.prefix + len(req.prompt)
                self._mirror(req, range(-(-plen // self.page_size)))
            if req.max_new_tokens <= 1 or (req.eos_id is not None
                                           and req.tokens[-1] == req.eos_id):
                self._retire(req)
        return emitted

    # -- the per-step scheduler loop -----------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """One scheduler step: admit, monitor+tier, decode the request set,
        sample, retire.  Returns the (rid, token) pairs emitted this step,
        including the prefill-sampled first token of newly admitted
        requests."""
        emitted = self._admit()
        self.step_idx += 1
        if not self.active:
            return emitted
        if self.monitor is not None:
            masses = np.asarray(self._mon_fn(self.cache, self.tok, self.pos))
            merged = self.monitor.merge(
                [(r.gids[: r.n_pages], masses[r.row, : r.n_pages])
                 for r in self.active.values()])
            self.monitor.on_step(merged, n_active=len(self.active))

        pos_before = np.asarray(self.pos)
        logits, self.cache = self._step_fn(self.cache, self.tok, self.pos)
        self.pos = self.pos + 1
        new_tok = self.tok
        for row, req in list(self.active.items()):
            req._key = jax.random.fold_in(req._key, req._i)
            req._i += 1
            tok = E._sample(logits[row: row + 1, 0], req._key,
                            req.temperature)
            req.tokens.append(int(tok[0]))
            new_tok = new_tok.at[row].set(tok)
            emitted.append((req.rid, int(tok[0])))
            if self.mirror_pages:
                self._mirror(req, [int(pos_before[row]) // self.page_size])
            if (len(req.tokens) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and req.tokens[-1] == req.eos_id)):
                self._retire(req)
        self.tok = new_tok
        return emitted

    def run(self, max_steps: int = 10 ** 6) -> Dict[int, List[int]]:
        """Drive until every submitted request completed (or the step
        budget runs out).  Returns rid -> emitted tokens."""
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return {r.rid: list(r.tokens) for r in self.completed}

    def _retire(self, req: Request) -> None:
        req.done = True
        del self.active[req.row]
        self.rows_free.append(req.row)
        self.completed.append(req)
        if self.monitor is not None:
            self.monitor.release(req.gids)

    # -- shared-pool data path -----------------------------------------------
    def _mirror(self, req: Request, pages) -> None:
        """Write-through the monitor layer's KV pages of one request from
        the packed cache into the shared pools (host + resident slots)."""
        c = self.cache["segments"][self._si][self._sj]
        ps = self.page_size
        for p in pages:
            if 0 <= p < req.n_pages:
                # slice on device: only the touched page crosses to host
                k = c["k"][-1, req.row, p * ps: (p + 1) * ps]
                v = c["v"][-1, req.row, p * ps: (p + 1) * ps]
                self.monitor.pools.write_page(int(req.gids[p]), k, v)

    def paged_context(self, rid: int, q, *, impl: str = "interpret"):
        """Monitor-layer attention context for one in-flight request,
        gathered by ``kernels.paged_attention`` *from the shared HBM pool*
        through the request's page table (``slot_of`` indirection).  Pages
        are demand-fetched first; returns (context [1,H,D], fetched)."""
        if not self.mirror_pages:
            raise ValueError("paged_context needs mirror_pages=True over "
                             "physical pools: without the write-through "
                             "mirror the shared pool holds no KV data")
        req = next((r for r in self.active.values() if r.rid == rid), None)
        if req is None:
            raise KeyError(f"request {rid} is not in flight")
        length = int(np.asarray(self.pos)[req.row])
        n = -(-length // self.page_size)
        gids = req.gids[:n]
        fetched = self.monitor.pools.ensure_resident(gids)
        # demand-fetched pages are on-demand host reads: charge them
        mgr = self.monitor.manager
        mgr.misses += fetched
        mgr.modeled_time += fetched * mgr.cfg.miss_penalty
        table = jnp.asarray(self.monitor.pools.table(gids), jnp.int32)[None]
        lengths = jnp.asarray([length], jnp.int32)
        out = ops.paged_attention(q, self.monitor.pools.k_hbm,
                                  self.monitor.pools.v_hbm, table, lengths,
                                  impl=impl)
        return out, fetched


# ---------------------------------------------------------------------------
# model-free traffic simulation (same scheduling core, synthetic masses)
# ---------------------------------------------------------------------------


def _sink_pattern(spec: RequestSpec, n_pages: int) -> np.ndarray:
    return W.attention_sink(spec.new_tokens, n_pages,
                            sink_pages=min(2, n_pages),
                            window_pages=min(4, n_pages),
                            seed=spec.seed, drift_every=1)


def _periodic_pattern(spec: RequestSpec, n_pages: int) -> np.ndarray:
    span = max(1, min(8, n_pages - n_pages // 4))
    return W.periodic_context(spec.new_tokens, n_pages, span_pages=span,
                              period=16, seed=spec.seed)


def _random_pattern(spec: RequestSpec, n_pages: int) -> np.ndarray:
    return W.random_lookup(spec.new_tokens, n_pages,
                           touches=min(3, n_pages), seed=spec.seed)


WORKLOAD_KINDS: Dict[str, Callable[[RequestSpec, int], np.ndarray]] = {
    "sink": _sink_pattern,
    "periodic": _periodic_pattern,
    "random": _random_pattern,
}


@dataclasses.dataclass
class _SynthActive:
    spec: RequestSpec
    gids: np.ndarray
    pattern: np.ndarray                # [lifetime, n_pages]
    t: int = 0


class TrafficScheduler:
    """Model-free continuous batching over a ``core.traffic`` request
    stream: admission (Poisson arrivals, FIFO head-of-line), page-aligned
    allocation from the shared pool, per-step mass merge through the
    ``TrafficMonitor``, retirement on length.  Deterministic given the
    stream -- and admission never depends on residency or period, so
    fixed-period replays of the same stream are directly comparable (the
    brute-force sweep the benchmark ranks the online tuner against)."""

    def __init__(self, specs: Sequence[RequestSpec], monitor: TrafficMonitor,
                 *, page_size: int = 16, max_active: int = 8,
                 kinds: Optional[Dict[str, Callable]] = None):
        self.pending = collections.deque(
            sorted(specs, key=lambda s: (s.arrival, s.rid)))
        self.monitor = monitor
        self.page_size = page_size
        self.max_active = max_active
        self.kinds = dict(WORKLOAD_KINDS)
        if kinds:
            self.kinds.update(kinds)
        self.active: List[_SynthActive] = []
        self.now = 0
        self.admitted = 0
        self.completed = 0
        self.rejected = 0

    def step(self) -> None:
        while (self.pending and self.pending[0].arrival <= self.now
               and len(self.active) < self.max_active):
            spec = self.pending[0]
            n_pages = spec.n_pages(self.page_size)
            if n_pages > self.monitor.pools.n_logical:
                # can never fit, not even fully drained: dropping it is the
                # only alternative to blocking the queue forever
                self.pending.popleft()
                self.rejected += 1
                continue
            gids = self.monitor.pools.alloc(n_pages, spec.rid)
            if gids is None:           # head-of-line: keep arrival order
                break
            self.pending.popleft()
            pattern = self.kinds[spec.kind](spec, n_pages)
            self.admitted += 1
            if pattern.shape[0] == 0:      # zero-lifetime: retire at once
                self.monitor.release(gids)
                self.completed += 1
                continue
            self.active.append(_SynthActive(spec, gids, pattern))

        # idle steps are not fed to the monitor (matching the model-backed
        # batcher): an empty lull's near-zero cost would read as a phase
        # change and churn the tuner through spurious re-profiles
        if self.active:
            merged = self.monitor.merge(
                [(a.gids, a.pattern[a.t]) for a in self.active])
            self.monitor.on_step(merged, n_active=len(self.active))
        self.now += 1

        still: List[_SynthActive] = []
        for a in self.active:
            a.t += 1
            if a.t >= a.pattern.shape[0]:
                self.monitor.release(a.gids)
                self.completed += 1
            else:
                still.append(a)
        self.active = still

    def run(self, steps: int) -> "TrafficScheduler":
        for _ in range(steps):
            self.step()
        return self
