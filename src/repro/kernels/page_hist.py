"""Pallas TPU kernel: fused per-period page-access histogram + EMA hotness.

This is the page scheduler's monitor step (paper SII-A: scan accessed bits,
EMA-smooth, classify hot/cold) -- the hottest loop of both the simulator and
the KV-tiering runtime, fused into one pass.

Layout: the access slice (one period, P ids) is small and replicated into
VMEM; the page state (num_pages-wide hotness) is tiled over the grid.  Each
grid step owns a PAGE_TILE-wide slab of pages and counts matches against the
whole slice with a vectorised compare (VPU work, no gather/scatter -- TPUs
hate scatters; a [TILE, P] compare matrix is the TPU-native formulation of a
histogram).

  counts[p]  = sum_i (ids[i] == p)
  hotness'   = alpha * counts + (1 - alpha) * hotness
  hot[p]     = hotness' >= threshold
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAGE_TILE = 512


def _kernel(ids_ref, hot_ref, counts_ref, new_hot_ref, mask_ref, *,
            alpha: float, threshold: float, tile: int):
    t = pl.program_id(0)
    base = t * tile
    ids = ids_ref[...]                        # [P] int32 (whole slice)
    page_ids = base + jax.lax.iota(jnp.int32, tile)
    # [TILE, P] compare matrix -> per-page counts
    eq = (ids[None, :] == page_ids[:, None]).astype(jnp.float32)
    counts = jnp.sum(eq, axis=1)
    hot = hot_ref[...]
    new_hot = alpha * counts + (1.0 - alpha) * hot
    counts_ref[...] = counts
    new_hot_ref[...] = new_hot
    mask_ref[...] = (new_hot >= threshold)


def page_hist(ids: jnp.ndarray, hotness: jnp.ndarray, *, alpha: float = 0.5,
              threshold: float = 1.0, tile: int = PAGE_TILE,
              interpret: bool = False):
    """ids: int32[P] page ids of one period (pad with -1); hotness:
    f32[num_pages].  Returns (counts, new_hotness, hot_mask).

    ``num_pages`` need not be a tile multiple: the page state is zero-padded
    to the grid and the outputs sliced back (padding pages can never match a
    real id, so the extra lanes stay zero)."""
    num_pages = hotness.shape[0]
    padded = -(-num_pages // tile) * tile
    if padded != num_pages:
        c, h, m = page_hist(ids, jnp.pad(hotness, (0, padded - num_pages)),
                            alpha=alpha, threshold=threshold, tile=tile,
                            interpret=interpret)
        return c[:num_pages], h[:num_pages], m[:num_pages]
    grid = (num_pages // tile,)
    kernel = functools.partial(_kernel, alpha=alpha, threshold=threshold,
                               tile=tile)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(ids.shape, lambda t: (0,)),          # replicated
            pl.BlockSpec((tile,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_pages,), jnp.float32),
            jax.ShapeDtypeStruct((num_pages,), jnp.float32),
            jax.ShapeDtypeStruct((num_pages,), jnp.bool_),
        ],
        interpret=interpret,
    )(ids, hotness)
