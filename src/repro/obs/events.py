"""Event taxonomy: the closed registry of flight-recorder event types.

Every ``Recorder.emit`` call site in ``src/`` must name a type registered
here, and every registered type must appear in the taxonomy table of
``docs/observability.md`` -- both directions are enforced by
``scripts/check_events.py`` in CI, so instrumentation and docs cannot
drift apart.  ``Recorder.emit`` itself rejects unregistered types at
runtime.

This module is deliberately stdlib-only (no numpy/jax): the CI docs job
loads it standalone to cross-check the docs table without installing the
runtime dependencies.

Field-name contract: ``seq``, ``t`` and ``type`` are reserved (the
envelope the Recorder wraps every event in); event fields must not reuse
them so the JSONL export can stay flat.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

__all__ = ["Event", "EVENTS", "RESERVED_FIELDS"]

RESERVED_FIELDS = ("seq", "t", "type")


class Event(NamedTuple):
    """One registered event type: its field names and what it records."""
    name: str
    domain: str                 # tuner | tier | pool | serve | ft | meta
    fields: Tuple[str, ...]
    description: str


def _ev(name: str, fields: Tuple[str, ...], description: str) -> Event:
    domain = name.split(".", 1)[0]
    for f in fields:
        if f in RESERVED_FIELDS:
            raise ValueError(f"{name}: field {f!r} shadows the envelope")
    return Event(name, domain, fields, description)


_ALL = [
    # -- tuner: the OnlineTuner decision path (step domain) ------------------
    _ev("tuner.transition",
        ("tuner", "step", "frm", "to", "reason", "period", "detail"),
        "OnlineTuner state change (PROFILE/TRIAL/HOLD) with the decision "
        "reason -- profile-complete, sweep-complete, warm-/cold- re-tune "
        "cause, guard abort/escalation"),
    _ev("tuner.period",
        ("tuner", "step", "period", "prev"),
        "the live tiering period changed (trial candidate switch, sweep "
        "winner adoption, guard revert)"),
    _ev("tuner.trial",
        ("tuner", "step", "period", "cost", "best_period", "best_cost",
         "stale", "improved"),
        "one TRIAL candidate finished: tail-mean per-step cost and its "
        "effect on the sweep ranking"),
    _ev("tuner.guard",
        ("tuner", "step", "where", "verdict", "cv", "ref", "cost"),
        "cost-spike guardrail trip: TRIAL burst-vs-regime verdict, or a "
        "discarded guard-level HOLD window"),
    _ev("tuner.extend",
        ("tuner", "step", "cv", "win_target"),
        "variance-scaled trial window doubled (tail bucket CV above "
        "var_cv); the tail restarts"),
    _ev("tuner.baseline",
        ("tuner", "step", "cost", "floored"),
        "HOLD baseline (re-)attested from a clean window; floored=True "
        "when the sweep winner's trial cost raised it"),
    _ev("tuner.hold_window",
        ("tuner", "step", "kind", "cost", "baseline", "strikes"),
        "one HOLD measurement window closed: skip-transient, "
        "discard-guard, drift-strike, improve-strike or ok"),
    _ev("tuner.profile_extend",
        ("tuner", "step"),
        "PROFILE window elapsed with an empty reuse histogram; profiling "
        "continues for another window"),
    # -- tiering: the page scheduler (step domain) ---------------------------
    _ev("tier.move",
        ("manager", "step", "period", "promoted", "evicted", "pages_moved",
         "cost"),
        "one tiering boundary: pages promoted into HBM, lazily evicted, "
        "total pages of data moved (promotions x the geometry's leaf "
        "planes: k+v, ckv+krope, state) and the modeled "
        "migration+wakeup cost"),
    _ev("tier.move_failed",
        ("manager", "step", "pages", "attempts", "detail"),
        "a planned promotion's migrate_slots failed after bounded "
        "retries: the slot bookkeeping is rolled back, the pages stay "
        "host-resident (demand-fetched later) and the failure is priced "
        "into the tuner's window"),
    # -- pool: the shared slot pool (step domain) ----------------------------
    _ev("pool.attach",
        ("layers", "leaves", "planes"),
        "per-geometry cache leaves attached to a SharedPagedPools: layer "
        "count, the leaf-name set (k,v / ckv,krope / state), and how many "
        "planes one page migration moves"),
    # -- serve: the continuous-batching scheduler (wall clock) ---------------
    _ev("serve.admit",
        ("step", "joiners", "pages", "queue_depth", "wall_ms", "stall_ms"),
        "one admission batch: requests packed-prefilled together, pages "
        "allocated, queue depth after, prefill wall time; the pipelined "
        "loop adds stall_ms, the batch's worst reservation-to-activation "
        "admission stall (the SLO the chunk knob trades against)"),
    _ev("serve.retire",
        ("step", "rid", "tokens", "status", "deadline_ms"),
        "a request left the system with a typed terminal status -- "
        "completed (EOS or length), shed (bounded-queue overflow) or "
        "expired (deadline passed while queued) -- plus wall "
        "milliseconds from submit to retirement; its pages recycle"),
    _ev("serve.preempt",
        ("step", "rid", "pages", "mass", "hbm_need", "hbm_cap"),
        "pool pressure froze the coldest active request (by Cori page "
        "mass): its resident pages demoted to host, HBM slots released, "
        "caches kept intact for later reactivation without recompute"),
    _ev("serve.shed",
        ("step", "rid", "reason", "queue_depth"),
        "admission control refused a request: queue-full at submit or "
        "deadline expiry while waiting; the request retires with a "
        "typed non-completed status instead of stalling the batch"),
    _ev("serve.worker_restart",
        ("step", "reason", "restarts", "degraded"),
        "the DecisionWorker watchdog fired (hang or crash): the boundary "
        "fell back to a synchronous decision, the tuner reverted to "
        "last-good, and the worker was relaunched (degraded=True once "
        "restarts are exhausted and the loop stays synchronous)"),
    _ev("serve.macro",
        ("step", "n_steps", "tokens", "active", "fetched", "wall_ms",
         "straggler"),
        "one macro-step launch: a movement period of device-resident "
        "decode -- scan length, tokens served, mean active rows, up-front "
        "prefetch misses, wall time, StepTimer straggler flag"),
    _ev("serve.stream",
        ("phase", "tokens", "wall_ms"),
        "single-stream monitored_generate started/finished"),
    _ev("serve.pipeline.stage",
        ("step", "stage", "wall_ms"),
        "one overlap-window stage of the pipelined macro loop finished "
        "behind the in-flight scan: decision_wait, prefetch, tables or "
        "admit"),
    _ev("serve.pipeline.decision",
        ("step", "generation", "period", "bring", "evict", "wait_ms"),
        "a background-worker tiering/tuner decision was applied at a "
        "macro boundary (the stale-by-one hand-off): its generation, the "
        "period adopted, planned bring/evict sizes, and how long the "
        "overlap window waited for it"),
    _ev("serve.pipeline.admit_chunk",
        ("step", "rid", "chunk", "tokens", "total", "wall_ms", "done"),
        "one bounded prefill chunk of a long-prompt admission was "
        "dispatched between macro launches (the SLO admission knob); "
        "done=True marks the request's final chunk"),
    # -- ft: fault-tolerance runtime -----------------------------------------
    _ev("ft.straggler",
        ("timer", "step", "dt_s", "ema_s"),
        "StepTimer flagged a step slower than threshold x EMA (serving "
        "macro launches and the training step share this event)"),
    _ev("ft.inject",
        ("kind", "clock", "count", "value"),
        "a FaultPlan injection point fired: the fault kind, the plan's "
        "logical clock, this kind's occurrence counter and the point's "
        "magnitude parameter (chaos runs replay deterministically from "
        "the plan seed)"),
    # -- meta: records written by the exporters, never emit()ed --------------
    _ev("metrics.summary",
        ("schema", "counters", "gauges", "hists"),
        "final JSONL record: the Recorder's counters/gauges/histogram "
        "summaries (written by the exporter, not an emit site)"),
]

EVENTS: Dict[str, Event] = {e.name: e for e in _ALL}
