"""Hostile-traffic suite: adversarial stream generators + tuner defenses.

Covers the hardening PR end to end: the modulated-Poisson stream shapes
(flash crowds, diurnal swings, correlated bursts, kind-mix inversions)
and the OnlineTuner defenses they attack -- the TRIAL cost-spike
guardrail (spiky poison aborts the sweep and reverts to last-good), the
HOLD guard (a single extreme window is discarded, a sustained run
escalates to a cold re-profile), variance-scaled trial windows, warm
re-tune candidate ordering, and the winner-seeded HOLD baseline."""
import collections

import numpy as np
import pytest

from repro.core import (OnlineTuner, correlated_burst_stream, diurnal_stream,
                        flash_crowd_stream, invert_kinds, mix_inversion_stream,
                        shifting_mix_stream)


# ---------------------------------------------------------------------------
# hostile stream generators
# ---------------------------------------------------------------------------


def _per_step_counts(specs, steps, start=0):
    counts = np.zeros(steps, np.int64)
    for r in specs:
        counts[r.arrival - start] += 1
    return counts


def test_flash_crowd_spikes_dominate_base_rate():
    steps, rate = 600, 0.5
    specs = flash_crowd_stream(steps, rate, {"random": 1.0},
                               spike_factor=10.0, spike_every=200,
                               spike_len=20, seed=1)
    counts = _per_step_counts(specs, steps)
    spike = np.array([(t % 200) < 20 for t in range(steps)])
    spike_density = counts[spike].mean()
    base_density = counts[~spike].mean()
    assert spike_density > 4.0 * base_density
    assert base_density == pytest.approx(rate, rel=0.5)


def test_diurnal_swing_peak_vs_trough():
    steps = 800
    specs = diurnal_stream(steps, 1.0, {"random": 1.0},
                           swing_period=400, amplitude=0.8, seed=2)
    counts = _per_step_counts(specs, steps)
    t = np.arange(steps)
    peak = ((t % 400 >= 50) & (t % 400 < 150))     # around sin max at 100
    trough = ((t % 400 >= 250) & (t % 400 < 350))  # around sin min at 300
    assert counts[peak].mean() > 3.0 * max(counts[trough].mean(), 1e-9)


def test_correlated_bursts_clump_and_preserve_mean_rate():
    steps, rate, b = 2000, 0.5, 5
    specs = correlated_burst_stream(steps, rate, {"random": 1.0},
                                    burst_size=b, seed=3)
    counts = _per_step_counts(specs, steps)
    assert (counts % b == 0).all(), "arrivals must clump in whole bursts"
    assert counts.sum() == pytest.approx(steps * rate, rel=0.2)
    # variance is ~burst_size x Poisson: far above the mean rate
    assert counts.var() > 2.0 * rate


def test_invert_kinds_reverses_weights_and_is_involutive():
    k = {"a": 0.7, "b": 0.2, "c": 0.1}
    flipped = invert_kinds(k)
    assert flipped == {"a": 0.1, "b": 0.2, "c": 0.7}
    assert invert_kinds(flipped) == k
    assert sum(flipped.values()) == pytest.approx(sum(k.values()))


def test_mix_inversion_flips_dominant_kind_on_schedule():
    specs = mix_inversion_stream(400, 2.0, {"a": 0.9, "b": 0.1},
                                 invert_every=100, seed=4)
    for seg, dominant in ((0, "a"), (1, "b"), (2, "a"), (3, "b")):
        kinds = [r.kind for r in specs
                 if seg * 100 <= r.arrival < (seg + 1) * 100]
        frac = kinds.count(dominant) / max(1, len(kinds))
        assert frac > 0.7, f"segment {seg} must be {dominant}-dominated"


def test_shifting_mix_stream_dispatches_hostile_generators():
    specs = shifting_mix_stream(
        [(100, 1.0, {"a": 1.0}),
         (100, 1.0, {"b": 1.0}, {"gen": "burst", "burst_size": 4}),
         (100, 2.0, {"c": 1.0}, {"gen": "flash_crowd", "spike_factor": 6.0,
                                 "spike_every": 50, "spike_len": 5})],
        seed=5)
    assert [r.rid for r in specs] == list(range(len(specs)))
    by_phase = collections.defaultdict(list)
    for r in specs:
        by_phase[r.arrival // 100].append(r)
    assert set(by_phase) == {0, 1, 2}
    assert {r.kind for r in by_phase[0]} == {"a"}
    assert {r.kind for r in by_phase[1]} == {"b"}
    assert {r.kind for r in by_phase[2]} == {"c"}
    counts = _per_step_counts(by_phase[1], 100, start=100)
    assert (counts % 4 == 0).all(), "burst phase must clump in 4s"


# ---------------------------------------------------------------------------
# TRIAL cost-spike guardrail
# ---------------------------------------------------------------------------


def _converged_tuner(**kw):
    """Drive a tuner to a clean HOLD at period 8 with attested cost ~1."""
    params = dict(default_period=2, profile_steps=32, trial_steps=32,
                  horizon_steps=64, bin_width=1, patience=3)
    params.update(kw)
    tuner = OnlineTuner(64, **params)
    # 4-page round robin: every gap is exactly 4, so the ladder stays the
    # multi-candidate [4, 8, ...] however far the sliding window advances
    ids = lambda t: np.array([t % 4])
    for t in range(600):
        tuner.on_step(accessed_ids=ids(t), cost=abs(tuner.period - 8) + 1.0)
    assert tuner.state == OnlineTuner.HOLD
    assert tuner.period == 8
    assert np.isfinite(tuner.last_good_cost)
    return tuner, ids


def test_poisoned_trial_sweep_aborts_and_reverts_to_last_good():
    """A spiky cost poison during a re-tune sweep must trip the guardrail
    and revert to the last attested period instead of crowning whichever
    candidate the burst happened to spare."""
    tuner, ids = _converged_tuner()
    retunes = tuner.retunes
    tuner._reprofile()                       # force a (warm) re-tune sweep
    assert tuner.state == OnlineTuner.TRIAL
    assert tuner.period == 8, "warm sweep starts at the previous winner"
    # spiky poison: whole 8-step buckets alternate 300x / clean, so the
    # tail mean blows past guard_ratio x last_good AND the bucket CV reads
    # as a burst (not a uniform regime change)
    for i in range(200):
        if tuner.state != OnlineTuner.TRIAL:
            break
        c = 300.0 if (i // 8) % 2 == 0 else 1.0
        tuner.on_step(accessed_ids=ids(i), cost=c)
    assert tuner.guard_trips >= 1
    assert tuner.state == OnlineTuner.HOLD
    assert tuner.period == 8, "must revert to the last-good period"
    assert tuner.retunes == retunes, "an aborted sweep is not a re-tune"
    assert tuner._resweep_pending, "truncated sweep owes a re-rank"


def test_nan_cost_poison_does_not_propagate_or_crash():
    """NaN/inf cost measurements are pinned to +inf: the guardrail eats
    them (unmeasurable CV == burst -> abort to last-good) and no NaN ever
    reaches the baseline, the ranking, or the period."""
    tuner, ids = _converged_tuner()
    tuner._reprofile()
    assert tuner.state == OnlineTuner.TRIAL
    for i in range(200):
        if tuner.state != OnlineTuner.TRIAL:
            break
        tuner.on_step(accessed_ids=ids(i), cost=float("nan"))
    assert tuner.state == OnlineTuner.HOLD
    assert tuner.period == 8
    assert tuner.guard_trips >= 1
    assert isinstance(tuner.period, int)
    assert tuner.baseline_cost is None or np.isfinite(tuner.baseline_cost)
    # the log records the pinned +inf, never NaN
    assert not any(np.isnan(c) for c in tuner.cost_log)


def test_uniform_regime_change_mid_sweep_goes_cold_not_revert():
    """A uniformly elevated tail (low bucket CV) is a cost regime change,
    not a burst: the guardrail must cold re-profile (stale anchor and
    reuse info dropped) rather than revert to a stale last-good."""
    tuner, ids = _converged_tuner()
    tuner._reprofile()
    assert tuner.state == OnlineTuner.TRIAL
    for i in range(200):
        if tuner.state != OnlineTuner.TRIAL:
            break
        tuner.on_step(accessed_ids=ids(i), cost=300.0)   # flat 300x
    assert tuner.guard_trips >= 1
    assert tuner.state == OnlineTuner.PROFILE
    assert not np.isfinite(tuner.last_good_cost), \
        "cold reset must drop the stale cost anchor"


# ---------------------------------------------------------------------------
# HOLD guard: burst windows discarded, sustained runs escalate
# ---------------------------------------------------------------------------


def test_hold_discards_single_guard_window_then_escalates_sustained():
    tuner, ids = _converged_tuner(drift_patience=3)
    base = tuner.baseline_cost
    retunes = tuner.retunes
    # one guard-level window (100x >> guard_ratio x baseline): discarded
    trips0 = tuner.guard_trips
    i = 0
    while tuner.guard_trips == trips0 and i < 100:
        tuner.on_step(accessed_ids=ids(i), cost=100.0)
        i += 1
    assert tuner.guard_trips == trips0 + 1
    assert tuner.state == OnlineTuner.HOLD
    assert tuner.baseline_cost == base, "a burst window must not baseline"
    assert tuner.retunes == retunes, "a burst window must not re-profile"
    # clean windows in between reset the strike counter
    for _ in range(3 * tuner._win_target):
        tuner.on_step(accessed_ids=ids(i), cost=base)
        i += 1
    assert tuner.state == OnlineTuner.HOLD and tuner._guard_strikes == 0
    # sustained guard-level windows == regime change: cold re-profile
    for _ in range(8 * tuner._win_target):
        if tuner.state != OnlineTuner.HOLD:
            break
        tuner.on_step(accessed_ids=ids(i), cost=100.0)
        i += 1
    assert tuner.state == OnlineTuner.PROFILE
    assert not np.isfinite(tuner.last_good_cost)


def test_hold_baseline_floored_by_winner_trial_cost():
    """One anomalously quiet first window must not arm a hair-trigger
    drift detector: the baseline is floored by the winner's attested
    trial cost (the mirror image of the _hold_skip transient discard)."""
    tuner = OnlineTuner(8, default_period=4, trial_steps=8)
    tuner.state = OnlineTuner.HOLD
    tuner._sweep_cost = 10.0
    tuner.baseline_cost = None
    tuner._hold_skip = False
    tuner._arm_window()
    for i in range(tuner._win_target):
        tuner.on_step(accessed_ids=np.array([i % 4]), cost=2.0)
    assert tuner.baseline_cost == pytest.approx(10.0)
    assert tuner.last_good_cost == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# variance-scaled trial windows
# ---------------------------------------------------------------------------


def _armed_trial(trial_steps, var_cv=0.3, var_max_factor=4):
    tuner = OnlineTuner(8, default_period=4, trial_steps=trial_steps,
                        guard_ratio=None, var_cv=var_cv,
                        var_max_factor=var_max_factor)
    tuner.state = OnlineTuner.TRIAL
    tuner.candidates = np.array([4.0])
    tuner.tried = []
    tuner._trial_idx = 0
    tuner._best_cost = np.inf
    tuner._best_period = 4
    tuner._stale = 0
    tuner._arm_window()
    return tuner


def _alternating(i):
    """Whole-period buckets alternate 9x / 1x: heavy-tailed (CV ~0.8)."""
    return 9.0 if (i // 4) % 2 == 0 else 1.0


def test_heavy_tailed_trial_window_extends_then_settles():
    tuner = _armed_trial(trial_steps=16)
    # noisy first window: buckets alternate -> CV > var_cv -> extend once
    for i in range(16):
        tuner.on_step(accessed_ids=np.array([i % 4]), cost=_alternating(i))
    assert tuner.window_extensions == 1
    assert tuner.state == OnlineTuner.TRIAL and not tuner.tried
    # the restarted tail is calm: the trial completes at the doubled target
    for i in range(16):
        tuner.on_step(accessed_ids=np.array([i % 4]), cost=1.0)
    assert len(tuner.tried) == 1
    assert tuner.tried[0][1] == pytest.approx(1.0)
    assert tuner.window_extensions == 1


def test_variance_extension_capped_at_var_max_factor():
    tuner = _armed_trial(trial_steps=16, var_max_factor=4)
    for i in range(200):
        if tuner.tried:
            break
        tuner.on_step(accessed_ids=np.array([i % 4]), cost=_alternating(i))
    # 16 -> 32 -> 64 == var_max_factor x base, then the trial must finish
    assert tuner.window_extensions == 2
    assert len(tuner.tried) == 1


def test_calm_trial_window_never_extends():
    tuner = _armed_trial(trial_steps=16)
    for i in range(16):
        tuner.on_step(accessed_ids=np.array([i % 4]), cost=5.0)
    assert tuner.window_extensions == 0
    assert len(tuner.tried) == 1
    assert tuner.tried[0][1] == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# warm re-tune candidate ordering
# ---------------------------------------------------------------------------


def test_warm_retune_explores_outward_from_previous_winner():
    tuner, _ = _converged_tuner()
    hist = tuner.collector.histogram()
    tuner._launch_trials(hist)
    cand = np.asarray(tuner.candidates, float)
    assert len(cand) > 1
    dist = np.abs(cand - float(tuner.last_good_period))
    assert (np.diff(dist) >= 0).all(), \
        "warm sweep must be ordered nearest-first around the last winner"
    assert cand[0] == pytest.approx(tuner.last_good_period, abs=2.0)


def test_cold_retune_reverts_to_shortest_first_order():
    tuner, _ = _converged_tuner()
    hist = tuner.collector.histogram()
    tuner._warm_next = False                 # what a cold reset sets
    tuner._launch_trials(hist)
    cand = np.asarray(tuner.candidates, float)
    assert (np.diff(cand) > 0).all(), \
        "cold sweep must re-walk the ladder shortest-first"
    assert tuner._warm_next, "the cold order is consumed one-shot"


# ---------------------------------------------------------------------------
# defenses are inert on clean stationary traffic
# ---------------------------------------------------------------------------


def test_defenses_change_nothing_on_stationary_workload():
    def drive(**kw):
        params = dict(default_period=2, profile_steps=32, trial_steps=16,
                      horizon_steps=64, bin_width=1, patience=3)
        params.update(kw)
        tuner = OnlineTuner(64, **params)
        ids = lambda t: (np.array([0]) if t % 4 == 0
                         else np.array([1 + (t % 63)]))
        for t in range(400):
            tuner.on_step(accessed_ids=ids(t),
                          cost=abs(tuner.period - 8) + 1.0)
        return tuner

    on = drive()
    off = drive(guard_ratio=None, var_cv=None, warm_start=False)
    assert on.period == off.period == 8
    assert on.retunes == off.retunes == 1
    assert on.guard_trips == 0 and on.window_extensions == 0


# ---------------------------------------------------------------------------
# end to end: a hostile stream through the serving scheduler
# ---------------------------------------------------------------------------


def test_flash_crowd_stream_through_scheduler_stays_stable():
    """Flash crowds through the real TrafficScheduler -> TrafficMonitor ->
    OnlineTuner loop: the tuner must not churn (bounded re-tunes), must
    keep a sane period, and every logged cost must be finite."""
    from repro.memtier import SharedPagedPools, TierConfig, TieringManager
    from repro.serve.sched import TrafficMonitor, TrafficScheduler

    specs = flash_crowd_stream(400, 0.08, {"random": 0.6, "sink": 0.4},
                               spike_factor=6.0, spike_every=120,
                               spike_len=10, prompt_len=(16, 48),
                               new_tokens=(40, 100), seed=3)
    pools = SharedPagedPools.create(128, 16)
    mgr = TieringManager(128, TierConfig(page_size=16, hbm_pages=16,
                                         period_steps=8))
    tuner = OnlineTuner(128, default_period=8, drift_ratio=1.5,
                        drift_patience=3)
    sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr, tuner),
                             page_size=16, max_active=6)
    for _ in range(400):
        sched.step()
    assert sched.completed > 0
    assert tuner.retunes <= 3, "flash crowds must not churn the tuner"
    assert tuner.period >= 1
    assert all(np.isfinite(c) for c in tuner.cost_log)
