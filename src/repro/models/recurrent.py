"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and RG-LRU (RecurrentGemma).

TPU adaptation notes (DESIGN.md S3):
  * RG-LRU is a *linear* recurrence with elementwise gates, so it runs as a
    log-depth ``jax.lax.associative_scan`` -- the TPU-native formulation
    (the GPU reference uses a custom linear-scan kernel).
  * mLSTM/sLSTM use exponential gating with the max-stabiliser; the
    sequence dimension is processed with ``lax.scan`` (sequential form).
    All cells expose a single-step path for decode.
  * The xLSTM paper's causal conv1d(4) front of each cell is kept (cheap,
    shift-and-add form); GroupNorm after the cell is RMS-normalised per
    head (simplification, documented).

Every state is a dict of named arrays so the serving runtime can treat
recurrent state and KV caches uniformly.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import _dense_init, rms_norm, split_tree

Params = Dict[str, Any]


def _causal_conv1d(x, w):
    """Depthwise causal conv.  x: [B,S,D], w: [K,D]."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for j in range(k):
        xs = x if j == 0 else jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j or None]
        out = out + xs * w[k - 1 - j]
    return out


def _conv_step(state, x_t, w):
    """Single-token conv.  state: [B,K-1,D] (previous inputs)."""
    k = w.shape[0]
    window = jnp.concatenate([state, x_t[:, None]], axis=1)  # [B,K,D]
    y = jnp.einsum("bkd,kd->bd", window, w)
    return window[:, 1:], y


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    dm = 2 * d                      # block up-projection
    nh = max(1, cfg.num_kv_heads)   # xLSTM heads ride the kv_heads field
    ks = jax.random.split(key, 8)
    tree = {
        "w_up": _dense_init(ks[0], (d, dm), ("embed", "mlp")),
        "w_gate": _dense_init(ks[1], (d, dm), ("embed", "mlp")),
        "conv": (jnp.zeros((4, dm), jnp.float32), (None, "mlp")),
        "wq": _dense_init(ks[2], (dm, dm), ("mlp", "mlp")),
        "wk": _dense_init(ks[3], (dm, dm), ("mlp", "mlp")),
        "wv": _dense_init(ks[4], (dm, dm), ("mlp", "mlp")),
        "w_if": _dense_init(ks[5], (dm, 2 * nh), ("mlp", None)),
        "out_norm": (jnp.ones((dm,), jnp.float32), ("mlp",)),
        "w_down": _dense_init(ks[6], (dm, d), ("mlp", "embed")),
    }
    return split_tree(tree)


def mlstm_zero_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    dm, nh = 2 * d, max(1, cfg.num_kv_heads)
    hd = dm // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), dtype),
        "n": jnp.zeros((batch, nh, hd), dtype),
        "m": jnp.full((batch, nh), -1e30, dtype),
        "conv": jnp.zeros((batch, 3, dm), dtype),
    }


def _mlstm_cell(state, qkvif):
    """One timestep.  state: (C, n, m); q,k,v: [B,nh,hd]; i,f: [B,nh]."""
    q, k, v, i, f = qkvif
    C, n, m = state
    hd = q.shape[-1]
    k = k / np.sqrt(hd)
    m_new = jnp.maximum(f + m, i)
    i_p = jnp.exp(i - m_new)[..., None]
    f_p = jnp.exp(f + m - m_new)[..., None]
    n_new = f_p * n + i_p * k
    C_new = f_p[..., None] * C + i_p[..., None] * (k[..., :, None]
                                                   * v[..., None, :])
    num = jnp.einsum("bhkv,bhk->bhv", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return (C_new, n_new, m_new), h


def _mlstm_inputs(p, x_in, nh):
    """Projections shared by scan/step.  x_in: [B,S,dm] (post-conv)."""
    b, s, dm = x_in.shape
    hd = dm // nh
    q = (x_in @ p["wq"].astype(x_in.dtype)).reshape(b, s, nh, hd)
    k = (x_in @ p["wk"].astype(x_in.dtype)).reshape(b, s, nh, hd)
    v = (x_in @ p["wv"].astype(x_in.dtype)).reshape(b, s, nh, hd)
    gf = (x_in @ p["w_if"].astype(x_in.dtype)).astype(jnp.float32)
    i, f = gf[..., :nh], gf[..., nh:]
    f = jax.nn.log_sigmoid(f)     # forget gate in log space
    return q, k, v, i, f


def mlstm_apply(p: Params, cfg: ModelConfig, x, state=None):
    """Sequence form.  x: [B,S,d] -> (y, final_state)."""
    b, s, d = x.shape
    up = x @ p["w_up"].astype(x.dtype)
    gate = x @ p["w_gate"].astype(x.dtype)
    if state is None:
        state = mlstm_zero_state(cfg, b, jnp.float32)
    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), up], axis=1)
    xc = jax.nn.silu(_causal_conv1d(conv_in, p["conv"].astype(x.dtype))[:, 3:])
    nh = max(1, cfg.num_kv_heads)
    q, k, v, i, f = _mlstm_inputs(p, xc, nh)

    seq = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
           v.transpose(1, 0, 2, 3),
           i.transpose(1, 0, 2), f.transpose(1, 0, 2))
    cell_state = (state["C"], state["n"], state["m"])

    def _cell_bf16(st, t_in):
        qt, kt, vt, it, ft = t_in
        st2, h = _mlstm_cell(st, (qt.astype(jnp.float32),
                                  kt.astype(jnp.float32),
                                  vt.astype(jnp.float32), it, ft))
        return st2, h.astype(x.dtype)

    final, hs = jax.lax.scan(_cell_bf16, cell_state, seq)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, -1).astype(x.dtype)
    h = rms_norm(h, p["out_norm"]) * jax.nn.silu(gate)
    y = h @ p["w_down"].astype(x.dtype)
    new_state = {"C": final[0], "n": final[1], "m": final[2],
                 "conv": conv_in[:, -3:].astype(jnp.float32)}
    return y, new_state


def mlstm_step(p: Params, cfg: ModelConfig, x, state):
    """Decode step.  x: [B,1,d]."""
    up = (x @ p["w_up"].astype(x.dtype))[:, 0]
    gate = (x @ p["w_gate"].astype(x.dtype))[:, 0]
    conv_state, xc = _conv_step(state["conv"].astype(x.dtype), up,
                                p["conv"].astype(x.dtype))
    xc = jax.nn.silu(xc)[:, None]
    nh = max(1, cfg.num_kv_heads)
    q, k, v, i, f = _mlstm_inputs(p, xc, nh)
    cell = (state["C"], state["n"], state["m"])
    new_st, h = _mlstm_cell(
        cell, (q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
               v[:, 0].astype(jnp.float32), i[:, 0], f[:, 0]))
    h = h.reshape(h.shape[0], -1).astype(x.dtype)
    h = rms_norm(h, p["out_norm"]) * jax.nn.silu(gate)
    y = (h @ p["w_down"].astype(x.dtype))[:, None]
    return y, {"C": new_st[0], "n": new_st[1], "m": new_st[2],
               "conv": conv_state.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    nh = max(1, cfg.num_kv_heads)
    hd = d // nh
    ks = jax.random.split(key, 6)
    ff = int(d * 4 / 3)
    tree = {
        "conv": (jnp.zeros((4, d), jnp.float32), (None, "embed")),
        "w_gates": _dense_init(ks[0], (d, 4 * d), ("embed", "mlp")),
        "r_gates": _dense_init(ks[1], (nh, hd, 4 * hd),
                               ("kv_heads", None, None)),
        "out_norm": (jnp.ones((d,), jnp.float32), ("embed",)),
        "w_up": _dense_init(ks[2], (d, ff), ("embed", "mlp")),
        "w_down": _dense_init(ks[3], (ff, d), ("mlp", "embed")),
    }
    return split_tree(tree)


def slstm_zero_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d, nh = cfg.d_model, max(1, cfg.num_kv_heads)
    hd = d // nh
    return {
        "c": jnp.zeros((batch, nh, hd), dtype),
        "n": jnp.full((batch, nh, hd), 1e-6, dtype),
        "m": jnp.full((batch, nh, hd), -1e30, dtype),
        "h": jnp.zeros((batch, nh, hd), dtype),
        "conv": jnp.zeros((batch, 3, d), dtype),
    }


def _slstm_cell(state, wx, r_gates):
    """wx: [B,4d] precomputed input part; recurrent part from state h."""
    c, n, m, h = state["c"], state["n"], state["m"], state["h"]
    b, nh, hd = h.shape
    rx = jnp.einsum("bhk,hkg->bhg", h, r_gates)          # [B,nh,4hd]
    gates = wx.reshape(b, nh, 4 * hd) + rx
    z, i, f, o = jnp.split(gates, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    f = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(f + m, i)
    i_p = jnp.exp(i - m_new)
    f_p = jnp.exp(f + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}, h_new


def slstm_apply(p: Params, cfg: ModelConfig, x, state=None):
    b, s, d = x.shape
    if state is None:
        state = slstm_zero_state(cfg, b, jnp.float32)
    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), x], axis=1)
    xc = jax.nn.silu(_causal_conv1d(conv_in, p["conv"].astype(x.dtype))[:, 3:])
    wx = xc @ p["w_gates"].astype(x.dtype)
    r = p["r_gates"].astype(jnp.float32)

    def step(st, wx_t):
        new_st, h = _slstm_cell(st, wx_t.astype(jnp.float32), r)
        return new_st, h.astype(x.dtype)

    cell = {k: state[k] for k in ("c", "n", "m", "h")}
    final, hs = jax.lax.scan(step, cell, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    h = rms_norm(h, p["out_norm"])
    y = jax.nn.gelu(h @ p["w_up"].astype(x.dtype)) @ p["w_down"].astype(x.dtype)
    new_state = dict(final, conv=conv_in[:, -3:].astype(jnp.float32))
    return y, new_state


def slstm_step(p: Params, cfg: ModelConfig, x, state):
    conv_state, xc = _conv_step(state["conv"].astype(x.dtype), x[:, 0],
                                p["conv"].astype(x.dtype))
    xc = jax.nn.silu(xc)
    wx = (xc @ p["w_gates"].astype(x.dtype)).astype(jnp.float32)
    cell = {k: state[k] for k in ("c", "n", "m", "h")}
    new_st, h = _slstm_cell(cell, wx, p["r_gates"].astype(jnp.float32))
    b = x.shape[0]
    h = h.reshape(b, -1).astype(x.dtype)
    h = rms_norm(h, p["out_norm"])
    y = (jax.nn.gelu(h @ p["w_up"].astype(x.dtype))
         @ p["w_down"].astype(x.dtype))[:, None]
    return y, dict(new_st, conv=conv_state.astype(jnp.float32))


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_init(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a = exp(-c*softplus(L)) is in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C))
    tree = {
        "w_x": _dense_init(ks[1], (d, w), ("embed", "lru")),
        "w_gate": _dense_init(ks[2], (d, w), ("embed", "lru")),
        "conv": (jnp.zeros((4, w), jnp.float32), (None, "lru")),
        "lam": (lam, ("lru",)),
        "w_a": _dense_init(ks[3], (w, w // 8), ("lru", None)),
        "w_a2": _dense_init(ks[4], (w // 8, w), (None, "lru")),
        "w_i": _dense_init(ks[5], (w, w // 8), ("lru", None)),
        "w_i2": _dense_init(jax.random.fold_in(key, 9), (w // 8, w),
                            (None, "lru")),
        "w_out": _dense_init(jax.random.fold_in(key, 10), (w, d),
                             ("lru", "embed")),
    }
    return split_tree(tree)


def rglru_zero_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), dtype),
            "conv": jnp.zeros((batch, 3, w), dtype)}


def _rglru_gates(p, xc):
    """a (log-space) and gated input for each position.  xc: [..., w]."""
    r = jax.nn.sigmoid((xc @ p["w_a"].astype(xc.dtype))
                       @ p["w_a2"].astype(xc.dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid((xc @ p["w_i"].astype(xc.dtype))
                       @ p["w_i2"].astype(xc.dtype)).astype(jnp.float32)
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = beta * (i * xc.astype(jnp.float32))
    return a, b


def rglru_apply(p: Params, cfg: ModelConfig, x, state=None):
    """x: [B,S,d] -> (y, state).  Associative scan over the linear
    recurrence h_t = a_t*h_{t-1} + b_t (TPU-native log-depth form)."""
    bsz, s, d = x.shape
    if state is None:
        state = rglru_zero_state(cfg, bsz, jnp.float32)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_x"].astype(x.dtype)
    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), u], axis=1)
    xc = _causal_conv1d(conv_in, p["conv"].astype(x.dtype))[:, 3:]
    a, b = _rglru_gates(p, xc)
    # fold previous state into the first step
    b = b.at[:, 0].add(a[:, 0] * state["h"])

    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    new_state = {"h": h[:, -1], "conv": conv_in[:, -3:].astype(jnp.float32)}
    return y, new_state


def rglru_step(p: Params, cfg: ModelConfig, x, state):
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"].astype(x.dtype))
    u = x[:, 0] @ p["w_x"].astype(x.dtype)
    conv_state, xc = _conv_step(state["conv"].astype(x.dtype), u,
                                p["conv"].astype(x.dtype))
    a, b = _rglru_gates(p, xc)
    h = a * state["h"] + b
    y = ((h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype))[:, None]
    return y, {"h": h, "conv": conv_state.astype(jnp.float32)}
