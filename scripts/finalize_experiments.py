"""Assemble the final EXPERIMENTS.md sections from benchmark/dry-run JSONs.

Run whenever new dry-run cells land:
    PYTHONPATH=src python scripts/finalize_experiments.py
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import roofline as RL  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parents[1]
NEW = ROOT / "benchmarks/out/dryrun"
OLD = ROOT / "benchmarks/out/dryrun_f32resid"


def _load(d):
    out = {}
    for f in sorted(pathlib.Path(d).glob("*__single.json")):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def perf_cell_1(old, new) -> str:
    k = ("nemotron-4-340b", "train_4k")
    lines = ["* **Hypothesis**: nemotron's residual stream is f32 (HLO shows "
             "`f32[96,2,256,18432]` stacked saves; a bf16 stream would halve "
             "them).  Forensics: `embed()` scaled by a *strong* `np.float64` "
             "scalar, promoting x to f32 from the first op -- for every arch.",
             "* **Change**: weak-typed python-float scale in `embed` "
             "(+ explicit weight casts in the non-swiglu MLP).",
             "* **Measured** (per chip):"]
    for kk in [k, ("stablelm-12b", "train_4k"), ("qwen3-14b", "train_4k"),
               ("nemotron-4-340b", "prefill_32k")]:
        if kk in old and kk in new:
            a, b = old[kk], new[kk]
            ca = a.get("cost_variant", {})
            cb = b.get("cost_variant", {})
            lines.append(
                f"  * {kk[0]} {kk[1]}: temp {a['temp_bytes']/1e9:.1f} -> "
                f"**{b['temp_bytes']/1e9:.1f} GB**, cost-variant collectives "
                f"{ca.get('collective_bytes_total',0)/1e9:.0f} -> "
                f"**{cb.get('collective_bytes_total',0)/1e9:.0f} GB**, bytes "
                f"{ca.get('bytes_accessed',0)/1e12:.2f} -> "
                f"{cb.get('bytes_accessed',0)/1e12:.2f} TB")
    lines.append("* **Verdict**: confirmed -- one weak-typing bug cost ~2x "
                 "on the memory and collective terms of *every* cell; the "
                 "single highest-leverage change of the whole perf pass.")
    return "\n".join(lines)


def lever(r) -> str:
    """One sentence: what would move the dominant term down."""
    a, sh, d = r["arch"], r["shape"], r["dominant"]
    if d == "collective":
        if "deepseek" in a or "olmoe" in a:
            return ("overlap the EP psum with expert GEMMs and move expert "
                    "dispatch to ragged all-to-all on the ICI torus")
        if sh == "train_4k":
            return ("overlap SP all-gathers/reduce-scatters with the QKV/MLP "
                    "GEMMs (async collectives), and halve volume via the bf16 "
                    "residual stream (RESID_WEAK_SCALE)")
        if "decode" in sh or sh == "long_500k":
            return ("replicate KV heads per shard to drop the context-parallel "
                    "softmax all-reduce; batch decode steps to amortise")
        return ("async-overlap the per-layer seq all-gather with the "
                "projection GEMMs")
    if d == "memory":
        if "xlstm" in a:
            return ("chunkwise-parallel mLSTM (64-token chunks) turns the "
                    "per-step C-state read/write into MXU GEMMs, ~S/64x less "
                    "state traffic")
        if "decode" in sh or sh == "long_500k":
            return ("int8/fp8 KV cache (+ paged HBM working set via the "
                    "Cori-tuned tiering runtime) halves cache reads")
        return ("fuse attention with the Pallas flash kernel so scores never "
                "round-trip HBM; bf16 residual stream")
    return ("raise arithmetic intensity: larger microbatch per chip or fewer "
            "accum steps now that memory fits")


def roofline_summary(rows) -> str:
    if not rows:
        return "_dry-run cells still compiling at assembly time_"
    dom = {}
    for r in rows:
        dom.setdefault(r["dominant"], []).append(r)
    lines = [f"{len(rows)} single-pod cells analysed "
             f"(remainder in roofline.md as they land):", ""]
    for d, rs in sorted(dom.items()):
        cells = ", ".join(f"{r['arch']}/{r['shape']}" for r in rs[:6])
        more = "..." if len(rs) > 6 else ""
        lines.append(f"* **{d}-bound** ({len(rs)}): {cells}{more}")
    best = max(rows, key=lambda r: r["roofline_fraction"])
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    lines += ["",
              f"* best roofline fraction: {best['roofline_fraction']:.3f} "
              f"({best['arch']}/{best['shape']})",
              f"* worst: {worst['roofline_fraction']:.3f} "
              f"({worst['arch']}/{worst['shape']})",
              "",
              "| arch | shape | compute s | memory s | collective s | "
              "dominant | useful/HLO | roofline frac | fits 16G | "
              "lever to move the dominant term |",
              "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | {r['dominant']} "
            f"| {r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{'yes' if r['fits_hbm_16g'] else 'no'} | {lever(r)} |")
    return "\n".join(lines)


def main():
    old, new = _load(OLD), _load(NEW)
    rows = RL.analyze() if new else []
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    exp = exp.replace("<!-- ROOFLINE_SUMMARY -->", roofline_summary(rows))
    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print(f"assembled: {len(new)} post-fix cells, {len(old)} pre-fix cells, "
          f"{len(rows)} roofline rows")


if __name__ == "__main__":
    main()
