"""OLMoE-1B-7B [arXiv:2409.02060]: 64 experts top-8, qk-norm."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=0, vocab_size=50304,
        segments=((("attn.moe",), 16),),
        mlp_kind="swiglu", qk_norm=True, tie_embeddings=False,
        moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
        moe_impl="shard_map", rope_theta=10_000.0, max_seq_len=32768)
