"""Supervisor hang detection: stale-heartbeat relaunch and restart
exhaustion (the process-level rung of the degradation ladder --
docs/robustness.md)."""
import sys
import textwrap

from repro.ft.supervisor import SupervisorConfig, supervise

HANG_ONCE = textwrap.dedent("""\
    import pathlib, sys, time
    work = pathlib.Path(sys.argv[1])
    sentinel = work / "ran_once"
    if sentinel.exists():
        sys.exit(0)                      # the relaunch succeeds
    sentinel.write_text("1")
    (work / "heartbeat").write_text(str(time.time()))
    time.sleep(60)                       # hang: heartbeat goes stale
""")


def test_stale_heartbeat_triggers_relaunch(tmp_path):
    """A child that stops touching its heartbeat is declared hung and
    killed (exit -9 in the history), and the relaunch runs to a clean
    exit: hangs are recoverable, not merely detectable."""
    script = tmp_path / "child.py"
    script.write_text(HANG_ONCE)
    report = supervise(
        [sys.executable, str(script), str(tmp_path)], tmp_path,
        SupervisorConfig(max_restarts=2, hang_timeout_s=1.5, poll_s=1.0))
    assert report.exit_code == 0
    assert report.restarts == 1
    assert report.history == [-9, 0]


def test_hang_restarts_exhaust(tmp_path):
    """A child that never heartbeats is killed on every launch; the
    supervisor gives up after ``max_restarts`` and reports the kill."""
    script = tmp_path / "child.py"
    script.write_text("import time; time.sleep(60)\n")
    report = supervise(
        [sys.executable, str(script)], tmp_path,
        SupervisorConfig(max_restarts=1, hang_timeout_s=0.2, poll_s=0.2))
    assert report.exit_code == -9
    assert report.restarts == 1
    assert report.history == [-9, -9]
