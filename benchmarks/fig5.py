"""Fig. 5: tuning-overhead comparison -- Cori vs base-left/right/random
(paper SV-B).

(a) trials-to-best per (app, scheduler) for each method;
(b) slowdown the baselines reach when given only Cori's trial budget;
(c) Cori's final period selections."""
from __future__ import annotations

import numpy as np

from benchmarks.common import APPS, SCHEDS, save_json
from repro.core import (baseline_trials_all, base_candidates, bin_trace,
                        generate, run_cori, simulate, study, trials_to_best)


def run(apps=APPS, quick: bool = False):
    apps = apps[:4] if quick else apps
    rows = []
    for app in apps:
        trace = generate(app)
        bins = bin_trace(trace)
        for sched in SCHEDS:
            st = study(app, sched)
            base = baseline_trials_all(bins, sched, seeds=3)
            # (b): best runtime baselines find within Cori's budget
            budget = max(1, st.cori_trials_to_best)
            timestep = max(bins.block, bins.num_accesses // 128)
            cands = base_candidates(bins.num_accesses, timestep)
            rts = np.array([simulate(bins, int(p), sched).runtime
                            for p in cands])
            within = {
                "base-right": float(rts[:budget].min()),
                "base-left": float(rts[::-1][:budget].min()),
            }
            rng_best = []
            for s in range(3):
                perm = np.random.default_rng(s).permutation(len(rts))
                rng_best.append(float(rts[perm][:budget].min()))
            within["base-random"] = float(np.mean(rng_best))
            rows.append({
                "app": app, "scheduler": sched,
                "cori_trials": st.cori_trials_to_best,
                "baseline_trials": base,
                "cori_period": st.cori.chosen_period,
                "cori_slowdown": st.cori_slowdown_vs_optimal,
                "baseline_slowdown_at_cori_budget": {
                    k: v / st.optimal_runtime - 1.0 for k, v in within.items()},
            })
    cori_mean = float(np.mean([r["cori_trials"] for r in rows]))
    base_mean = float(np.mean([v for r in rows
                               for v in r["baseline_trials"].values()]))
    summary = {"rows": rows, "cori_mean_trials": cori_mean,
               "baseline_mean_trials": base_mean,
               "trial_reduction": base_mean / max(cori_mean, 1e-9)}
    save_json("fig5", summary)
    return summary


if __name__ == "__main__":
    s = run()
    print(f"cori {s['cori_mean_trials']:.1f} trials vs baselines "
          f"{s['baseline_mean_trials']:.1f} -> {s['trial_reduction']:.1f}x")
