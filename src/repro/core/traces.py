"""Synthetic memory-access trace generators for the nine paper applications.

The paper (Table II / Fig. 2) evaluates on Rodinia, Coral-2 and ParTI!
benchmarks, collecting last-level-cache-miss page traces with Intel Pin.
This container cannot run those x86 binaries, so each generator below
reproduces the *published* access-pattern family and its reuse-distance
structure (Fig. 2 / Fig. 3):

  backprop     strided array traversal; 16 strides; dominant reuse distance
               ~20 000 requests appearing 15x (one per stride boundary).
  quicksilver  strided traversal (Monte-Carlo particle sweep), fewer/longer
               strides than backprop.
  lud          triangular traversal: sweep i only revisits the trailing part
               of the footprint -> reuse-distance histogram with decreasing
               appearance counts.
  cpd          sparse-tensor (MTTKRP) traversal: streaming nonzeros with
               zipf-hot factor-matrix pages -> bimodal reuse.
  pennant      irregular accesses over a fixed number of repetitive cycles.
  kmeans       repeated full sweeps over points + very hot centroid pages.
  hotspot      2-D stencil sweeps: short intra-row reuse + long inter-iteration
               reuse.
  bfs          frontier-random traversal (near-random page reuse).
  bptree       random lookups through a tree: zipf-hot upper levels, random
               leaves.

Every generator is deterministic given ``seed`` and returns a ``Trace``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import numpy as np

__all__ = [
    "Trace",
    "TRACE_GENERATORS",
    "generate",
    "available_traces",
]


@dataclasses.dataclass(frozen=True)
class Trace:
    """A page-granularity memory access trace.

    Attributes:
      name:       application name (paper Table II abbreviation).
      pages:      int32[num_accesses] page id of each access, in issue order.
      num_pages:  memory footprint in pages.
      loop_durations: list of per-loop lengths in *accesses* -- the practical
        reuse proxy collected by Cori's Reuse Collector on the real system
        (paper SIV-A).  One entry per dynamic loop execution.
    """

    name: str
    pages: np.ndarray
    num_pages: int
    loop_durations: np.ndarray

    @property
    def num_accesses(self) -> int:
        return int(self.pages.shape[0])


def _sequential_sweep(
    rng: np.random.Generator,
    num_pages: int,
    accesses_per_page: int,
    jitter: float = 0.0,
) -> np.ndarray:
    """One sequential pass over [0, num_pages) with `accesses_per_page`
    consecutive accesses per page and optional local jitter."""
    base = np.repeat(np.arange(num_pages, dtype=np.int64), accesses_per_page)
    if jitter > 0:
        noise = rng.integers(-int(jitter), int(jitter) + 1, size=base.shape[0])
        base = np.clip(base + noise, 0, num_pages - 1)
    return base


def backprop(seed: int = 0, num_pages: int = 4096, sweeps: int = 16,
             accesses_per_page: int = 5) -> Trace:
    """Strided traversal: `sweeps` full passes; reuse distance == sweep length
    (~20k requests at the default sizing), appearing (sweeps-1) times."""
    rng = np.random.default_rng(seed)
    sweep = _sequential_sweep(rng, num_pages, accesses_per_page)
    pages = np.tile(sweep, sweeps)
    loops = np.full(sweeps, sweep.shape[0], dtype=np.int64)
    return Trace("backprop", pages.astype(np.int32), num_pages, loops)


def quicksilver(seed: int = 0, num_pages: int = 4096, sweeps: int = 8,
                accesses_per_page: int = 10) -> Trace:
    """Strided particle sweep: fewer, longer strides; mild jitter from
    particle scattering."""
    rng = np.random.default_rng(seed + 1)
    parts = [_sequential_sweep(rng, num_pages, accesses_per_page, jitter=2)
             for _ in range(sweeps)]
    pages = np.concatenate(parts)
    loops = np.array([p.shape[0] for p in parts], dtype=np.int64)
    return Trace("quicksilver", pages.astype(np.int32), num_pages, loops)


def lud(seed: int = 0, num_pages: int = 4096, sweeps: int = 24,
        accesses_per_page: int = 4, row_pages: int = 256) -> Trace:
    """Triangular traversal (LU): sweep i eliminates the trailing submatrix
    [i*num_pages/sweeps, end).  The inner update loop re-reads the pivot row
    before every trailing row, so short pivot reuses dominate the histogram
    and their count decays across sweeps -- the paper's "gradual
    degradation ... decreasing appearances" shape, with a dominant reuse
    much shorter than the sweep length (cf. Fig. 6b: lud's DR "much less")."""
    rng = np.random.default_rng(seed + 2)
    parts: List[np.ndarray] = []
    for i in range(sweeps):
        start = (i * num_pages) // sweeps
        width = num_pages - start
        if width <= 0:
            break
        piv_hi = min(start + row_pages, num_pages)
        pivot = np.repeat(np.arange(start, piv_hi, dtype=np.int64),
                          accesses_per_page)
        rows = []
        for r0 in range(start, num_pages, row_pages):
            r1 = min(r0 + row_pages, num_pages)
            row = _sequential_sweep(rng, r1 - r0, accesses_per_page) + r0
            rows.append(pivot)
            rows.append(row)
        parts.append(np.concatenate(rows))
    pages = np.concatenate(parts)
    loops = np.array([p.shape[0] for p in parts], dtype=np.int64)
    return Trace("lud", pages.astype(np.int32), num_pages, loops)


def cpd(seed: int = 0, num_pages: int = 4096, passes: int = 10,
        nnz_per_pass: int = 24000, factor_frac: float = 0.15) -> Trace:
    """Sparse CP decomposition (MTTKRP): each pass streams nonzero pages
    (uniform over the tensor region) interleaved with zipf-hot factor-matrix
    pages -> short reuse for factors, pass-length reuse for the tensor."""
    rng = np.random.default_rng(seed + 3)
    n_factor = max(1, int(num_pages * factor_frac))
    tensor_lo = n_factor
    parts = []
    # Zipf-like weights for factor rows.
    ranks = np.arange(1, n_factor + 1, dtype=np.float64)
    w = 1.0 / ranks
    w /= w.sum()
    for _ in range(passes):
        nnz = np.sort(rng.integers(tensor_lo, num_pages, size=nnz_per_pass))
        factors = rng.choice(n_factor, size=nnz_per_pass, p=w)
        inter = np.empty(2 * nnz_per_pass, dtype=np.int64)
        inter[0::2] = nnz
        inter[1::2] = factors
        parts.append(inter)
    pages = np.concatenate(parts)
    loops = np.array([p.shape[0] for p in parts], dtype=np.int64)
    return Trace("cpd", pages.astype(np.int32), num_pages, loops)


def pennant(seed: int = 0, num_pages: int = 4096, cycles: int = 12,
            accesses_per_cycle: int = 26000) -> Trace:
    """Irregular (unstructured-mesh) accesses over fixed repetitive cycles:
    random permutation walk within the footprint each cycle."""
    rng = np.random.default_rng(seed + 4)
    parts = []
    for _ in range(cycles):
        # Random but full-coverage: permutation plus extra random accesses.
        perm = rng.permutation(num_pages)
        extra = rng.integers(0, num_pages, size=accesses_per_cycle - num_pages)
        cyc = np.concatenate([perm, extra])
        rng.shuffle(cyc)
        parts.append(cyc)
    pages = np.concatenate(parts)
    loops = np.array([p.shape[0] for p in parts], dtype=np.int64)
    return Trace("pennant", pages.astype(np.int32), num_pages, loops)


def kmeans(seed: int = 0, num_pages: int = 4096, iters: int = 12,
           accesses_per_page: int = 4, centroid_pages: int = 64) -> Trace:
    """Repeated full sweeps over point pages; centroid pages interleaved
    every few accesses (very hot, short reuse)."""
    rng = np.random.default_rng(seed + 5)
    n_pts = num_pages - centroid_pages
    parts = []
    for _ in range(iters):
        sweep = _sequential_sweep(rng, n_pts, accesses_per_page) + centroid_pages
        cent = rng.integers(0, centroid_pages, size=sweep.shape[0] // 4)
        merged = np.empty(sweep.shape[0] + cent.shape[0], dtype=np.int64)
        merged[::5] = cent[: merged[::5].shape[0]]
        mask = np.ones(merged.shape[0], dtype=bool)
        mask[::5] = False
        merged[mask] = sweep[: mask.sum()]
        parts.append(merged)
    pages = np.concatenate(parts)
    loops = np.array([p.shape[0] for p in parts], dtype=np.int64)
    return Trace("kmeans", pages.astype(np.int32), num_pages, loops)


def hotspot(seed: int = 0, grid: int = 64, iters: int = 20,
            accesses_per_page: int = 4) -> Trace:
    """2-D stencil: row-major sweeps; each page touched with its row
    neighbours (short reuse) and revisited every iteration (long reuse)."""
    rng = np.random.default_rng(seed + 6)
    num_pages = grid * grid
    rows = np.arange(num_pages, dtype=np.int64).reshape(grid, grid)
    parts = []
    for _ in range(iters):
        sweep = []
        for r in range(grid):
            row = np.repeat(rows[r], accesses_per_page)
            # neighbour touches: previous row (stencil dependence)
            if r > 0:
                nb = rows[r - 1]
                row = np.stack([row[: grid * accesses_per_page],
                                np.repeat(nb, accesses_per_page)], axis=1
                               ).reshape(-1)[: row.shape[0]]
            sweep.append(row)
        parts.append(np.concatenate(sweep))
    pages = np.concatenate(parts)
    loops = np.array([p.shape[0] for p in parts], dtype=np.int64)
    return Trace("hotspot", pages.astype(np.int32), num_pages, loops)


def bfs(seed: int = 0, num_pages: int = 4096, num_accesses: int = 320000,
        frontier_frac: float = 0.1) -> Trace:
    """Frontier-random graph traversal: accesses nearly random over the
    footprint with a slowly drifting frontier window."""
    rng = np.random.default_rng(seed + 7)
    n_levels = 16
    per = num_accesses // n_levels
    parts = []
    for lvl in range(n_levels):
        centre = rng.integers(0, num_pages)
        width = max(64, int(num_pages * frontier_frac * (1 + lvl / 4)))
        local = (centre + rng.integers(0, width, size=per // 2)) % num_pages
        rand = rng.integers(0, num_pages, size=per - local.shape[0])
        mix = np.concatenate([local, rand])
        rng.shuffle(mix)
        parts.append(mix)
    pages = np.concatenate(parts)
    loops = np.array([p.shape[0] for p in parts], dtype=np.int64)
    return Trace("bfs", pages.astype(np.int32), num_pages, loops)


def bptree(seed: int = 0, num_pages: int = 4096, lookups: int = 40000,
           levels: int = 4) -> Trace:
    """B+tree lookups: each lookup touches one page per level; level-l page
    chosen from an exponentially growing region (root hot, leaves random)."""
    rng = np.random.default_rng(seed + 8)
    bounds = np.cumsum([max(1, num_pages // (16 ** (levels - l)))
                        for l in range(levels)])
    bounds = np.clip(bounds, 1, num_pages)
    cols = []
    lo = 0
    for l in range(levels):
        hi = int(bounds[l])
        cols.append(rng.integers(lo, max(lo + 1, hi), size=lookups))
        lo = hi
    pages = np.stack(cols, axis=1).reshape(-1)
    loops = np.full(8, pages.shape[0] // 8, dtype=np.int64)
    return Trace("bptree", pages.astype(np.int32), num_pages, loops)


TRACE_GENERATORS: Dict[str, Callable[..., Trace]] = {
    "backprop": backprop,
    "quicksilver": quicksilver,
    "lud": lud,
    "cpd": cpd,
    "pennant": pennant,
    "kmeans": kmeans,
    "hotspot": hotspot,
    "bfs": bfs,
    "bptree": bptree,
}


def available_traces() -> List[str]:
    return sorted(TRACE_GENERATORS)


def generate(name: str, seed: int = 0, **kw) -> Trace:
    """Generate the named application trace deterministically."""
    try:
        gen = TRACE_GENERATORS[name]
    except KeyError as e:
        raise ValueError(
            f"unknown trace {name!r}; available: {available_traces()}") from e
    return gen(seed=seed, **kw)
