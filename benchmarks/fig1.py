"""Fig. 1: performance of Table-I frequencies vs the optimal frequency vs
Cori, for reactive and predictive page schedulers (paper SIII-A / SV-A).

Output per (app, scheduler): slowdown-vs-optimal for each Table-I system,
for Cori's chosen frequency, and the data moved (% of footprint)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import APPS, SCHEDS, save_json
from repro.core import (SimConfig, bin_trace, generate, simulate, study)


def run(apps=APPS, quick: bool = False):
    apps = apps[:4] if quick else apps
    rows = []
    for app in apps:
        trace = generate(app)
        bins = bin_trace(trace)
        for sched in SCHEDS:
            st = study(app, sched)
            gaps = st.table_i_slowdowns()
            moved = {}
            for name in gaps:
                from repro.core import table_i_periods_for
                p = table_i_periods_for(bins.num_accesses)[name]
                r = simulate(bins, p, sched)
                moved[name] = r.data_moved_pages / bins.num_pages
            r_cori = simulate(bins, int(st.cori.chosen_period), sched)
            rows.append({
                "app": app, "scheduler": sched,
                "optimal_period": st.optimal_period,
                "optimal_runtime": st.optimal_runtime,
                "cori_period": st.cori.chosen_period,
                "cori_slowdown": st.cori_slowdown_vs_optimal,
                "cori_data_moved_frac": r_cori.data_moved_pages / bins.num_pages,
                "table_i_slowdown": gaps,
                "table_i_data_moved_frac": moved,
            })
    worst = max(max(r["table_i_slowdown"].values()) for r in rows)
    mean_cori = float(np.mean([r["cori_slowdown"] for r in rows]))
    mean_best_fixed = float(np.mean(
        [min(r["table_i_slowdown"].values()) for r in rows]))
    mean_worst_fixed = float(np.mean(
        [max(r["table_i_slowdown"].values()) for r in rows]))
    summary = {
        "rows": rows,
        "worst_fixed_gap": worst,
        "mean_cori_slowdown": mean_cori,
        "mean_best_fixed_slowdown": mean_best_fixed,
        "mean_worst_fixed_slowdown": mean_worst_fixed,
    }
    save_json("fig1", summary)
    return summary


if __name__ == "__main__":
    s = run()
    print(f"mean cori slack {s['mean_cori_slowdown']:.2%}; fixed-frequency "
          f"gap {s['mean_best_fixed_slowdown']:.2%}.."
          f"{s['mean_worst_fixed_slowdown']:.2%} (worst {s['worst_fixed_gap']:.0%})")
