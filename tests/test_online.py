"""Online Cori: streaming reuse collection, closed-loop tuning, live periods.

Covers the tentpole path end to end: StreamingReuseCollector vs the batch
histogram, the OnlineTuner state machine, live period changes in the
TieringManager, online_replay on phase-shifted workloads, and the serving
engine's per-step mass hook + sampling PRNG regression."""
import dataclasses

import numpy as np
import pytest

from repro.core import OnlineTuner, StreamingReuseCollector
from repro.memtier import (TierConfig, TieringManager, cori_tune_period,
                           interleaved_resident, online_replay, replay)
from repro.memtier import workload as W

CFG = TierConfig(hbm_pages=16, period_steps=8)


# ---------------------------------------------------------------------------
# streaming reuse collector
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wl_name", ["attention_sink", "periodic_context",
                                     "random_lookup"])
def test_streaming_histogram_matches_batch(wl_name):
    """On a static workload the sliding-window histogram (window covering
    the whole run) equals the batch histogram over the full access log."""
    wl = getattr(W, wl_name)(200, 64)
    mgr = replay(wl, CFG)
    batch = mgr.reuse_histogram(bin_width=4)
    col = StreamingReuseCollector(64, window=None, bin_width=4)
    for t in range(wl.shape[0]):
        col.observe_mass(wl[t], CFG.access_threshold)
    stream = col.histogram()
    np.testing.assert_array_equal(batch.values, stream.values)
    np.testing.assert_array_equal(batch.counts, stream.counts)
    # an ample finite window must agree as well
    col2 = StreamingReuseCollector(64, window=10 * wl.shape[0], bin_width=4)
    for t in range(wl.shape[0]):
        col2.observe_mass(wl[t], CFG.access_threshold)
    s2 = col2.histogram()
    np.testing.assert_array_equal(batch.values, s2.values)
    np.testing.assert_array_equal(batch.counts, s2.counts)


def test_streaming_window_evicts_old_phase():
    """Gaps older than the window fall out: after a phase change the
    histogram only reflects the recent reuse distance."""
    col = StreamingReuseCollector(8, window=40, bin_width=1)
    # phase 1: page 0 re-accessed every 2 steps, for 60 steps
    for t in range(60):
        col.observe(np.array([0]) if t % 2 == 0 else np.array([], np.int64))
    # phase 2: page 1 re-accessed every 5 steps, for 60 steps
    for t in range(60, 120):
        col.observe(np.array([1]) if t % 5 == 0 else np.array([], np.int64))
    h = col.histogram()
    assert h.num_bins >= 1
    assert set(np.unique(h.values)) == {5.0}, "phase-1 gaps must be evicted"


def test_streaming_reset():
    col = StreamingReuseCollector(4, bin_width=1)
    for _ in range(5):
        col.observe(np.array([0, 1]))
    assert col.num_samples > 0
    col.reset()
    assert col.num_samples == 0 and col.step == 0
    assert (col.last_access == -1).all()


# ---------------------------------------------------------------------------
# OnlineTuner state machine
# ---------------------------------------------------------------------------


def _drive(tuner, steps, ids_fn, cost_fn):
    for t in range(steps):
        tuner.on_step(accessed_ids=ids_fn(t), cost=cost_fn(tuner.period))
    return tuner


def test_online_tuner_trials_pick_best_candidate():
    """Accessed ids with a 4-step reuse gap give DR=4; a cost curve with its
    minimum at period 8 must make the tuner hold at 8."""
    tuner = OnlineTuner(64, default_period=2, profile_steps=32,
                        trial_steps=16, horizon_steps=64, bin_width=1,
                        patience=3)
    # page 0 re-accessed every 4 steps; filler pages reuse only at gap 63
    ids = lambda t: np.array([0]) if t % 4 == 0 else np.array([1 + (t % 63)])
    cost = lambda p: abs(p - 8) + 1.0
    _drive(tuner, 400, ids, cost)
    assert tuner.state == OnlineTuner.HOLD
    assert tuner.period == 8
    assert tuner.dominant_reuse == pytest.approx(4.0, abs=1.0)
    assert tuner.converged_at is not None


def test_online_tuner_drift_triggers_reprofile():
    tuner = OnlineTuner(8, default_period=2, profile_steps=16, trial_steps=8,
                        horizon_steps=32, bin_width=1, drift_ratio=1.3)
    ids = lambda t: np.array([t % 4])
    _drive(tuner, 200, ids, lambda p: 1.0)
    assert tuner.state == OnlineTuner.HOLD
    cycles = tuner.retunes
    # cost regresses 10x -> after drift_patience windows the detector must
    # leave HOLD and work through a fresh PROFILE -> TRIAL cycle
    _drive(tuner, 200, ids, lambda p: 10.0)
    assert tuner.retunes > cycles


def test_hold_window_aligns_to_period_no_false_drift():
    """Regression: a held period that does not divide trial_steps must not
    alias against the measurement window.  A stable workload whose cost has
    a migration burst every `period` steps showed oscillating window costs
    (1 vs 2 bursts per window) and re-profiled forever."""
    tuner = OnlineTuner(64, default_period=4, profile_steps=40,
                        trial_steps=32, horizon_steps=44, bin_width=1)
    # page 0 reused every 20 steps -> DR=20 -> single-candidate ladder [20]
    ids = lambda t: np.array([0]) if t % 20 == 0 else np.array([1 + (t % 63)])
    # cost burst at every period boundary, flat otherwise
    cost = lambda t: 17.0 if t % 20 == 0 else 1.0
    for t in range(2000):
        tuner.on_step(accessed_ids=ids(t), cost=cost(t))
    assert tuner.period == 20
    assert tuner.state == OnlineTuner.HOLD
    assert tuner.retunes == 1, "stable workload must not re-profile"


def test_online_tuner_improvement_triggers_reprofile():
    """Symmetric drift: a *sustained improvement* beyond improve_ratio is a
    phase change too -- the cheaper mix may admit an even better period, so
    the tuner must re-profile rather than hold the stale choice."""
    tuner = OnlineTuner(8, default_period=2, profile_steps=16, trial_steps=8,
                        horizon_steps=32, bin_width=1, improve_ratio=2.0)
    ids = lambda t: np.array([t % 4])
    _drive(tuner, 200, ids, lambda p: 10.0)
    assert tuner.state == OnlineTuner.HOLD
    cycles = tuner.retunes
    # cost improves 10x sustained -> must leave HOLD and re-tune
    _drive(tuner, 200, ids, lambda p: 1.0)
    assert tuner.retunes > cycles


def test_online_tuner_improvement_detector_can_be_disabled():
    tuner = OnlineTuner(8, default_period=2, profile_steps=16, trial_steps=8,
                        horizon_steps=32, bin_width=1, improve_ratio=None)
    ids = lambda t: np.array([t % 4])
    _drive(tuner, 200, ids, lambda p: 10.0)
    cycles = tuner.retunes
    _drive(tuner, 400, ids, lambda p: 1.0)
    assert tuner.retunes == cycles, "regression-only detector must hold"


def test_online_tuner_empty_reuse_keeps_default():
    """No page is ever re-accessed: the tuner must not crash and must keep
    the default period."""
    tuner = OnlineTuner(64, default_period=4, profile_steps=8, trial_steps=4)
    for t in range(32):
        tuner.on_step(accessed_ids=np.array([t]), cost=1.0)
    assert tuner.period == 4
    assert tuner.state == OnlineTuner.PROFILE


# ---------------------------------------------------------------------------
# live tiering period
# ---------------------------------------------------------------------------


def test_set_period_changes_tier_cadence():
    mgr = TieringManager(16, dataclasses.replace(CFG, hbm_pages=4,
                                                 period_steps=4))
    resident = interleaved_resident(16, 4)
    mass = np.zeros(16, np.float32)
    mass[:2] = 1.0
    tiers = []
    for t in range(16):
        mgr.on_step(mass, resident)
        if mgr.maybe_tier_symbolic(resident):
            tiers.append(t)
        if t == 7:
            mgr.set_period(2)
    assert tiers == [3, 7, 9, 11, 13, 15]


def test_online_replay_profile_only_matches_fixed_replay():
    """A tuner that never leaves PROFILE must leave the manager identical to
    a fixed-period replay (the closed loop is a no-op until it acts)."""
    wl = W.attention_sink(100, 64)
    tuner = OnlineTuner(64, default_period=CFG.period_steps,
                        profile_steps=10 ** 6,
                        access_threshold=CFG.access_threshold)
    mgr_on, _ = online_replay(wl, CFG, tuner=tuner)
    mgr_fix = replay(wl, CFG)
    assert mgr_on.modeled_time == mgr_fix.modeled_time
    assert mgr_on.migrations == mgr_fix.migrations


# ---------------------------------------------------------------------------
# closed loop on phase-shifted workloads (the tentpole acceptance)
# ---------------------------------------------------------------------------


def _phase_shifted(phase=600, n=64):
    # drift_every=1: the hot set moves every step in phase B, so the best
    # period there is unambiguously the shortest (no tier/drift aliasing)
    return np.concatenate([W.random_lookup(phase, n, seed=0),
                           W.attention_sink(phase, n, seed=1, drift_every=1)])


def test_online_retunes_and_reaches_best_fixed_steady_state():
    """Acceptance: on a phase-shifted workload the online tuner re-tunes the
    period and its steady-state cost ends within 5% of the best fixed
    period's cost over the same final window."""
    wl = _phase_shifted()
    steps = wl.shape[0]
    lo, hi = steps - 100, steps
    mgr, tuner = online_replay(wl, CFG)
    assert tuner.retunes >= 2, "phase shift must trigger at least one re-tune"
    assert tuner.converged_at is not None and tuner.converged_at < steps
    online_steady = float(np.mean(np.asarray(tuner.cost_log)[lo - hi:]))

    def fixed_window(p):
        c = dataclasses.replace(CFG, period_steps=p)
        return (replay(wl[:hi], c).modeled_time
                - replay(wl[:lo], c).modeled_time) / (hi - lo)

    best_fixed = min(fixed_window(p) for p in (1, 2, 4, 8, 16, 32, 64, 200))
    assert online_steady <= 1.05 * best_fixed


def test_online_converges_near_offline_choice_per_phase():
    """After the last re-tune the online period must sit within the same
    cost neighbourhood as the offline Tuner's choice for the final phase."""
    wl = _phase_shifted()
    phase_b = wl[600:]
    _, tuner = online_replay(wl, CFG)
    off_res, _ = cori_tune_period(phase_b, CFG)

    def steady(p):
        c = dataclasses.replace(CFG, period_steps=max(1, int(round(p))))
        full = replay(phase_b, c).modeled_time
        head = replay(phase_b[:-100], c).modeled_time
        return (full - head) / 100.0

    online_cost = steady(tuner.period)
    offline_cost = steady(off_res.chosen_period)
    assert online_cost <= 1.10 * offline_cost


def test_online_beats_stale_offline_tuning():
    """Tune-once-on-phase-A Cori goes stale after the shift; the closed loop
    must end the run strictly cheaper in steady state."""
    wl = _phase_shifted()
    steps = wl.shape[0]
    lo, hi = steps - 100, steps
    _, tuner = online_replay(wl, CFG)
    online_steady = float(np.mean(np.asarray(tuner.cost_log)[lo - hi:]))
    off_res, _ = cori_tune_period(wl[:600], CFG)
    c = dataclasses.replace(CFG,
                            period_steps=max(1, int(round(off_res.chosen_period))))
    off_steady = (replay(wl[:hi], c).modeled_time
                  - replay(wl[:lo], c).modeled_time) / (hi - lo)
    assert online_steady < off_steady


# ---------------------------------------------------------------------------
# serving engine: mass hook + sampling PRNG regression
# ---------------------------------------------------------------------------


def test_sample_prng_deterministic_and_folds():
    """Regression for the `key / 1` bug: temperature sampling must accept a
    PRNG key, be deterministic for a fixed key, and differ across fold_in
    steps."""
    import jax
    from repro.serve.engine import _sample
    logits = jax.random.normal(jax.random.PRNGKey(7), (4, 128))
    key = jax.random.PRNGKey(0)
    a = _sample(logits, key, temperature=1.0)
    b = _sample(logits, key, temperature=1.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    draws = [np.asarray(_sample(logits, jax.random.fold_in(key, i), 1.0))
             for i in range(8)]
    assert any(not np.array_equal(draws[0], d) for d in draws[1:]), \
        "folded keys must change the sample"
    # greedy path ignores the key entirely
    g = _sample(logits, key, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(g),
                                  np.argmax(np.asarray(logits), axis=-1))


def test_generate_with_temperature_is_deterministic():
    """End-to-end sampling path: same key -> same tokens (would crash with
    the old `key / 1` PRNG bug)."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    cfg = C.reduced("stablelm-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    t1 = generate(params, cfg, prompts, steps=5, temperature=0.8,
                  key=jax.random.PRNGKey(3))
    t2 = generate(params, cfg, prompts, steps=5, temperature=0.8,
                  key=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_monitored_generate_on_mass_hook():
    """The per-step hook sees exactly the masses the engine returns, in
    order -- the contract the online tiering loop relies on."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import monitored_generate
    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                 cfg.vocab_size)
    seen = []
    toks, mass = monitored_generate(params, cfg, prompts, steps=6,
                                    page_size=4,
                                    on_mass=lambda i, m: seen.append((i, m)))
    assert [i for i, _ in seen] == list(range(mass.shape[0]))
    np.testing.assert_array_equal(np.stack([m for _, m in seen]), mass)


# ---------------------------------------------------------------------------
# cost-accounting regressions (adversarial-traffic hardening PR)
# ---------------------------------------------------------------------------


def test_cost_log_is_uniformly_per_step():
    """The cost log must hold per-step costs: raw observation costs would
    mix per-token and per-macro magnitudes whenever dt varies."""
    tuner = OnlineTuner(8, default_period=4)
    tuner.on_step(accessed_ids=np.array([0]), cost=3.0, dt=1)
    tuner.on_step(accessed_ids=np.array([1]), cost=8.0, dt=4)
    assert list(tuner.cost_log)[-2:] == [3.0, 2.0]


def test_trial_tail_straddle_prorated_under_macro_dt():
    """A macro observation straddling the head/tail boundary must charge
    only its tail overlap to the tail mean (charging the whole macro cost
    biases the ranking for windows that are not a multiple of dt)."""
    tuner = OnlineTuner(8, default_period=5, trial_steps=10,
                        guard_ratio=None, var_cv=None)
    tuner.state = OnlineTuner.TRIAL
    tuner.candidates = np.array([5.0])
    tuner.tried = []
    tuner._trial_idx = 0
    tuner._arm_window()
    assert tuner._win_target == 10 and tuner._tail_begin == 5
    # obs spans [0,4): head only.  obs spans [4,8): 3 of 4 steps in the
    # tail.  obs spans [8,12): all 4 in the tail, window done.
    tuner.on_step(accessed_ids=np.array([0]), cost=100.0, dt=4)
    tuner.on_step(accessed_ids=np.array([1]), cost=8.0, dt=4)
    tuner.on_step(accessed_ids=np.array([2]), cost=4.0, dt=4)
    assert len(tuner.tried) == 1
    # tail cost = 8 * (3/4) + 4, over 7 tail steps
    assert tuner.tried[0][1] == pytest.approx((8.0 * 0.75 + 4.0) / 7.0)


def test_clean_period_switch_does_not_fake_drift():
    """The first HOLD window inherits the residency transient from the
    period switch; baselining it makes every later (clean, cheaper) window
    read as a fake sustained improvement and re-profiles a perfectly
    stable workload.  The tuner must skip that window before baselining."""
    tuner = OnlineTuner(64, default_period=4, profile_steps=40,
                        trial_steps=32, horizon_steps=44, bin_width=1)
    ids = lambda t: np.array([0]) if t % 20 == 0 else np.array([1 + (t % 63)])
    hold_at = None
    for t in range(2000):
        if hold_at is None and tuner.state == OnlineTuner.HOLD:
            hold_at = t
        # a 15-step cost transient right after the winning period switch
        c = 30.0 if hold_at is not None and t - hold_at < 15 else 1.0
        tuner.on_step(accessed_ids=ids(t), cost=c)
    assert tuner.period == 20
    assert tuner.state == OnlineTuner.HOLD
    assert tuner.retunes == 1, "a clean switch must not fake drift/improve"
    assert tuner.baseline_cost == pytest.approx(1.0)
