"""End-to-end Cori pipeline over the simulator (paper Fig. 4 wiring).

Ties the Reuse Collector -> Frequency Generator -> Tuner loop to the
trace-driven hybrid-memory simulator, and provides the comparison harness
against Table-I fixed frequencies and the Eq.-3 step baselines.  This module
is what the figure benchmarks and the headline-claim tests drive.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import baselines as bl
from repro.core import cori, reuse, sim
from repro.core.traces import Trace, generate

__all__ = [
    "CoriRun",
    "run_cori",
    "optimal_runtime",
    "table_i_runtimes",
    "baseline_trials",
    "baseline_trials_all",
    "AppStudy",
    "study",
]


@dataclasses.dataclass(frozen=True)
class CoriRun:
    trace: str
    scheduler: str
    dominant_reuse: float
    result: cori.TuneResult

    @property
    def chosen_period(self) -> float:
        return self.result.chosen_period

    @property
    def trials(self) -> int:
        return self.result.trials


def _evaluator(bins: sim.TraceBins, scheduler: str, cfg: sim.SimConfig):
    cache: Dict[int, float] = {}

    def evaluate(period: float) -> float:
        key = max(1, int(round(period / bins.block))) * bins.block
        if key not in cache:
            cache[key] = sim.simulate(bins, key, scheduler, cfg).runtime
        return cache[key]

    return evaluate


def run_cori(bins: sim.TraceBins, trace: Trace, scheduler: str,
             cfg: sim.SimConfig = sim.SimConfig(),
             collector: str = "trace", patience: int = 2,
             max_trials: Optional[int] = None,
             significance: float = 0.05) -> CoriRun:
    """Full Cori loop: collect reuse -> DR -> candidate ladder -> tune."""
    if collector == "trace":
        hist = reuse.reuse_distance_histogram(trace.pages, bin_width=bins.block * 10)
    elif collector == "loops":
        hist = reuse.loop_duration_histogram(trace.loop_durations,
                                             bin_width=bins.block * 10)
    else:
        raise ValueError("collector must be 'trace' or 'loops'")
    hist = reuse.prune_insignificant(hist, significance)
    dr = cori.dominant_reuse(hist)
    cands = cori.candidate_periods(dr, float(bins.num_accesses),
                                   min_period=float(bins.block))
    tuner = cori.Tuner(_evaluator(bins, scheduler, cfg), patience=patience,
                       max_trials=max_trials)
    return CoriRun(trace.name, scheduler, dr, tuner.run(cands))


def optimal_runtime(bins: sim.TraceBins, scheduler: str,
                    cfg: sim.SimConfig = sim.SimConfig(),
                    max_candidates: int = 96) -> Dict[str, float]:
    """Best runtime over the (subsampled-)exhaustive period space."""
    periods = sim.exhaustive_periods(bins, max_candidates)
    res = sim.sweep(bins, periods, scheduler, cfg)
    best_p = min(res, key=lambda p: res[p].runtime)
    return {"period": float(best_p), "runtime": res[best_p].runtime}


def table_i_runtimes(bins: sim.TraceBins, scheduler: str,
                     cfg: sim.SimConfig = sim.SimConfig()) -> Dict[str, sim.SimResult]:
    periods = bl.table_i_periods_for(bins.num_accesses)
    return {name: sim.simulate(bins, p, scheduler, cfg)
            for name, p in periods.items()}


def baseline_trials_all(bins: sim.TraceBins, scheduler: str,
                        cfg: sim.SimConfig = sim.SimConfig(),
                        timestep: Optional[int] = None, seeds: int = 5,
                        tol: float = 0.005) -> Dict[str, float]:
    """Trials-to-best for every Eq.-3 baseline order (one shared sweep; the
    three orders are permutations of the same candidate runtimes)."""
    timestep = timestep or max(bins.block, bins.num_accesses // 128)
    ev = _evaluator(bins, scheduler, cfg)
    cands = bl.base_candidates(bins.num_accesses, timestep)
    rts = np.array([ev(float(p)) for p in cands])
    out = {
        "base-right": float(cori.trials_to_best(rts, tol)),
        "base-left": float(cori.trials_to_best(rts[::-1], tol)),
    }
    rnd = []
    for s in range(seeds):
        perm = np.random.default_rng(s).permutation(rts.shape[0])
        rnd.append(cori.trials_to_best(rts[perm], tol))
    out["base-random"] = float(np.mean(rnd))
    return out


def baseline_trials(bins: sim.TraceBins, scheduler: str, order: str,
                    cfg: sim.SimConfig = sim.SimConfig(),
                    timestep: Optional[int] = None, seeds: int = 5,
                    tol: float = 0.005) -> float:
    """Trials-to-best for one Eq.-3 baseline order."""
    return baseline_trials_all(bins, scheduler, cfg, timestep, seeds, tol)[order]


@dataclasses.dataclass(frozen=True)
class AppStudy:
    """Everything the paper reports for one (application, scheduler) cell."""
    trace: str
    scheduler: str
    optimal_period: float
    optimal_runtime: float
    cori: CoriRun
    cori_trials_to_best: int
    table_i: Dict[str, float]          # name -> runtime

    @property
    def cori_slowdown_vs_optimal(self) -> float:
        return self.cori.result.best_runtime_tried / self.optimal_runtime - 1.0

    def table_i_slowdowns(self) -> Dict[str, float]:
        return {k: v / self.optimal_runtime - 1.0 for k, v in self.table_i.items()}


def study(name: str, scheduler: str, cfg: sim.SimConfig = sim.SimConfig(),
          seed: int = 0, collector: str = "trace", **trace_kw) -> AppStudy:
    trace = generate(name, seed=seed, **trace_kw)
    bins = sim.bin_trace(trace)
    opt = optimal_runtime(bins, scheduler, cfg)
    crun = run_cori(bins, trace, scheduler, cfg, collector=collector)
    # Fig. 5a metric: trials until Cori has *tried* its ladder's best value.
    ev = _evaluator(bins, scheduler, cfg)
    ladder_rts = [ev(float(p)) for p in crun.result.candidates[
        : max(crun.result.trials * 4, 8)]]
    ttb = cori.trials_to_best(ladder_rts)
    t1 = {k: v.runtime for k, v in table_i_runtimes(bins, scheduler, cfg).items()}
    return AppStudy(name, scheduler, opt["period"], opt["runtime"], crun,
                    ttb, t1)
