"""Cori: Frequency Generator + Tuner (paper §IV-B, §IV-C).

Dominant reuse (Eq. 1), with reuses sorted ascending so that the extra
``(N - i)`` weight favours shorter reuse distances:

            sum_i (N - i) * repeat_i * reuse_i
    DR  =  ------------------------------------        i = 1..N
            sum_i (N - i) * repeat_i

Candidate periods (Eq. 2):  [DR, 2*DR, 3*DR, ..., Runtime/2], emitted
shortest period first (highest frequency first) -- this priority ordering is
essential to Cori's trial efficiency (§IV-B).

The Tuner (§IV-C) trials candidates in order against the actual system (here:
the hybrid-memory simulator, or any callable ``period -> runtime``) and stops
either when a trial budget is hit or when performance stops improving
("performance ... shows no significant variation from the last trial",
§IV-D).

Invariants of the online state machine (pinned by tests/test_online.py and
tests/test_sched.py):

  * **Trial-window alignment.**  Every cost window (TRIAL and HOLD) is
    rounded up to a whole multiple of the period being measured, so each
    window contains the same number of tiering events.  Without this, a
    window boundary aliasing against the period makes per-step costs
    oscillate and fakes drift on a perfectly stable workload.  Trials rank
    by the window's *tail* half only -- the head absorbs the residency
    transient inherited from whatever period ran before.
  * **Page-ID recycling contract.**  ``forget_pages`` must be called when
    the serving scheduler frees a logical page ID, *before* the allocator
    may recycle it; a recycled ID's first access by its new owner must
    never pair with the old owner's last access into a bogus reuse gap.
  * **Mass-domain stability.**  The collector thresholds page masses into
    accessed sets.  The fully-paged serving path feeds masses aggregated
    over ALL attention layers (head-normalised, layer-averaged);
    ``rel_threshold`` switches the cut to a fraction of the step's peak
    mass so the accessed-set size does not drift with batch occupancy.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.reuse import ReuseHistogram, StreamingReuseCollector
from repro.obs import telemetry as _obs

__all__ = [
    "dominant_reuse",
    "candidate_periods",
    "TuneResult",
    "Tuner",
    "OnlineTuner",
    "trials_to_best",
]


def dominant_reuse(hist: ReuseHistogram) -> float:
    """Eq. 1: weighted average of reuses, biased towards short ones."""
    if hist.num_bins == 0:
        raise ValueError("empty reuse histogram: nothing to tune from")
    order = np.argsort(hist.values)
    reuse = hist.values[order].astype(np.float64)
    repeat = hist.counts[order].astype(np.float64)
    n = reuse.shape[0]
    if n == 1:
        return float(reuse[0])
    w = (n - np.arange(1, n + 1, dtype=np.float64)) * repeat  # (N - i) * repeat_i
    denom = w.sum()
    if denom <= 0:  # degenerate: all weight on the longest reuse
        return float(reuse[-1])
    return float((w * reuse).sum() / denom)


def candidate_periods(dr: float, runtime: float, max_candidates: int = 64,
                      min_period: float = 1.0) -> np.ndarray:
    """Eq. 2: multiples of DR up to Runtime/2, shortest first.

    `runtime` and the returned periods are in whatever domain DR is measured
    in (requests for the simulator, seconds / decode-steps on a system).
    """
    dr = max(float(dr), float(min_period))
    hi = runtime / 2.0
    if dr > hi:
        return np.array([hi], dtype=np.float64)
    n = int(hi // dr)
    ks = np.arange(1, n + 1, dtype=np.float64)
    if n > max_candidates:
        # Keep the ladder's head exact (the critical low-multiples region),
        # thin the tail geometrically -- same endpoints as Eq. 2.
        head = ks[: max_candidates // 2]
        tail = np.unique(np.geomspace(head[-1] + 1, n,
                                      max_candidates - head.shape[0]).round())
        ks = np.concatenate([head, tail])
    return ks * dr


@dataclasses.dataclass(frozen=True)
class TuneResult:
    chosen_period: float
    chosen_runtime: float
    trials: int                      # trials actually executed
    tried_periods: np.ndarray
    tried_runtimes: np.ndarray
    candidates: np.ndarray           # full candidate ladder

    @property
    def best_runtime_tried(self) -> float:
        finite = self.tried_runtimes[np.isfinite(self.tried_runtimes)]
        return float(finite.min()) if finite.size else float("inf")


class Tuner:
    """Cori's Tuner: trial candidates in order, stop on no-improvement.

    Args:
      evaluate: callable(period) -> runtime (lower is better).  For the
        simulator this wraps `core.sim.simulate`; for the serving runtime it
        wraps a measured window of decode steps.
      patience: stop after this many consecutive non-improving trials
        (the flexible stopping policy of §IV-D).
      rel_tol: a trial must beat the best-so-far by this fraction to count
        as an improvement.
      max_trials: hard trial budget (None = whole ladder).
    """

    def __init__(self, evaluate: Callable[[float], float], patience: int = 2,
                 rel_tol: float = 0.01, max_trials: Optional[int] = None):
        self.evaluate = evaluate
        self.patience = patience
        self.rel_tol = rel_tol
        self.max_trials = max_trials

    def run(self, candidates: Sequence[float]) -> TuneResult:
        candidates = np.asarray(list(candidates), dtype=np.float64)
        if candidates.size == 0:
            raise ValueError(
                "empty candidate ladder: nothing to trial (Eq. 2 produced no "
                "periods -- check the reuse histogram / runtime horizon)")
        best_rt = np.inf
        best_p = float(candidates[0])
        tried_p: List[float] = []
        tried_rt: List[float] = []
        stale = 0
        for p in candidates:
            rt = float(self.evaluate(float(p)))
            tried_p.append(float(p))
            tried_rt.append(rt)
            # a NaN/inf runtime is a failed trial, never an improvement: it
            # must not become best_rt (NaN would poison every later
            # comparison) and counts as a stale trial like any non-improver
            if np.isfinite(rt) and rt < best_rt * (1.0 - self.rel_tol):
                best_rt, best_p, stale = rt, float(p), 0
            else:
                stale += 1
                if stale >= self.patience:
                    break
            if self.max_trials is not None and len(tried_p) >= self.max_trials:
                break
        if not np.isfinite(best_rt):
            # every trial came back non-finite: keep the ladder head but
            # report an infinite runtime rather than adopting a poisoned
            # NaN as the "measured" chosen_runtime
            best_rt, best_p = float("inf"), tried_p[0]
        return TuneResult(best_p, best_rt, len(tried_p),
                          np.asarray(tried_p), np.asarray(tried_rt), candidates)


class OnlineTuner:
    """Closed-loop Cori: profile -> trial -> hold, re-entered on drift.

    The offline ``Tuner`` needs an oracle ``evaluate(period)`` it can call at
    will (the simulator).  Inside a running system there is no oracle -- each
    candidate must be *lived through* for a window of decode steps while the
    system serves traffic.  The OnlineTuner is that state machine:

      PROFILE  feed a ``StreamingReuseCollector`` for ``profile_steps`` steps,
               then derive DR (Eq. 1) and the candidate ladder (Eq. 2) over
               the ``horizon_steps`` trial horizon.  Decode steps are already
               coarse, so reuse gaps bin at width 1 by default -- a wider
               bin floors DR (and hence the shortest candidate) at the bin
               centre, hiding period-1 ladders.
      TRIAL    live each candidate period for a window of decode steps, but
               rank candidates by the per-step cost of the window's *tail*
               half only: the head absorbs the residency transient the
               trial inherits from whatever ran before it (charging that
               transient to the candidate biases the ranking against
               whichever period is trialed first).  The offline Tuner's
               stopping rule (``rel_tol`` improvement, ``patience`` stale
               trials, ``max_trials`` budget) decides when to stop.
      HOLD     run at the winning period.  Every measurement window the
               per-step cost is compared against the post-tune baseline; a
               regression beyond ``drift_ratio`` sustained for
               ``drift_patience`` consecutive windows means the workload
               changed phase -> reset the collector and re-enter PROFILE.
               The detector is symmetric: a *sustained improvement* beyond
               ``improve_ratio`` (cost below baseline/improve_ratio for
               ``improve_patience`` windows) also re-profiles -- a cheaper
               phase may admit an even better period than the one tuned
               for the old, more expensive mix.  Set ``improve_ratio`` to
               ``None`` to restore the regression-only detector.

    Cost windows (TRIAL and HOLD) are rounded up to a whole multiple of the
    period being measured, so every window contains the same number of
    tiering events -- otherwise a window boundary that aliases against the
    period makes per-step costs oscillate and fakes drift on a perfectly
    stable workload.

    Three defenses harden the state machine against *adversarial* traffic
    (flash crowds, correlated bursts, abrupt mix inversions -- the hostile
    suite in ``core.traffic``):

      * **Cost-spike guardrail** (``guard_ratio``).  If a TRIAL window's
        running per-step tail cost blows past ``guard_ratio`` x the
        last *attested* cost (a completed sweep's winner or a clean HOLD
        baseline), the sweep is *aborted* -- the spiked
        candidate is never adopted; the tuner falls back to the cleanly
        ranked best (or the last-good period) and re-enters HOLD.  In
        HOLD, a window beyond the guard ratio is a burst, not a baseline:
        it is discarded rather than baselined or struck, and only
        ``drift_patience`` *consecutive* guard-level windows (a sustained
        regime change) force a re-profile.  Non-finite costs are treated
        as +inf so a NaN can never win a ladder or silently poison the
        baseline.
      * **Variance-scaled trial windows** (``var_cv``).  Trial windows
        whose per-period cost variance is high (coefficient of variation
        over whole-period buckets above ``var_cv``) double, up to
        ``var_max_factor`` x ``trial_steps``; the noisy segment becomes
        head (warmup) and the tail restarts, so a heavy-tailed burst does
        not de-noise into a wrong ranking.  Buckets span whole periods,
        so a stationary workload's within-period migration burst pattern
        does not read as variance.
      * **Warm re-tunes** (``warm_start``).  A drift/improve re-tune
        rebuilds the ladder from the *live* rolling collector window and
        goes straight to TRIAL (no PROFILE stage), exploring outward
        from the previous winner (bandit-style nearest-first) instead of
        shortest-first -- a mild phase change re-converges in
        ~``patience``+1 trials instead of paying a profile window plus a
        full sweep, while a large change still walks to the far end
        because every improvement resets the stopping rule.  Only the
        guard-strike escalation (a hostile regime change) pays the cold
        collector reset + PROFILE.

    Drive it one decode step at a time with ``on_step``; it returns the
    period the tiering runtime should use *now*.
    """

    PROFILE, TRIAL, HOLD = "profile", "trial", "hold"

    _obs_count = 0          # process-wide id counter for telemetry streams

    def __init__(self, n_pages: int, default_period: int = 8,
                 profile_steps: int = 64, trial_steps: int = 32,
                 horizon_steps: Optional[int] = None,
                 window: Optional[int] = None,
                 patience: int = 2, rel_tol: float = 0.01,
                 max_trials: Optional[int] = None,
                 drift_ratio: float = 1.3, drift_patience: int = 2,
                 improve_ratio: Optional[float] = 2.0,
                 improve_patience: Optional[int] = None,
                 bin_width: int = 1,
                 min_period: float = 1.0, access_threshold: float = 0.05,
                 rel_threshold: bool = False,
                 max_candidates: int = 16, cost_log_len: int = 4096,
                 guard_ratio: Optional[float] = 6.0,
                 var_cv: Optional[float] = 0.3,
                 var_max_factor: int = 4,
                 warm_start: bool = True,
                 actuation_lag: int = 0):
        self.collector = StreamingReuseCollector(
            n_pages, window=window or 4 * profile_steps, bin_width=bin_width)
        self.profile_steps = profile_steps
        self.trial_steps = trial_steps
        self.horizon_steps = horizon_steps or 2 * trial_steps
        self.patience = patience
        self.rel_tol = rel_tol
        self.max_trials = max_trials
        self.drift_ratio = drift_ratio
        self.drift_patience = drift_patience
        self.improve_ratio = improve_ratio
        self.improve_patience = (improve_patience if improve_patience
                                 is not None else drift_patience)
        self.min_period = min_period
        self.access_threshold = access_threshold
        self.rel_threshold = rel_threshold
        self.max_candidates = max_candidates
        self.guard_ratio = guard_ratio
        self.var_cv = var_cv
        self.var_max_factor = max(1, int(var_max_factor))
        self.warm_start = warm_start
        # extra HOLD transient windows to discard after a period switch:
        # a pipelined serving loop applies a new period one macro boundary
        # late (the stale-by-one hand-off), so the residency transient the
        # _hold_skip window absorbs stretches `actuation_lag` windows
        # further before the baseline is clean
        self.actuation_lag = max(0, int(actuation_lag))

        self.state = self.PROFILE
        self.period = int(default_period)
        self.step = 0
        self.dominant_reuse: Optional[float] = None
        self.candidates: np.ndarray = np.empty(0)
        self.tried: List[Tuple[float, float]] = []   # (period, cost/step)
        self.baseline_cost: Optional[float] = None
        self.retunes = 0          # completed PROFILE->TRIAL->HOLD cycles
        self.history: List[Tuple[int, int]] = []     # (step, period) changes
        self.converged_at: Optional[int] = None      # step of last HOLD entry
        # guardrail fallback: the last period attested by a clean sweep or
        # HOLD baseline, and the per-step cost it achieved (inf = nothing
        # attested yet, e.g. right after a phase-change re-profile)
        self.last_good_period = int(default_period)
        self.last_good_cost = float("inf")
        self.guard_trips = 0        # guard aborts + discarded HOLD windows
        self.window_extensions = 0  # variance-driven trial-window doublings
        # public rolling window of recent PER-STEP costs (bounded; read by
        # tests and benchmarks for cost-level asserts).  The flight
        # recorder's "tuner.cost_per_step" histogram sees the same stream
        # but keeps full-run quantiles in O(1) memory -- use the deque for
        # exact recent values, the histogram for distributional summaries.
        self.cost_log: "collections.deque[float]" = collections.deque(
            maxlen=cost_log_len)
        OnlineTuner._obs_count += 1
        #: short id tagging this instance's telemetry events ("t1", ...)
        self.obs_id = f"t{OnlineTuner._obs_count}"
        self._drift_strikes = 0
        self._improve_strikes = 0
        self._guard_strikes = 0
        # counts HOLD transient windows still to skip (int; bools coerce)
        self._hold_skip = 0
        self._resweep_pending = False
        self._warm_next = True
        # winner's attested trial cost from the most recent sweep: floors
        # the first clean HOLD baseline (one quiet window must not arm a
        # hair-trigger drift detector)
        self._sweep_cost: Optional[float] = None
        self._trial_idx = 0
        self._best_cost = np.inf
        self._best_period = self.period
        self._stale = 0
        self._win_cost = 0.0
        self._win_steps = 0
        self._tail_cost = 0.0
        self._tail_steps = 0
        self._win_target = self._cost_window()
        self._tail_begin = self._win_target - self._tail_window()
        # per-period cost buckets feeding the window-variance signal
        self._seg_sum = 0.0
        self._seg_sq = 0.0
        self._seg_n = 0
        self._bucket_cost = 0.0
        self._bucket_steps = 0

    # -- per-step entry point ------------------------------------------------
    def on_step(self, page_mass: Optional[np.ndarray] = None,
                cost: float = 0.0,
                accessed_ids: Optional[np.ndarray] = None,
                dt: int = 1) -> int:
        """Feed one observation (attention masses or accessed page ids, plus
        the measured cost); returns the period to tier at.

        ``dt`` is the number of token-steps the observation spans (the
        macro length when the serving loop samples once per movement
        period).  The tuner's clock, reuse gaps, and profile/trial
        windows all advance by ``dt``, so the derived period stays in
        the same token-step units it is actuated in -- ``cost`` must
        then be the total for those ``dt`` steps (window means stay
        per-step)."""
        dt = max(1, int(dt))
        if accessed_ids is not None:
            self.collector.observe(accessed_ids, dt=dt)
        elif page_mass is not None:
            self.collector.observe_mass(page_mass, self.access_threshold,
                                        relative=self.rel_threshold, dt=dt)
        cost = float(cost)
        if not np.isfinite(cost):
            # a NaN/inf measurement is hostile garbage: pin it to +inf so
            # it reads as "arbitrarily expensive" (the guardrail catches
            # it) instead of silently propagating NaN through every
            # window mean and comparison
            cost = float("inf")
        per_step = cost / dt
        self._win_cost += cost
        self._win_steps += dt
        # the log is uniformly PER-STEP: raw observation costs would mix
        # per-token and per-macro magnitudes whenever dt varies
        self.cost_log.append(per_step)
        if (r := _obs.RECORDER).enabled:
            r.observe("tuner.cost_per_step", per_step)
        self.step += dt
        if self.state == self.PROFILE:
            if self._win_steps >= self.profile_steps:
                self._begin_trials()
        elif self.state == self.TRIAL:
            # tail accounting: the observation spans [win_steps - dt,
            # win_steps); an observation straddling the head/tail boundary
            # charges only its tail overlap (charging its whole macro cost
            # to the tail biases the tail mean under macro dt > 1)
            overlap = self._win_steps - max(self._win_steps - dt,
                                            self._tail_begin)
            if overlap > 0:
                self._tail_cost += cost * (overlap / dt)
                self._tail_steps += overlap
                # variance buckets also cover the tail only: the head's
                # residency transient is *expected* to be expensive, and
                # letting it into the buckets would read every period
                # switch as heavy-tailed noise worth extending over
                self._observe_period_bucket(per_step, overlap)
            if self._guard_tripped():
                self._trip_guard()
            elif self._win_steps >= self._win_target:
                if self._should_extend():
                    self._extend_window()
                else:
                    self._finish_trial()
        else:  # HOLD
            if self._win_steps >= self._win_target:
                self._check_drift()
        return self.period

    def _cost_window(self) -> int:
        """Measurement window: >= trial_steps, rounded up to a whole multiple
        of the current period so every window sees the same number of
        tiering events (no aliasing between window and period)."""
        p = max(1, self.period)
        return -(-self.trial_steps // p) * p

    def _tail_window(self) -> int:
        """Measured tail of a trial window: ~half of it, still a whole
        multiple of the period (the head is warmup for the residency
        transient)."""
        p = max(1, self.period)
        return max(1, (self._cost_window() // (2 * p))) * p

    # -- guardrail + variance machinery --------------------------------------
    def _observe_period_bucket(self, per_step: float, dt: int) -> None:
        """Accumulate the observation into whole-period cost buckets (the
        variance signal).  Buckets span exactly one period, so a stationary
        workload's within-period burst structure (a migration burst at
        every tiering boundary) contributes ZERO across-bucket variance;
        only bucket-to-bucket change -- a flash crowd, a correlated burst
        -- reads as noise worth extending the window over.  Always
        accumulated (even with ``var_cv=None``): the guardrail's
        burst-vs-regime verdict reads the same buckets."""
        p = max(1, self.period)
        rem = dt
        while rem > 0:
            take = min(rem, p - self._bucket_steps)
            self._bucket_cost += per_step * take
            self._bucket_steps += take
            rem -= take
            if self._bucket_steps >= p:
                x = self._bucket_cost
                self._seg_sum += x
                self._seg_sq += x * x
                self._seg_n += 1
                self._bucket_cost = 0.0
                self._bucket_steps = 0

    def _guard_ref(self) -> float:
        """Per-step cost the guardrail compares against: the last cost
        *attested* by a completed sweep or a clean HOLD baseline.  The
        in-sweep best is deliberately NOT used -- candidates are measured
        under different stretches of traffic, and a merely-expensive
        candidate must rank (and lose) normally rather than abort the
        sweep against a sibling that happened to be measured cheaply.
        Before anything is attested (first sweep, post-reset) the ref is
        inf and the sweep runs unguarded."""
        return self.last_good_cost

    def _guard_tripped(self) -> bool:
        if self.guard_ratio is None:
            return False
        ref = self._guard_ref()
        if not np.isfinite(ref) or ref <= 0:
            return False                 # nothing attested yet: unguarded
        if self._seg_n < 2:
            # judge only the ranking tail, and only once it holds two
            # whole-period buckets: the head legitimately carries the
            # period-switch residency transient (that is what the head
            # discard is for, and a spike confined to the head cannot
            # poison the ranking anyway), and the burst-vs-regime verdict
            # needs at least two buckets to compare
            return False
        return (self._tail_cost / self._tail_steps
                > self.guard_ratio * ref)

    def _tail_bucket_cv(self) -> float:
        """Coefficient of variation of the tail's whole-period cost buckets
        (NaN when fewer than two buckets or the mean is not usable)."""
        if self._seg_n < 2:
            return float("nan")
        mean = self._seg_sum / self._seg_n
        if not np.isfinite(mean) or mean <= 0:
            return float("nan")
        var = max(0.0, self._seg_sq / self._seg_n - mean * mean)
        return (var ** 0.5) / mean

    def _trip_guard(self) -> None:
        """The TRIAL tail blew past the guard ratio -- decide burst vs
        regime change by the *shape* of the tail: spiky buckets (CV above
        ``var_cv``, or unmeasurable -- e.g. a NaN pinned to inf) mean a
        burst is poisoning the window, so abort the sweep and revert;
        uniformly elevated buckets mean the cost regime itself moved, so
        the stale anchor (and reuse info) must go -- cold re-profile."""
        cv = self._tail_bucket_cv()
        spiky_above = self.var_cv if self.var_cv is not None else 0.5
        burst = bool(not np.isfinite(cv) or cv > spiky_above)
        if (r := _obs.RECORDER).enabled:
            r.emit("tuner.guard", tuner=self.obs_id, step=self.step,
                   where="trial", verdict="burst" if burst else "regime",
                   cv=float(cv), ref=float(self._guard_ref()),
                   cost=self._tail_cost / max(1, self._tail_steps))
            r.count("tuner.guard_trips")
        if burst:
            self._abort_sweep()
        else:
            self.guard_trips += 1
            self._reprofile(cold=True, reason="guard-regime")
            self._arm_window()

    def _abort_sweep(self) -> None:
        """Cost-spike guardrail: the running TRIAL window blew past
        ``guard_ratio`` x the best-known cost -- a burst is poisoning the
        sweep.  Abort it: adopt the best candidate already ranked cleanly
        this sweep (if any), else revert to the last-good period, and
        fall back to HOLD.  A sustained spike then re-profiles through the
        HOLD guard once its patience runs out."""
        self.guard_trips += 1
        adopted = bool(np.isfinite(self._best_cost))
        if (r := _obs.RECORDER).enabled:
            r.emit("tuner.transition", tuner=self.obs_id, step=self.step,
                   frm=self.state, to=self.HOLD, reason="guard-abort",
                   period=int(self._best_period if adopted
                              else self.last_good_period),
                   detail=("adopt ranked winner" if adopted
                           else "revert to last-good"))
        if adopted:
            # the sweep still produced a cleanly ranked winner: adopting it
            # completes the cycle, so it counts as a re-tune
            self._set_period(self._best_period)
            self.last_good_period = self.period
            self.last_good_cost = min(self.last_good_cost, self._best_cost)
            self.retunes += 1
        else:
            self._set_period(self.last_good_period)
        self.state = self.HOLD
        self.baseline_cost = None
        self._sweep_cost = (float(self._best_cost)
                            if np.isfinite(self._best_cost) else None)
        self._drift_strikes = 0
        self._improve_strikes = 0
        self._guard_strikes = 0
        self._hold_skip = 1 + self.actuation_lag
        # the truncated sweep only half-ranked the ladder: once HOLD
        # re-attests a clean baseline (the burst passed, or the new cost
        # level proved real), finish the job with a warm re-sweep
        self._resweep_pending = True
        self.converged_at = self.step
        self._arm_window()

    def revert_last_good(self, reason: str = "external-fault") -> None:
        """Externally-triggered safety revert: a component outside the
        tuner (e.g. the serving loop's DecisionWorker watchdog) detected a
        fault whose cost telemetry may be garbage, so whatever sweep or
        HOLD window is in flight cannot be trusted.  Drop back to the
        last-good period and re-attest from a fresh HOLD window -- the
        same non-adopting tail as a guard abort, but without charging a
        guard trip (the tuner did nothing wrong) and without ranking the
        half-measured sweep."""
        if (r := _obs.RECORDER).enabled:
            r.emit("tuner.transition", tuner=self.obs_id, step=self.step,
                   frm=self.state, to=self.HOLD, reason="external-revert",
                   period=int(self.last_good_period), detail=reason)
        self._set_period(self.last_good_period)
        self.state = self.HOLD
        self.baseline_cost = None
        self._drift_strikes = 0
        self._improve_strikes = 0
        self._guard_strikes = 0
        self._hold_skip = 1 + self.actuation_lag
        self._resweep_pending = True
        self.converged_at = self.step
        self._arm_window()

    def _should_extend(self) -> bool:
        """Variance-scaled trial windows: extend when the window's
        per-period cost buckets are heavy-tailed (coefficient of variation
        above ``var_cv``), up to ``var_max_factor`` x the base window."""
        if self.var_cv is None:
            return False
        if self._win_target >= self.var_max_factor * self._cost_window():
            return False
        cv = self._tail_bucket_cv()
        return np.isfinite(cv) and cv > self.var_cv

    def _extend_window(self) -> None:
        """Double the trial window: the just-measured noisy segment becomes
        head (warmup) and the ranking tail restarts, so the burst that
        triggered the extension cannot de-noise into the ranking."""
        self.window_extensions += 1
        if (r := _obs.RECORDER).enabled:
            r.emit("tuner.extend", tuner=self.obs_id, step=self.step,
                   cv=float(self._tail_bucket_cv()),
                   win_target=int(self._win_target * 2))
            r.count("tuner.window_extensions")
        self._tail_begin = self._win_target
        self._win_target += self._win_target   # stays a period multiple
        self._tail_cost = 0.0
        self._tail_steps = 0
        self._seg_sum = 0.0
        self._seg_sq = 0.0
        self._seg_n = 0
        self._bucket_cost = 0.0
        self._bucket_steps = 0

    # -- state transitions ---------------------------------------------------
    def _set_period(self, period: float) -> None:
        p = max(1, int(round(period)))
        if p != self.period:
            self.history.append((self.step, p))
            if (r := _obs.RECORDER).enabled:
                r.emit("tuner.period", tuner=self.obs_id, step=self.step,
                       period=p, prev=self.period)
                r.gauge(f"tuner.period.{self.obs_id}", p)
        self.period = p

    def _arm_window(self) -> None:
        """Zero the accumulators and re-arm the measurement window for the
        period now in force (call AFTER ``_set_period``)."""
        self._win_cost = 0.0
        self._win_steps = 0
        self._tail_cost = 0.0
        self._tail_steps = 0
        self._win_target = self._cost_window()
        self._tail_begin = self._win_target - self._tail_window()
        self._seg_sum = 0.0
        self._seg_sq = 0.0
        self._seg_n = 0
        self._bucket_cost = 0.0
        self._bucket_steps = 0

    def _begin_trials(self) -> None:
        hist = self.collector.histogram()
        if hist.num_bins == 0:
            # nothing re-accessed yet: keep the default period, try again
            # after another profile window
            if (r := _obs.RECORDER).enabled:
                r.emit("tuner.profile_extend", tuner=self.obs_id,
                       step=self.step)
            self._arm_window()
            return
        self._launch_trials(hist, reason="profile-complete")

    def _launch_trials(self, hist: ReuseHistogram,
                       reason: str = "profile-complete") -> None:
        self.dominant_reuse = dominant_reuse(hist)
        ladder = candidate_periods(self.dominant_reuse,
                                   float(self.horizon_steps),
                                   max_candidates=self.max_candidates,
                                   min_period=self.min_period)
        # a candidate longer than the trial window cannot be observed even
        # once per window -- clip the ladder (keep at least the head)
        feasible = ladder[ladder <= self.trial_steps]
        cand = feasible if feasible.size else ladder[:1]
        if self.warm_start and self.retunes > 0 and self._warm_next:
            # warm re-tune: explore outward from the previous winner
            # (bandit-style) instead of re-walking the ladder shortest-
            # first -- a mild phase change stops after ~patience+1 trials,
            # a large one still reaches the far end because improvements
            # keep resetting the stopping rule.  After a COLD reset the
            # previous winner is exactly what proved stale, so the sweep
            # reverts to the paper's shortest-first priority order.
            order = np.argsort(np.abs(cand - float(self.last_good_period)),
                               kind="stable")
            cand = cand[order]
        self._warm_next = True
        self.candidates = cand
        self.tried = []
        self._trial_idx = 0
        self._best_cost = np.inf
        self._best_period = self.period
        self._stale = 0
        if (r := _obs.RECORDER).enabled:
            r.emit("tuner.transition", tuner=self.obs_id, step=self.step,
                   frm=self.state, to=self.TRIAL, reason=reason,
                   period=int(max(1, round(cand[0]))),
                   detail=f"ladder {[int(round(c)) for c in cand]}, "
                          f"DR={self.dominant_reuse:.1f}")
        self.state = self.TRIAL
        self._set_period(self.candidates[0])
        self._arm_window()

    def _finish_trial(self) -> None:
        cost = self._tail_cost / max(1, self._tail_steps)
        if not np.isfinite(cost):
            cost = float("inf")
        self.tried.append((float(self.period), cost))
        improved = cost < self._best_cost * (1.0 - self.rel_tol)
        if improved:
            self._best_cost, self._best_period = cost, self.period
            self._stale = 0
        else:
            self._stale += 1
        self._trial_idx += 1
        done = (self._stale >= self.patience
                or self._trial_idx >= len(self.candidates)
                or (self.max_trials is not None
                    and self._trial_idx >= self.max_trials))
        if (r := _obs.RECORDER).enabled:
            r.emit("tuner.trial", tuner=self.obs_id, step=self.step,
                   period=self.period, cost=cost,
                   best_period=int(self._best_period),
                   best_cost=float(self._best_cost), stale=self._stale,
                   improved=improved)
            r.observe("tuner.trial_cost", cost)
            if done:
                r.emit("tuner.transition", tuner=self.obs_id,
                       step=self.step, frm=self.state, to=self.HOLD,
                       reason="sweep-complete",
                       period=int(self._best_period),
                       detail=f"{self._trial_idx} trials, winner "
                              f"p={int(self._best_period)}")
                r.count("tuner.retunes")
        if done:
            self.state = self.HOLD
            self.baseline_cost = None
            self._sweep_cost = (float(self._best_cost)
                                if np.isfinite(self._best_cost) else None)
            self._drift_strikes = 0
            self._improve_strikes = 0
            self._guard_strikes = 0
            # the first HOLD window inherits the residency transient from
            # the period switch (the same transient TRIAL's head discard
            # exists for): skip it before baselining -- plus one window
            # per actuation_lag when the serving loop applies the switch
            # a boundary late
            self._hold_skip = 1 + self.actuation_lag
            self._resweep_pending = False
            self.retunes += 1
            self.converged_at = self.step
            self._set_period(self._best_period)
            if np.isfinite(self._best_cost):
                self.last_good_period = self.period
                self.last_good_cost = self._best_cost
        else:
            self._set_period(self.candidates[self._trial_idx])
        self._arm_window()

    def _check_drift(self) -> None:
        if self._hold_skip:
            # period-switch transient window: measure nothing from it (a
            # clean switch must not fake drift via a polluted baseline)
            self._hold_skip = int(self._hold_skip) - 1
            if (r := _obs.RECORDER).enabled:
                r.emit("tuner.hold_window", tuner=self.obs_id,
                       step=self.step, kind="skip-transient",
                       cost=self._win_cost / max(1, self._win_steps),
                       baseline=self.baseline_cost, strikes=0)
            self._arm_window()
            return
        cost = self._win_cost / max(1, self._win_steps)
        ref = (self.baseline_cost if self.baseline_cost is not None
               else self.last_good_cost)
        if (self.guard_ratio is not None and np.isfinite(ref) and ref > 0
                and cost > self.guard_ratio * ref):
            # guardrail (HOLD): an extreme window is a burst, not a
            # baseline -- discard it entirely.  Only a sustained run of
            # guard-level windows (a regime change, not a flash crowd)
            # forces the re-profile.
            self.guard_trips += 1
            self._guard_strikes += 1
            self._drift_strikes = 0
            self._improve_strikes = 0
            escalate = self._guard_strikes >= self.drift_patience
            if (r := _obs.RECORDER).enabled:
                r.emit("tuner.guard", tuner=self.obs_id, step=self.step,
                       where="hold",
                       verdict="escalate" if escalate else "discard",
                       cv=float("nan"), ref=float(ref), cost=cost)
                r.emit("tuner.hold_window", tuner=self.obs_id,
                       step=self.step, kind="discard-guard", cost=cost,
                       baseline=self.baseline_cost,
                       strikes=self._guard_strikes)
                r.count("tuner.guard_trips")
            if escalate:
                self._reprofile(cold=True, reason="guard-escalate")
            self._arm_window()
            return
        self._guard_strikes = 0
        if self.baseline_cost is None:
            floored = (self._sweep_cost is not None
                       and self._sweep_cost > cost)
            if self._sweep_cost is not None:
                # the first clean window after a sweep can *undershoot* the
                # regime's steady cost (residency is still settling), and a
                # too-low baseline arms a hair-trigger drift detector -- the
                # mirror image of the transient the _hold_skip window
                # discards.  Floor the baseline with the winner's attested
                # trial cost so one quiet window cannot set the reference.
                cost = max(cost, self._sweep_cost)
            self.baseline_cost = cost
            if (r := _obs.RECORDER).enabled:
                r.emit("tuner.baseline", tuner=self.obs_id, step=self.step,
                       cost=cost, floored=floored)
            if np.isfinite(cost):
                self.last_good_period = self.period
                self.last_good_cost = cost
            if self._resweep_pending:
                # a guard abort truncated the last sweep; the clean window
                # just re-anchored the guardrail, so re-rank the ladder now
                # (warm -- explores outward from the adopted fallback)
                self._resweep_pending = False
                self._reprofile(reason="resweep")
        elif cost > self.drift_ratio * max(self.baseline_cost, 1e-12):
            self._drift_strikes += 1
            self._improve_strikes = 0
            if (r := _obs.RECORDER).enabled:
                r.emit("tuner.hold_window", tuner=self.obs_id,
                       step=self.step, kind="drift-strike", cost=cost,
                       baseline=self.baseline_cost,
                       strikes=self._drift_strikes)
            if self._drift_strikes >= self.drift_patience:
                # sustained regression == workload phase change: stale
                # reuse info is worse than none
                self._reprofile(reason="drift")
        elif (self.improve_ratio is not None
              and cost * self.improve_ratio < self.baseline_cost):
            self._improve_strikes += 1
            self._drift_strikes = 0
            if (r := _obs.RECORDER).enabled:
                r.emit("tuner.hold_window", tuner=self.obs_id,
                       step=self.step, kind="improve-strike", cost=cost,
                       baseline=self.baseline_cost,
                       strikes=self._improve_strikes)
            if self._improve_strikes >= self.improve_patience:
                # sustained *improvement* is a phase change too: the new,
                # cheaper mix may admit an even better period than the one
                # tuned against the old mix
                self._reprofile(reason="improve")
        else:
            self._drift_strikes = 0
            self._improve_strikes = 0
            if (r := _obs.RECORDER).enabled:
                r.emit("tuner.hold_window", tuner=self.obs_id,
                       step=self.step, kind="ok", cost=cost,
                       baseline=self.baseline_cost, strikes=0)
        self._arm_window()

    def _reprofile(self, cold: bool = False, reason: str = "manual") -> None:
        self._drift_strikes = 0
        self._improve_strikes = 0
        self._guard_strikes = 0
        if not cold and self.warm_start:
            # warm re-tune: the rolling collector window is still live, so
            # the ladder can be rebuilt NOW and trialed outward from the
            # previous winner -- skipping the PROFILE stage entirely.  The
            # window may still carry some pre-drift reuse, but the trials
            # rank candidates by *measured* cost, so a skewed ladder costs
            # at most a few extra trials (and the next drift window gets a
            # fresher histogram).
            hist = self.collector.histogram()
            if hist.num_bins > 0:
                self._launch_trials(hist, reason=f"warm-{reason}")
                return
        # cold reset (guard-strike escalation, or nothing collected yet):
        # stale reuse info is worse than none.  A drift-triggered WARM
        # re-tune keeps last_good_cost as the guard anchor (a mild drift
        # sits far below the guard ratio); only the cold path -- reached
        # when sustained guard-level cost proves a genuine regime change
        # -- drops the stale anchor, so the fresh sweep cannot be trapped
        # aborting against a cost level that no longer exists
        self.last_good_cost = float("inf")
        self._warm_next = False
        self.collector.reset()
        if (r := _obs.RECORDER).enabled:
            r.emit("tuner.transition", tuner=self.obs_id, step=self.step,
                   frm=self.state, to=self.PROFILE,
                   reason=f"cold-{reason}", period=self.period,
                   detail="reuse collector reset")
        self.state = self.PROFILE

    # -- multi-request traffic hooks -----------------------------------------
    def forget_pages(self, ids: np.ndarray) -> None:
        """Invalidate freed logical page IDs in the reuse collector (see
        ``StreamingReuseCollector.forget``): called by the serving scheduler
        when a request retires, so a recycled global page ID does not pair
        the new owner's first access with the old owner's last one."""
        self.collector.forget(ids)


def trials_to_best(runtimes_in_order: Sequence[float], tol: float = 0.005
                   ) -> int:
    """Number of trials until a candidate within `tol` of the sequence's own
    best has been tried (the Fig. 5a metric)."""
    rts = np.asarray(list(runtimes_in_order), dtype=np.float64)
    if rts.size == 0:
        return 0
    target = rts.min() * (1.0 + tol)
    return int(np.argmax(rts <= target)) + 1
