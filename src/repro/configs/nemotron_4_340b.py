"""Nemotron-4-340B [arXiv:2402.16819]: dense GQA, squared-ReLU MLP."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
        d_ff=73728, vocab_size=256000,
        segments=((("attn",), 96),),
        mlp_kind="squared_relu", tie_embeddings=False,
        rope_theta=10_000.0, max_seq_len=32768)
