"""Continuous-batching scheduler: shared page pool, traffic-fed tuning.

Covers the PR-2 tentpole: SharedPagedPools allocation/eviction across
requests, multi-request tiering with free slots and active masks, global
page-ID reuse collection (including ID recycling), the TrafficScheduler's
admission/retire path, the end-state acceptance vs a fixed-period sweep,
and the model-backed ContinuousBatcher's token parity with per-request
generate over the shared pool."""
import dataclasses

import numpy as np
import pytest

from repro.core import OnlineTuner, StreamingReuseCollector, RequestSpec
from repro.core.traffic import poisson_request_stream, shifting_mix_stream
from repro.memtier import (SharedPagedPools, TierConfig, TieringManager)
from repro.serve.sched import (TrafficMonitor, TrafficScheduler,
                               WORKLOAD_KINDS)

CFG = TierConfig(page_size=16, hbm_pages=8, period_steps=4)


# ---------------------------------------------------------------------------
# SharedPagedPools: allocation, eviction, recycling
# ---------------------------------------------------------------------------


def test_shared_pool_alloc_free_recycle():
    pools = SharedPagedPools.create(8, 4)
    a = pools.alloc(3, owner=0)
    b = pools.alloc(3, owner=1)
    np.testing.assert_array_equal(a, [0, 1, 2])
    np.testing.assert_array_equal(b, [3, 4, 5])
    assert pools.alloc(3, owner=2) is None, "over-capacity must queue"
    assert pools.free_pages == 2
    pools.free(a)
    c = pools.alloc(4, owner=2)
    np.testing.assert_array_equal(c, [0, 1, 2, 6])  # freed ids recycle
    assert (pools.owner_of[c] == 2).all()


def test_shared_pool_free_evicts_slots():
    pools = SharedPagedPools.create(8, 4)
    gids = pools.alloc(4, owner=0)
    pools.ensure_resident(gids)
    assert (pools.slot_of[gids] >= 0).all()
    assert len(pools.free_slots()) == 0
    pools.free(gids)
    assert (pools.slot_of[gids] == -1).all()
    assert len(pools.free_slots()) == 4, "retired pages release their slots"


def test_ensure_resident_demand_fetch_counts_and_evicts():
    pools = SharedPagedPools.create(16, 4)
    a = pools.alloc(4, owner=0)
    b = pools.alloc(4, owner=1)
    assert pools.ensure_resident(a) == 4
    assert pools.ensure_resident(a) == 0, "already resident: no fetch"
    assert pools.ensure_resident(b[:2]) == 2, "evicts a's LRU slots"
    resident_b = pools.slot_of[b[:2]]
    assert (resident_b >= 0).all()
    assert (pools.slot_of[a] >= 0).sum() == 2
    with pytest.raises(ValueError, match="cannot fit"):
        pools.ensure_resident(np.arange(5))


def test_multi_request_tiering_fills_freed_slots_without_evicting():
    """After a retirement, maybe_tier brings new hot pages into the freed
    slots and keeps still-useful residents (lazy eviction)."""
    pools = SharedPagedPools.create(16, 4)
    mgr = TieringManager(16, dataclasses.replace(CFG, hbm_pages=4,
                                                 period_steps=1))
    a = pools.alloc(4, owner=0)
    mass = np.zeros(16, np.float32)
    mass[a] = 1.0
    for _ in range(4):
        mgr.on_step(mass, pools.resident_mask)
        mgr.maybe_tier(pools, active=pools.allocated_mask)
    assert (pools.slot_of[a] >= 0).all()
    # request 0 retires two pages; request 1 arrives hot
    mgr.release(a[2:])
    pools.free(a[2:])
    b = pools.alloc(2, owner=1)
    migs = mgr.migrations
    mass = np.zeros(16, np.float32)
    mass[a[:2]] = 1.0
    mass[b] = 1.0
    for _ in range(4):
        mgr.on_step(mass, pools.resident_mask)
        mgr.maybe_tier(pools, active=pools.allocated_mask)
    assert (pools.slot_of[b] >= 0).all(), "new request's pages tier in"
    assert (pools.slot_of[a[:2]] >= 0).all(), "live residents not evicted"
    assert mgr.migrations - migs == 2, "exactly the freed slots were filled"


def test_active_mask_keeps_unallocated_pages_out():
    """Pages no request owns must never enter the working set even when
    capacity exceeds the allocated footprint."""
    pools = SharedPagedPools.create(32, 8)
    mgr = TieringManager(32, dataclasses.replace(CFG, hbm_pages=8,
                                                 period_steps=1))
    gids = pools.alloc(3, owner=0)
    mass = np.zeros(32, np.float32)
    mass[gids] = 1.0
    for _ in range(6):
        mgr.on_step(mass, pools.resident_mask)
        mgr.maybe_tier(pools, active=pools.allocated_mask)
    resident = np.nonzero(pools.resident_mask)[0]
    assert set(resident.tolist()) <= set(gids.tolist())


# ---------------------------------------------------------------------------
# global page-ID reuse collection and recycling
# ---------------------------------------------------------------------------


def test_collector_forget_blocks_cross_owner_gaps():
    col = StreamingReuseCollector(8, bin_width=1)
    col.observe(np.array([3]))          # owner A touches page 3 at t=0
    col.forget(np.array([3]))           # A retires, id 3 recycled
    col.observe(np.array([3]))          # owner B touches page 3 at t=1
    assert col.num_samples == 0, "cross-owner gap must not be recorded"
    col.observe(np.array([3]))          # B re-touches: a real gap
    assert col.num_samples == 1


def test_tuner_forget_pages_delegates():
    tuner = OnlineTuner(8, bin_width=1)
    tuner.on_step(accessed_ids=np.array([2]), cost=1.0)
    tuner.forget_pages(np.array([2]))
    tuner.on_step(accessed_ids=np.array([2]), cost=1.0)
    assert tuner.collector.num_samples == 0


def test_monitor_release_clears_everything():
    pools = SharedPagedPools.create(16, 4)
    mgr = TieringManager(16, dataclasses.replace(CFG, hbm_pages=4,
                                                 period_steps=1))
    tuner = OnlineTuner(16, bin_width=1)
    mon = TrafficMonitor(pools, mgr, tuner)
    gids = pools.alloc(3, owner=7)
    mass = np.zeros(16, np.float32)
    mass[gids] = 1.0
    for _ in range(3):
        mon.on_step(mass, n_active=1)
    assert mgr.hotness[gids].sum() > 0
    mon.release(gids)
    assert mgr.hotness[gids].sum() == 0
    assert (mgr.last_access[gids] == -1).all()
    assert (tuner.collector.last_access[gids] == -1).all()
    assert pools.free_pages == 16
    assert (pools.slot_of[gids] == -1).all()


def test_monitor_merge_is_max_per_page():
    pools = SharedPagedPools.create(8, 4)
    mgr = TieringManager(8, CFG)
    mon = TrafficMonitor(pools, mgr)
    m = mon.merge([(np.array([0, 1]), np.array([0.5, 0.2], np.float32)),
                   (np.array([1, 2]), np.array([0.9, 0.1], np.float32))])
    np.testing.assert_allclose(m[:4], [0.5, 0.9, 0.1, 0.0])


# ---------------------------------------------------------------------------
# traffic stream + scheduler
# ---------------------------------------------------------------------------


def test_poisson_stream_reproducible_and_phased():
    a = poisson_request_stream(100, 0.2, {"sink": 1.0}, seed=3)
    b = poisson_request_stream(100, 0.2, {"sink": 1.0}, seed=3)
    assert a == b
    mix = shifting_mix_stream([(50, 0.2, {"random": 1.0}),
                               (50, 0.2, {"sink": 1.0})], seed=1)
    assert all(s.kind == "random" for s in mix if s.arrival < 50)
    assert all(s.kind == "sink" for s in mix if s.arrival >= 50)
    assert [s.rid for s in mix] == list(range(len(mix)))
    spec = RequestSpec(rid=0, arrival=0, prompt_len=17, new_tokens=30,
                       kind="sink", seed=0)
    assert spec.n_pages(16) == 3, "page-aligned allocation rounds up"


def _traffic(specs, steps, *, period=8, tuner=None, n_logical=128,
             hbm=16, page=16, max_active=6, probe_at=None):
    pools = SharedPagedPools.create(n_logical, hbm)
    mgr = TieringManager(n_logical, TierConfig(
        page_size=page, hbm_pages=hbm, period_steps=period))
    sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr, tuner),
                             page_size=page, max_active=max_active)
    probe = 0.0
    for t in range(steps):
        if t == probe_at:
            probe = mgr.modeled_time
        sched.step()
    return sched, mgr, probe


def test_traffic_scheduler_admits_and_retires():
    specs = poisson_request_stream(120, 0.15, {"sink": 0.5, "random": 0.5},
                                   prompt_len=(8, 32), new_tokens=(16, 40),
                                   seed=2)
    sched, mgr, _ = _traffic(specs, 400)
    assert sched.admitted == len(specs)
    assert sched.completed == len(specs), "all requests must drain"
    assert sched.monitor.pools.free_pages == 128, "all pages returned"
    assert mgr.hits + mgr.misses > 0


def test_traffic_scheduler_head_of_line_admission_order():
    """Admission is FIFO even when a later, smaller request would fit."""
    specs = [RequestSpec(0, 0, 40 * 16 - 8, 8, "sink", 0),    # 40 pages
             RequestSpec(1, 0, 40 * 16 - 8, 8, "sink", 1),    # 40 pages
             RequestSpec(2, 0, 8, 8, "sink", 2)]              # 1 page
    sched, _, _ = _traffic(specs, 3, n_logical=64, hbm=16)
    assert sched.admitted == 1, "head-of-line blocks; order is preserved"


def test_impossible_requests_rejected_not_deadlocked():
    """A request larger than the whole logical space can never admit; it is
    dropped (TrafficScheduler) or refused at submit (ContinuousBatcher)
    instead of blocking the queue forever."""
    specs = [RequestSpec(0, 0, 100 * 16 - 8, 8, "sink", 0),   # 100 pages
             RequestSpec(1, 0, 8, 8, "sink", 1)]              # 1 page
    sched, _, _ = _traffic(specs, 3, n_logical=64, hbm=16)
    assert sched.rejected == 1
    assert sched.admitted == 1, "the queue keeps moving"


def test_traffic_replay_deterministic():
    specs = poisson_request_stream(80, 0.2, {"sink": 1.0}, seed=5)
    _, m1, _ = _traffic(specs, 200)
    _, m2, _ = _traffic(specs, 200)
    assert m1.modeled_time == m2.modeled_time
    assert m1.migrations == m2.migrations


def test_admission_independent_of_period():
    """Fixed-period replays of one stream admit/retire identically -- the
    property that makes the brute-force sweep comparable."""
    specs = poisson_request_stream(100, 0.2, {"sink": 1.0}, seed=4)
    s1, _, _ = _traffic(specs, 300, period=1)
    s2, _, _ = _traffic(specs, 300, period=64)
    assert (s1.admitted, s1.completed) == (s2.admitted, s2.completed)


# ---------------------------------------------------------------------------
# the acceptance: scheduler-fed tuner vs brute-force sweep
# ---------------------------------------------------------------------------


def test_traffic_online_tuner_within_5pct_of_best_fixed():
    """PR-2 acceptance: on a Poisson stream whose mix shifts mid-run, the
    scheduler-fed OnlineTuner's end-state modeled cost is within 5% of the
    best fixed period found by sweeping."""
    phase = 700
    steps, window = 2 * phase, 150
    lo = steps - window
    specs = shifting_mix_stream(
        [(phase, 0.10, {"random": 1.0}), (phase, 0.10, {"sink": 1.0})],
        prompt_len=(16, 48), new_tokens=(40, 100), seed=0)
    kw = dict(n_logical=256, hbm=32, page=16, max_active=8)

    tuner = OnlineTuner(256, default_period=8, drift_ratio=1.5,
                        drift_patience=3)
    _, mgr, probe = _traffic(specs, steps, tuner=tuner, probe_at=lo, **kw)
    online_steady = (mgr.modeled_time - probe) / window
    assert tuner.retunes >= 2, "the mix shift must trigger a re-tune"

    best = np.inf
    for p in (1, 2, 4, 8, 16, 32, 64):
        _, m, pr = _traffic(specs, steps, period=p, probe_at=lo, **kw)
        best = min(best, (m.modeled_time - pr) / window)
    assert online_steady <= 1.05 * best, \
        f"online {online_steady:.1f} vs best fixed {best:.1f}"


# ---------------------------------------------------------------------------
# model-backed ContinuousBatcher (token parity over the shared pool)
# ---------------------------------------------------------------------------


def _tiny_serving_stack(cfg, params, *, n_logical=48, hbm=16, page=4):
    pools = SharedPagedPools.create(n_logical, hbm, page_size=page,
                                    kv_heads=cfg.num_kv_heads,
                                    head_dim=cfg.head_dim)
    mgr = TieringManager(n_logical, TierConfig(page_size=page,
                                               hbm_pages=hbm,
                                               period_steps=2))
    tuner = OnlineTuner(n_logical, default_period=2, profile_steps=8,
                        trial_steps=4)
    return TrafficMonitor(pools, mgr, tuner)


def test_batcher_token_parity_with_generate():
    """Multi-request decode over SharedPagedPools emits token-identical
    output to per-request generate (greedy and temperature sampling),
    across staggered admission and row reuse."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 9, 5)]
    keys = [jax.random.PRNGKey(10 + i) for i in range(3)]
    steps = [6, 4, 7]
    temps = [0.0, 0.7, 0.7]

    mon = _tiny_serving_stack(cfg, params)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon, mirror_pages=True)
    b.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=steps[0],
                     key=keys[0], temperature=temps[0]))
    b.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=steps[1],
                     key=keys[1], temperature=temps[1]))
    events = []
    for t in range(40):
        if t == 2:   # joins mid-flight, lands in a recycled row
            b.submit(Request(rid=2, prompt=prompts[2],
                             max_new_tokens=steps[2], key=keys[2],
                             temperature=temps[2]))
        events.extend(b.step())
        if not b.queue and not b.active:
            break
    got = {r.rid: r.tokens for r in b.completed}
    for i in range(3):
        ref = np.asarray(generate(params, cfg, jnp.asarray(prompts[i])[None],
                                  steps=steps[i], temperature=temps[i],
                                  key=keys[i]))[0].tolist()
        assert got[i] == ref, f"request {i} diverged from generate"
        streamed = [tok for rid, tok in events if rid == i]
        assert streamed == ref, \
            f"step()'s emitted stream must carry request {i}'s full output"
    assert mon.pools.free_pages == mon.pools.n_logical


def test_batcher_retires_on_eos():
    """A sampled EOS retires the request early (pages released, row
    recycled), truncating exactly at the EOS token of the generate-
    equivalent stream."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    key = jax.random.PRNGKey(5)
    ref = np.asarray(generate(params, cfg, jnp.asarray(prompt)[None],
                              steps=8, key=key))[0].tolist()
    eos = ref[2]       # make the third greedy token the EOS

    mon = _tiny_serving_stack(cfg, params)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon, mirror_pages=True)
    b.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, key=key,
                     eos_id=eos))
    got = b.run()
    k = ref.index(eos) + 1
    assert got[0] == ref[:k], "EOS must truncate the generate stream"
    assert mon.pools.free_pages == mon.pools.n_logical, \
        "early retirement must release the pages"
    assert b.rows_free == list(range(b.max_active - 1, -1, -1)) or \
        sorted(b.rows_free) == list(range(b.max_active))


def test_batcher_paged_kernel_gathers_shared_pool():
    """kernels.paged_attention over the shared HBM pool (slot_of
    indirection through a request's page table) matches the host-pool
    reference for an in-flight request with interleaved allocations."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.kernels import ops
    from repro.models import model as mdl
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    mon = _tiny_serving_stack(cfg, params)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon, mirror_pages=True)
    for i in range(2):
        prompt = rng.integers(0, cfg.vocab_size, size=7 + i).astype(np.int32)
        b.submit(Request(rid=i, prompt=prompt, max_new_tokens=8,
                         key=jax.random.PRNGKey(i)))
    for _ in range(4):
        b.step()
    page = b.page_size
    for req in list(b.active.values()):
        q = jax.random.normal(jax.random.PRNGKey(40 + req.rid),
                              (1, cfg.num_heads, cfg.head_dim))
        out, _ = b.paged_context(req.rid, q)
        length = int(np.asarray(b.pos)[req.row])
        n = -(-length // page)
        tbl = jnp.asarray(req.gids[:n], jnp.int32)[None]
        ref = ops.paged_attention(q, mon.pools.k_host, mon.pools.v_host,
                                  tbl, jnp.asarray([length], jnp.int32),
                                  impl="reference")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


def test_paged_attention_tolerates_ragged_minus_one_padding():
    """Ragged multi-request page tables pad short rows with -1; the kernel
    wrapper clamps them (they are masked by lengths) instead of gathering
    out of bounds."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    n, page, kvh, d, h = 6, 4, 2, 8, 4
    k = jax.random.normal(key, (n, page, kvh, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, page, kvh, d))
    q = jax.random.normal(jax.random.fold_in(key, 2), (2, h, d))
    # row 0 uses 3 pages, row 1 only 1 -- padded with -1
    tbl = jnp.asarray([[2, 0, 4], [5, -1, -1]], jnp.int32)
    lengths = jnp.asarray([3 * page, page], jnp.int32)
    out = ops.paged_attention(q, k, v, tbl, lengths, impl="interpret")
    ref = ops.paged_attention(q, k, v, jnp.maximum(tbl, 0), lengths,
                              impl="reference")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    assert not np.isnan(np.asarray(out)).any()
