"""KV-tiering runtime (the adapted paper technique) + serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.kernels import ops
from repro.memtier import (PagedPools, TierConfig, TieringManager,
                           cori_tune_period, replay)
from repro.memtier import workload as W
from repro.models import model as mdl
from repro.serve.engine import generate, monitored_generate

CFG = TierConfig(hbm_pages=16, period_steps=8)


def test_hot_pages_become_resident():
    """A few persistently hot pages must end up HBM-resident."""
    steps, n = 64, 64
    m = np.zeros((steps, n), np.float32)
    hot = [3, 17, 40]
    m[:, hot] = 1.0
    mgr_cfg = dataclasses.replace(CFG, hbm_pages=8, period_steps=4)
    k = jnp.zeros((n, 4, 2, 8))
    pools = PagedPools.create(k, k, hbm_pages=8)
    mgr = TieringManager(n, mgr_cfg)
    for t in range(steps):
        mgr.on_step(m[t], pools.slot_of >= 0)
        pools = mgr.maybe_tier(pools)
    assert all(pools.slot_of[h] >= 0 for h in hot)


def test_migration_moves_page_contents():
    """After tiering, the HBM pool physically holds the hot pages' data."""
    n, page, kv, d = 32, 4, 2, 8
    k_host = jnp.arange(n * page * kv * d, dtype=jnp.float32).reshape(
        n, page, kv, d)
    pools = PagedPools.create(k_host, k_host * 2, hbm_pages=4)
    mgr = TieringManager(n, dataclasses.replace(CFG, hbm_pages=4,
                                                period_steps=2))
    m = np.zeros((8, n), np.float32)
    m[:, [5, 9]] = 1.0
    for t in range(8):
        mgr.on_step(m[t], pools.slot_of >= 0)
        pools = mgr.maybe_tier(pools)
    for logical in (5, 9):
        slot = pools.slot_of[logical]
        assert slot >= 0
        np.testing.assert_array_equal(np.asarray(pools.k_hbm[slot]),
                                      np.asarray(k_host[logical]))
        assert pools.page_of_slot[slot] == logical


@pytest.mark.parametrize("wl_name", ["attention_sink", "periodic_context",
                                     "random_lookup"])
def test_cori_tunes_tiering_period(wl_name):
    """The full Cori loop on the tiering runtime: chosen period >= DR-ish,
    beats the long fixed period, and is within 1.6x of the best fixed
    period (the paper's 'bridging the gap' claim in the serving domain)."""
    wl = getattr(W, wl_name)(400, 64)
    res, dr = cori_tune_period(wl, CFG)
    fixed = {p: replay(wl, dataclasses.replace(CFG, period_steps=p)
                       ).modeled_time for p in (1, 2, 4, 8, 16, 32, 64, 200)}
    best_fixed = min(fixed.values())
    assert res.chosen_runtime <= fixed[200], "must beat arbitrarily long"
    assert res.chosen_runtime <= 1.6 * best_fixed
    assert res.trials <= 16


def test_periodic_workload_cori_wins_big():
    """On the RAG-loop workload (reuse == period K) Cori must find a period
    that does not break the reuse: >= the span reuse distance."""
    wl = W.periodic_context(400, 64, span_pages=8, period=16)
    res, dr = cori_tune_period(wl, CFG)
    t_break = replay(wl, dataclasses.replace(CFG, period_steps=1)).modeled_time
    assert res.chosen_runtime < t_break
    assert res.chosen_period >= dr


def test_paged_attention_consumes_tiered_pool():
    """paged_attention over the HBM working set == oracle over host pages
    for sequences whose pages are all resident."""
    n, page, kv, d, h, b = 16, 8, 2, 32, 4, 1
    key = jax.random.PRNGKey(0)
    k_host = jax.random.normal(key, (n, page, kv, d))
    v_host = jax.random.normal(jax.random.fold_in(key, 1), (n, page, kv, d))
    pools = PagedPools.create(k_host, v_host, hbm_pages=8)
    mgr = TieringManager(n, dataclasses.replace(CFG, hbm_pages=8,
                                                period_steps=1))
    mass = np.zeros((4, n), np.float32)
    mass[:, :4] = 1.0                     # first 4 logical pages hot
    for t in range(4):
        mgr.on_step(mass[t], pools.slot_of >= 0)
        pools = mgr.maybe_tier(pools)
    assert (pools.slot_of[:4] >= 0).all()
    q = jax.random.normal(jax.random.fold_in(key, 2), (b, h, d))
    # logical pages 0..3, physical slots via the table
    pt_logical = jnp.arange(4, dtype=jnp.int32)[None]
    pt_phys = jnp.asarray(pools.slot_of[:4])[None]
    lengths = jnp.array([4 * page], jnp.int32)
    out_tiered = ops.paged_attention(q, pools.k_hbm, pools.v_hbm, pt_phys,
                                     lengths, impl="interpret")
    out_oracle = ops.paged_attention(q, k_host, v_host, pt_logical, lengths,
                                     impl="reference")
    np.testing.assert_allclose(np.asarray(out_tiered), np.asarray(out_oracle),
                               atol=1e-5)


def test_generate_shapes_and_determinism():
    cfg = C.reduced("stablelm-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    t1 = generate(params, cfg, prompts, steps=5)
    t2 = generate(params, cfg, prompts, steps=5)
    assert t1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_monitored_generate_mass_is_probability_like():
    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                 cfg.vocab_size)
    toks, mass = monitored_generate(params, cfg, prompts, steps=8,
                                    page_size=4)
    assert toks.shape == (2, 8)
    assert mass.shape[0] == 7
    assert (mass >= 0).all()
    # per-step mass sums to ~num_heads (softmax over pages x heads)
    sums = mass.sum(axis=1)      # max-over-batch per page, summed
    assert (sums <= 2 * cfg.num_heads + 1e-3).all()
    assert (sums > 0.5).all()


def test_attention_free_arch_has_no_monitor():
    cfg = C.reduced("xlstm-1.3b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                 cfg.vocab_size)
    with pytest.raises(ValueError, match="attention-free"):
        monitored_generate(params, cfg, prompts, steps=4)


@pytest.mark.parametrize("wl_name", ["attention_sink", "periodic_context",
                                     "random_lookup"])
def test_symbolic_physical_tiering_parity(wl_name):
    """maybe_tier_symbolic and maybe_tier share the swap rule exactly: on
    the same access sequence (including a live period change mid-run) they
    must produce identical residency and accounting at every step."""
    from repro.memtier import interleaved_resident
    wl = getattr(W, wl_name)(120, 32)
    cfg = dataclasses.replace(CFG, hbm_pages=8, period_steps=4)
    k = jnp.zeros((32, 4, 2, 8))
    pools = PagedPools.create(k, k, hbm_pages=8)
    mgr_p = TieringManager(32, cfg)
    mgr_s = TieringManager(32, cfg)
    resident = interleaved_resident(32, 8)
    np.testing.assert_array_equal(resident, pools.slot_of >= 0)
    for t in range(wl.shape[0]):
        mgr_p.on_step(wl[t], pools.slot_of >= 0)
        pools = mgr_p.maybe_tier(pools)
        mgr_s.on_step(wl[t], resident)
        mgr_s.maybe_tier_symbolic(resident)
        if t == 50:    # live period change, applied to both mid-window
            mgr_p.set_period(2)
            mgr_s.set_period(2)
        np.testing.assert_array_equal(
            resident, pools.slot_of >= 0,
            err_msg=f"residency diverged at step {t}")
    assert mgr_p.migrations == mgr_s.migrations
    assert mgr_p.modeled_time == mgr_s.modeled_time
    assert mgr_p.data_moved_pages == mgr_s.data_moved_pages
    assert mgr_p.hits == mgr_s.hits and mgr_p.misses == mgr_s.misses


def test_set_period_mid_window_counts_since_last_tier():
    """A period change between tier boundaries is counted against the
    steps already elapsed since the last tier: shortening the period
    mid-window can make the very next step a boundary."""
    mgr = TieringManager(16, dataclasses.replace(CFG, hbm_pages=4,
                                                 period_steps=8))
    from repro.memtier import interleaved_resident
    resident = interleaved_resident(16, 4)
    mass = np.zeros(16, np.float32)
    mass[:2] = 1.0
    tiers = []
    for t in range(16):
        if t == 3:          # mid-window: 3 steps already elapsed
            mgr.set_period(2)
        mgr.on_step(mass, resident)
        if mgr.maybe_tier_symbolic(resident):
            tiers.append(t)
    # at t=3 since_tier hits 4 >= 2 -> immediate boundary, then every 2
    assert tiers == [3, 5, 7, 9, 11, 13, 15]


def test_adaptive_tuner_retunes_on_phase_change():
    """SIV-D extension: when the serving mix shifts (RAG loop -> random
    retrieval), the adaptive tuner detects the hit-rate drop and re-runs
    the Cori loop; a phase-appropriate period results."""
    from repro.memtier import AdaptiveTuner
    cfg = dataclasses.replace(CFG, hbm_pages=8)
    tuner = AdaptiveTuner(cfg, window=64, retune_ratio=0.9)
    phase_a = W.periodic_context(192, 64, span_pages=8, period=16, seed=0)
    phase_b = W.random_lookup(192, 64, touches=6, zipf_a=0.1, seed=1)
    periods = []
    for t in range(phase_a.shape[0]):
        periods.append(tuner.observe(phase_a[t]))
    p_before = tuner.period
    for t in range(phase_b.shape[0]):
        periods.append(tuner.observe(phase_b[t]))
    assert tuner.retunes >= 1, "phase change must trigger a re-tune"
    assert tuner.period != p_before or tuner.retunes >= 1
