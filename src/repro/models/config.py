"""Model configuration schema covering all assigned architecture families.

A model is a sequence of *segments*; each segment is a layer pattern repeated
R times (``(pattern, R)``).  Patterns are tuples of layer-kind strings:

    "attn"    full causal self-attention + MLP
    "local"   sliding-window causal self-attention + MLP
    "mlstm"   xLSTM matrix-LSTM block
    "slstm"   xLSTM scalar-LSTM block
    "rglru"   RG-LRU recurrent block (+ MLP)

Kind strings may carry dot-flags: ``.moe`` (MLP is a routed MoE),
``.xattn`` (adds cross-attention to conditioning), ``.mla`` (attention is
Multi-head Latent Attention).  Example: ``"attn.mla.moe"`` (DeepSeek-V3).

Scanning: parameters of each segment are stacked ``[R, ...]`` and the
segment is executed with ``lax.scan`` over repeats (pattern slots unrolled
inside the scan body), keeping HLO size proportional to the pattern length
rather than the layer count.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = [
    "MoEConfig", "MLAConfig", "LayerKind", "ModelConfig", "parse_kind",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    num_shared: int = 0         # always-on shared experts (DeepSeek)
    d_shared: int = 0           # shared expert hidden size (0 -> d_expert)
    capacity_factor: float = 1.25
    router_noise: float = 0.0   # jitter during training
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LayerKind:
    base: str                   # attn | local | mlstm | slstm | rglru
    moe: bool = False
    xattn: bool = False
    mla: bool = False

    @property
    def is_attention(self) -> bool:
        return self.base in ("attn", "local")

    @property
    def is_recurrent(self) -> bool:
        return self.base in ("mlstm", "slstm", "rglru")


def parse_kind(s: str) -> LayerKind:
    parts = s.split(".")
    base, flags = parts[0], set(parts[1:])
    assert base in ("attn", "local", "mlstm", "slstm", "rglru"), s
    assert flags <= {"moe", "xattn", "mla"}, s
    return LayerKind(base, "moe" in flags, "xattn" in flags, "mla" in flags)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                              # dense|vlm|ssm|audio|moe|hybrid
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Tuple[Tuple[str, ...], int], ...]  # ((pattern, repeats),...)
    # attention details
    window_size: int = 0                     # sliding window for "local"
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    softcap: float = 0.0                     # logit soft-capping (gemma-style)
    # MLP
    mlp_kind: str = "swiglu"                 # swiglu | squared_relu | gelu
    # optional sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # recurrent sizes
    lru_width: int = 0                       # RG-LRU state width (0 -> d_model)
    # embeddings / io
    tie_embeddings: bool = True
    prefix_len: int = 0                      # VLM image-prefix tokens
    cond_len: int = 0                        # cross-attention conditioning length
    cond_dim: int = 0                        # conditioning embed dim (0 -> d_model)
    max_seq_len: int = 8192
    # numerics
    dtype: str = "bfloat16"                  # activation dtype
    param_dtype: str = "float32"
    # implementation switches
    attention_impl: str = "reference"        # reference | pallas
    moe_impl: str = "dense"                  # dense | shard_map
    moe_chunk: int = 0                       # tokens per dispatch chunk (0 = all)
    remat: bool = True
    unroll_layers: bool = False              # python-loop segments (trip-count-
                                             # correct HLO cost analysis)
    # which shapes are lowerable (long_500k needs sub-quadratic paths)
    supports_long_context: bool = False

    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return sum(len(pat) * rep for pat, rep in self.segments)

    def layer_kinds(self):
        for pat, rep in self.segments:
            for _ in range(rep):
                for s in pat:
                    yield parse_kind(s)

    @property
    def has_recurrent(self) -> bool:
        return any(k.is_recurrent for k in self.layer_kinds())

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % max(1, self.num_kv_heads) == 0
        return self.num_heads // max(1, self.num_kv_heads)

    # -- parameter counting (for 6ND roofline math) ---------------------
    def param_count(self) -> int:
        """Total parameter count (embeddings included once if tied)."""
        d, h, kv, hd, ff, v = (self.d_model, self.num_heads, self.num_kv_heads,
                               self.head_dim, self.d_ff, self.vocab_size)
        n = v * d if self.tie_embeddings else 2 * v * d
        cd = self.cond_dim or d
        for k in self.layer_kinds():
            if k.base in ("attn", "local"):
                if k.mla:
                    m = self.mla
                    qk_head = m.qk_nope_dim + m.qk_rope_dim
                    n += d * m.q_lora_rank + m.q_lora_rank * h * qk_head
                    n += d * (m.kv_lora_rank + m.qk_rope_dim)
                    n += m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
                    n += h * m.v_head_dim * d
                else:
                    n += d * h * hd + 2 * d * kv * hd + h * hd * d
            elif k.base == "mlstm":
                dm = 2 * d  # up-projection width
                n += d * 2 * dm + 3 * dm * dm // 4 + dm * d  # qkv + gates approx
            elif k.base == "slstm":
                n += 4 * d * d + d * (4 * d) // 3 * 2
            elif k.base == "rglru":
                w = self.lru_width or d
                n += 2 * d * w + 2 * w * w // 8 + w * d + 2 * w  # in/gates/out
            if k.xattn:
                n += d * h * hd + 2 * cd * kv * hd + h * hd * d
            # MLP / MoE
            if k.moe:
                mo = self.moe
                n += d * mo.num_experts  # router
                n += mo.num_experts * 3 * d * mo.d_expert
                if mo.num_shared:
                    n += mo.num_shared * 3 * d * (mo.d_shared or mo.d_expert)
            elif ff > 0:
                mult = 3 if self.mlp_kind == "swiglu" else 2
                n += mult * d * ff
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full_moe = mo.num_experts * 3 * self.d_model * mo.d_expert
        act_moe = mo.top_k * 3 * self.d_model * mo.d_expert
        n_moe_layers = sum(1 for k in self.layer_kinds() if k.moe)
        return int(self.param_count() - n_moe_layers * (full_moe - act_moe))
