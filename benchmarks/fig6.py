"""Fig. 6: system-level validation with the loop-duration Reuse Collector
(paper SV-C).

Recreates Cori's three steps with the practical collector: (a) loop
durations, (b) DR + candidate ladder, (c) tuning trials -- including the
paper's DR/4 and DR/2 sanity points, which must move more data for no
runtime benefit ("don't break the data reuse")."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_json
from repro.core import (SimConfig, bin_trace, dominant_reuse, generate,
                        loop_duration_histogram, prune_insignificant,
                        run_cori, simulate)

FIG6_APPS = ["backprop", "kmeans", "hotspot", "lud"]


def run(apps=FIG6_APPS, quick: bool = False):
    apps = apps[:2] if quick else apps
    out = {}
    for app in apps:
        tr = generate(app)
        bins = bin_trace(tr)
        hist = prune_insignificant(
            loop_duration_histogram(tr.loop_durations, bin_width=1000))
        dr = dominant_reuse(hist)
        crun = run_cori(bins, tr, "reactive", collector="loops")
        probes = {}
        for label, p in [("DR/4", dr / 4), ("DR/2", dr / 2), ("DR", dr),
                         ("2DR", 2 * dr), ("3DR", 3 * dr)]:
            p = max(bins.block, int(p))
            r = simulate(bins, p, "reactive")
            probes[label] = {
                "period": r.period_requests,
                "slowdown_vs_inf": r.slowdown_vs_infinite_dram,
                "data_moved_frac": r.data_moved_pages / bins.num_pages,
            }
        out[app] = {
            "loop_histogram": {"values": hist.values.tolist(),
                               "counts": hist.counts.tolist()},
            "dominant_reuse_loops": dr,
            "cori_choice": crun.chosen_period,
            "cori_trials": crun.trials,
            "probes": probes,
            "sub_dr_moves_more_data": bool(
                probes["DR/4"]["data_moved_frac"]
                >= probes["DR"]["data_moved_frac"]),
        }
    save_json("fig6", out)
    return out


if __name__ == "__main__":
    o = run()
    for app, d in o.items():
        print(f"{app:9s} DR(loops)={d['dominant_reuse_loops']:8.0f} "
              f"choice={d['cori_choice']:8.0f} trials={d['cori_trials']} "
              f"subDR-moves-more={d['sub_dr_moves_more_data']}")
