"""Serving engine: single-stream generation with an attention monitor
feeding the tiering runtime.

``generate`` is the plain path (greedy/temperature sampling over
``model.decode_step``).  ``monitored_generate`` additionally recomputes the
attention distribution of one designated layer per step (the "accessed
bits" of the KV-tiering scheduler -- sampling one layer is the cheap
monitor for the DENSE decode path) and returns the per-page attention-mass
sequence that ``repro.memtier`` consumes.

The multi-request scheduler (``repro.serve.sched``) only uses
``make_monitor`` on its dense fallback path: in fully-paged mode the
masses originate inside ``kernels.paged_attention`` itself (a second
kernel output fused with the online-softmax accumulators), aggregated
across every attention layer by ``model.decode_step_paged`` /
``decode_macro_step`` -- no separate monitor recompute runs there, and
in macro-step mode the signal reaches the host once per movement period.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import model as mdl
from repro.models.config import ModelConfig, parse_kind
from repro.obs import telemetry as _obs

__all__ = ["generate", "monitored_generate", "page_mass_from_attention",
           "make_monitor", "monitor_slot"]


def _sample(logits, key, temperature: float):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def generate(params, cfg: ModelConfig, prompt_tokens, steps: int, *,
             max_len: Optional[int] = None, temperature: float = 0.0,
             cond=None, extra_embeds=None, key=None, mesh=None):
    """Greedy/temperature generation.  prompt_tokens: [B, P_len] int32.
    Returns tokens [B, steps]."""
    b, plen = prompt_tokens.shape
    prefix = cfg.prefix_len or 0
    max_len = max_len or (plen + prefix + steps)
    key = key if key is not None else jax.random.PRNGKey(0)
    logits, cache = mdl.prefill(params, cfg, prompt_tokens, cond=cond,
                                extra_embeds=extra_embeds, mesh=mesh)
    cache = mdl.pad_cache(cache, cfg, max_len)
    pos = jnp.full((b,), prefix + plen, jnp.int32)
    tok = _sample(logits[:, 0], key, temperature)[:, None]
    out = [tok]

    step_fn = jax.jit(lambda c, t, p: mdl.decode_step(
        params, cfg, c, t, p, cond=cond, mesh=mesh))
    for i in range(steps - 1):
        logits, cache = step_fn(cache, tok, pos)
        key = jax.random.fold_in(key, i)
        tok = _sample(logits[:, 0], key, temperature)[:, None]
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)


def monitor_slot(cfg: ModelConfig) -> Tuple[int, int]:
    """Pick the deepest full-attention slot as the monitor layer."""
    best = None
    for si, (pattern, _) in enumerate(cfg.segments):
        for j, ks in enumerate(pattern):
            kind = parse_kind(ks)
            if kind.base == "attn" and not kind.mla:
                best = (si, j)
    if best is None:
        raise ValueError("no full-attention layer to monitor "
                         f"in {cfg.name} (attention-free arch)")
    return best


def page_mass_from_attention(q, k, cache_pos, cur_pos, page_size: int,
                             n_pages: int):
    """Attention-probability mass per KV page for the monitor layer.
    q/k: [B,1|T,KV_or_H,D]; returns f32[B, n_pages] (per request -- the
    multi-request scheduler scatters each row into the global page-ID
    space; single-stream callers reduce over the batch axis themselves)."""
    d = q.shape[-1]
    rep = q.shape[2] // k.shape[2]
    kr = jnp.repeat(k, rep, axis=2)
    logits = jnp.einsum("bqhd,bthd->bhqt", q, kr).astype(jnp.float32)
    logits = logits / np.sqrt(d)
    valid = (cache_pos <= cur_pos[:, None]) & (cache_pos >= 0)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)           # [B,H,1,T]
    mass_tok = w.sum(axis=(1, 2))                 # [B,T]
    t = mass_tok.shape[1]
    pad = (-t) % page_size
    if pad:
        mass_tok = jnp.pad(mass_tok, ((0, 0), (0, pad)))
        cache_pos = jnp.pad(cache_pos, ((0, 0), (0, pad)),
                            constant_values=-1)
    # map cache slots -> logical pages by stored absolute position
    page_of = jnp.where(cache_pos >= 0, cache_pos // page_size, n_pages)
    mass = jnp.zeros((mass_tok.shape[0], n_pages + 1), jnp.float32)
    mass = mass.at[jnp.arange(mass.shape[0])[:, None], page_of].add(mass_tok)
    return mass[:, :n_pages]


def make_monitor(params, cfg: ModelConfig, page_size: int, n_pages: int):
    """Jitted per-step monitor: (cache, tok, pos) -> f32[B, n_pages].

    Recomputes the query of the designated monitor layer for the pending
    token and returns each request's attention mass per KV page -- the
    "accessed bits" feed shared by ``monitored_generate`` (single stream,
    reduced over batch) and ``repro.serve.sched.ContinuousBatcher``
    (per-request rows merged into the global page table)."""
    si, sj = monitor_slot(cfg)
    # monitor params of the LAST repeat of the chosen slot
    slot_p = jax.tree.map(lambda a: a[-1], params["segments"][si][sj])

    def monitor(cache, tok, pos):
        c = cache["segments"][si][sj]
        k = c["k"][-1]                          # [B,T,KV,D]
        x = L.embed(params["embed"], cfg, tok)
        h = L.rms_norm(x, slot_p["norm1"])
        q = jnp.einsum("bsd,dhk->bshk", h,
                       slot_p["attn"]["wq"].astype(h.dtype))
        if cfg.qk_norm:
            q = L.rms_norm(q, slot_p["attn"]["q_norm"])
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        return page_mass_from_attention(q, k, c["pos"][-1], pos, page_size,
                                        n_pages)

    return jax.jit(monitor)


def monitored_generate(params, cfg: ModelConfig, prompt_tokens, steps: int,
                       *, page_size: int = 16, temperature: float = 0.0,
                       cond=None, extra_embeds=None, key=None,
                       on_mass: Optional[Callable[[int, np.ndarray], None]]
                       = None):
    """generate() + per-step page-mass monitoring of one attention layer.
    Returns (tokens [B,steps], page_mass [steps, n_pages]).

    ``on_mass(step_idx, mass)`` is called with each step's per-page
    attention masses *before* the next decode step runs -- the hook the
    online tiering loop (TieringManager + OnlineTuner) hangs off, so the
    migration period can be re-tuned while generation is in flight."""
    b, plen = prompt_tokens.shape
    prefix = cfg.prefix_len or 0
    max_len = plen + prefix + steps
    n_pages = -(-max_len // page_size)
    key = key if key is not None else jax.random.PRNGKey(0)
    t_start = time.monotonic()
    if (r := _obs.RECORDER).enabled:
        r.emit("serve.stream", phase="start", tokens=int(b * steps),
               wall_ms=0.0)

    logits, cache = mdl.prefill(params, cfg, prompt_tokens, cond=cond,
                                extra_embeds=extra_embeds)
    cache = mdl.pad_cache(cache, cfg, max_len)
    pos = jnp.full((b,), prefix + plen, jnp.int32)
    tok = _sample(logits[:, 0], key, temperature)[:, None]
    out, masses = [tok], []

    step_fn = jax.jit(lambda c, t, p: mdl.decode_step(params, cfg, c, t, p,
                                                      cond=cond))
    mon_fn = make_monitor(params, cfg, page_size, n_pages)
    for i in range(steps - 1):
        masses.append(np.asarray(mon_fn(cache, tok, pos)).max(axis=0))
        if on_mass is not None:
            on_mass(i, masses[-1])
        logits, cache = step_fn(cache, tok, pos)
        key = jax.random.fold_in(key, i)
        tok = _sample(logits[:, 0], key, temperature)[:, None]
        out.append(tok)
        pos = pos + 1
    if (r := _obs.RECORDER).enabled:
        r.emit("serve.stream", phase="finish", tokens=int(b * steps),
               wall_ms=(time.monotonic() - t_start) * 1e3)
    return (jnp.concatenate(out, axis=1),
            np.stack(masses) if masses else np.zeros((0, n_pages)))
