"""DeepSeek-V3-671B [arXiv:2412.19437]: MLA + 1 shared/256 routed top-8 MoE.

First 3 layers dense (d_ff=18432), remaining 58 MoE (d_expert=2048).
MLA's compressed KV cache (kv_lora 512 + rope 64 per token) is the decode
cache -- the absorbed-matrix decode path is implemented.  MTP head omitted
(training-objective add-on; documented in DESIGN.md).
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        d_model=7168, num_heads=128, num_kv_heads=128, head_dim=128,
        d_ff=18432, vocab_size=129280,
        segments=((("attn.mla",), 3), (("attn.mla.moe",), 58)),
        mlp_kind="swiglu", tie_embeddings=False,
        moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                      num_shared=1, d_shared=2048),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe_impl="shard_map", rope_theta=10_000.0, max_seq_len=131072)
