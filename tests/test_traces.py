"""Trace generators: determinism, shape and published reuse structure."""
import numpy as np
import pytest

from repro.core import (available_traces, generate, reuse_distance_histogram,
                        reuse_distances)


@pytest.mark.parametrize("name", available_traces())
def test_generator_basic(name):
    tr = generate(name, seed=3)
    assert tr.pages.dtype == np.int32
    assert tr.num_accesses > 1000
    assert tr.pages.min() >= 0
    assert tr.pages.max() < tr.num_pages
    assert tr.loop_durations.sum() <= tr.num_accesses
    assert (tr.loop_durations > 0).all()


@pytest.mark.parametrize("name", available_traces())
def test_generator_deterministic(name):
    a = generate(name, seed=7)
    b = generate(name, seed=7)
    np.testing.assert_array_equal(a.pages, b.pages)
    np.testing.assert_array_equal(a.loop_durations, b.loop_durations)


def test_backprop_paper_reuse_structure():
    """Paper Fig. 3: backprop's dominant reuse distance equals the sweep
    length (~20k requests at paper scale) and appears (sweeps-1) times per
    page."""
    tr = generate("backprop")  # 16 sweeps over 4096 pages x 5 accesses
    hist = reuse_distance_histogram(tr.pages, bin_width=1000)
    assert hist.num_bins == 1
    sweep_len = tr.num_accesses / 16
    assert abs(hist.values[0] - sweep_len) < 1000
    # 15 appearances per page (16 strides) -> 15 * num_pages total.
    assert hist.counts[0] == 15 * tr.num_pages


def test_lud_decreasing_appearances():
    """Paper Fig. 3: triangular traversal -> appearance counts decrease with
    reuse distance."""
    tr = generate("lud")
    hist = reuse_distance_histogram(tr.pages, bin_width=1000)
    assert hist.num_bins >= 3
    order = np.argsort(hist.values)
    counts = hist.counts[order]
    # Broad trend: first half of distances has more appearances than last.
    half = counts.shape[0] // 2
    assert counts[:half].sum() > counts[half:].sum()


def test_reuse_distances_simple():
    # pages:  0 1 0 1 1  -> page0: gap=1 (one other access between)
    d = reuse_distances(np.array([0, 1, 0, 1, 1]))
    assert sorted(d.tolist()) == [0, 1, 1]


def test_kmeans_has_short_and_long_reuse():
    tr = generate("kmeans", num_pages=1024, iters=6)
    d = reuse_distances(tr.pages)
    assert (d < 100).sum() > 100       # hot centroid pages
    assert (d > 1000).sum() > 100      # sweep-length reuse
