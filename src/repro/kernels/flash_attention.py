"""Pallas TPU kernel: blockwise (flash) attention forward.

Online-softmax over KV tiles with running (max, sum) in VMEM scratch.
Grid: (batch*heads, q_tiles, kv_tiles); the kv dimension is the innermost
(sequential, "arbitrary") axis so the scratch accumulator carries across kv
steps and the output tile is written once at the last step.

MXU alignment: tiles are multiples of 128 in both seq and head dims; logits
accumulate in f32 (preferred_element_type).  Causal and sliding-window masks
are applied inside the tile; GQA is handled by the q->kv head index map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BKV = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bkv: int,
            n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                  # [bq, d]
    k = k_ref[0]                                  # [bkv, d]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)[:, None]
    k_pos = ki * bkv + jax.lax.iota(jnp.int32, bkv)[None, :]
    mask = jnp.ones_like(logits, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                           # [bq, 1]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bkv: int = DEFAULT_BKV,
                    interpret: bool = False):
    """q: [B,S,H,D]; k/v: [B,T,KV,D] (KV divides H).  Returns [B,S,H,D].

    Tiles must divide S/T.  Softmax scale = 1/sqrt(D).
    """
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    assert h % kv == 0
    rep = h // kv
    bq = min(bq, s)
    bkv = min(bkv, t)
    assert s % bq == 0 and t % bkv == 0, (s, bq, t, bkv)
    n_kv = t // bkv
    scale = 1.0 / np.sqrt(d)

    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, t, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, t, d)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bkv=bkv, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, qi, ki: (bh // rep, ki, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, qi, ki: (bh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
