"""Production mesh construction.

Single pod: 256 chips as (16, 16) ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16) ("pod", "data", "model");
the "pod" axis carries cross-DCN data parallelism (optionally with int8
gradient compression -- see repro.distributed.collectives).

Defined as functions (not module constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (possibly fake) host devices exist --
    used by tests and the local examples."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
