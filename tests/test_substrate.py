"""Data pipeline / checkpointing / fault-tolerance / optimizer tests."""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, DataPipeline, batch_at
from repro.ft.monitor import StepTimer
from repro.train import optim as O
from repro.train import step as S

CFG = C.reduced("stablelm-12b")


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_elastic():
    """Batch i is identical regardless of shard count (elastic resharding)."""
    dcfg = DataConfig(seed=3, global_batch=8, seq_len=32)
    full = batch_at(dcfg, CFG, index=5)
    halves = [batch_at(dcfg, CFG, index=5, shard=s, num_shards=2)
              for s in (0, 1)]
    np.testing.assert_array_equal(
        full["tokens"], np.concatenate([h["tokens"] for h in halves]))
    # deterministic across calls
    again = batch_at(dcfg, CFG, index=5)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])


def test_data_targets_are_shifted():
    dcfg = DataConfig(seed=0, global_batch=2, seq_len=16)
    b = batch_at(dcfg, CFG, 0)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
    assert (b["targets"][:, -1] == -1).all()


def test_pipeline_prefetch_matches_pure():
    dcfg = DataConfig(seed=1, global_batch=2, seq_len=16, prefetch=2)
    pipe = DataPipeline(dcfg, CFG)
    try:
        got = [next(pipe) for _ in range(3)]
    finally:
        pipe.close()
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"],
                                      batch_at(dcfg, CFG, i)["tokens"])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    ocfg = O.OptConfig()
    state, _ = S.init_state(jax.random.PRNGKey(0), CFG, ocfg)
    ckpt.save(tmp_path, 7, state)
    assert ckpt.latest_step(tmp_path) == 7
    state2, _ = S.init_state(jax.random.PRNGKey(1), CFG, ocfg)  # different
    restored = ckpt.restore(tmp_path, 7, state2)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    state = {"a": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, state, keep=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]
    assert ckpt.latest_step(tmp_path) == 4


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"a": jnp.zeros(3)})
    with pytest.raises(AssertionError, match="incompatible"):
        ckpt.restore(tmp_path, 1, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_quantized_state_checkpoint_roundtrip(tmp_path):
    ocfg = O.OptConfig(state_dtype="int8")
    state, _ = S.init_state(jax.random.PRNGKey(0), CFG, ocfg)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "targets": jnp.zeros((2, 8), jnp.int32)}
    state, _ = jax.jit(S.make_train_step(CFG, ocfg))(state, batch)
    ckpt.save(tmp_path, 1, state)
    restored = ckpt.restore(tmp_path, 1, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_adamw_state_dtypes_converge(dtype):
    """All state precisions reduce loss on an overfittable batch; bf16/int8
    track fp32 closely."""
    ocfg = O.OptConfig(lr=2e-3, state_dtype=dtype, warmup_steps=2,
                       decay_steps=50)
    state, _ = S.init_state(jax.random.PRNGKey(0), CFG, ocfg)
    dcfg = DataConfig(seed=0, global_batch=4, seq_len=32)
    batch = {k: jnp.asarray(v) for k, v in batch_at(dcfg, CFG, 0).items()}
    fn = jax.jit(S.make_train_step(CFG, ocfg))
    losses = []
    for _ in range(10):
        state, m = fn(state, batch)
        losses.append(float(m["loss"]))
    # int8 states carry quantisation noise early on; require clear progress
    # for exact states, directional progress for quantised ones.
    drop = 0.05 if dtype == "int8" else 0.2
    assert losses[-1] < losses[0] - drop, losses


def test_quantize_dequantize_error_bounded():
    from repro.train.optim import _pack, _unpack
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    q = _pack(x, "int8")
    y = _unpack(q, x.shape, "int8")
    err = float(jnp.abs(x - y).max())
    assert err <= float(jnp.abs(x).max()) / 127 + 1e-6


def test_lr_schedule_shape():
    ocfg = O.OptConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                       min_lr_frac=0.1)
    lrs = [float(O.schedule(ocfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 200]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]        # decay
    assert abs(lrs[-1] - 0.1) < 1e-6         # floor


def test_grad_clip_caps_update_norm():
    ocfg = O.OptConfig(lr=1e-2, grad_clip=0.5)
    params = {"w": jnp.zeros((10,))}
    st = O.init(params, ocfg)
    huge = {"w": jnp.full((10,), 1e6)}
    _, _, m = O.update(huge, st, params, ocfg)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_step_timer_detects_straggler():
    t = StepTimer(threshold=2.5, warmup=2)
    for i in range(6):
        t.start()
        time.sleep(0.01 if i != 4 else 0.08)
        t.stop(i)
    assert 4 in t.stragglers


def test_supervised_restart_resumes_training(tmp_path):
    """Injected crash -> supervisor restart -> resume from checkpoint ->
    run completes with exactly one restart (node-failure drill)."""
    from repro.ft.supervisor import SupervisorConfig, supervise
    env = dict(os.environ, PYTHONPATH="src", REPRO_FAIL_AT_STEP="8")
    metrics = tmp_path / "m.json"
    rep = supervise(
        [sys.executable, "-m", "repro.launch.train", "--arch", "olmoe-1b-7b",
         "--reduced", "--steps", "12", "--batch", "2", "--seq", "16",
         "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
         "--metrics-out", str(metrics)],
        workdir=tmp_path, cfg=SupervisorConfig(max_restarts=2), env=env)
    assert rep.exit_code == 0
    assert rep.restarts == 1
    rpt = json.loads(metrics.read_text())
    assert rpt["start"] == 8          # resumed from the step-8 checkpoint
    assert rpt["steps_run"] == 4
