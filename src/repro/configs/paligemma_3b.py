"""PaliGemma-3B [arXiv:2407.07726]: SigLIP frontend (STUB) + Gemma decoder.

The vision tower is a stub per the assignment: ``input_specs`` provides
pre-projected patch embeddings [B, 256, d_model]; the decoder applies a
bidirectional prefix mask over them (prefix-LM).
"""
from repro.models.config import ModelConfig

NUM_PATCHES = 256


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=257216,
        segments=((("attn",), 18),),
        mlp_kind="swiglu", tie_embeddings=True, prefix_len=NUM_PATCHES,
        rope_theta=10_000.0, max_seq_len=8192)
