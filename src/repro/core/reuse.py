"""Reuse Collector (paper §IV-A).

Two collection modes, exactly as the paper uses them:

  * ``reuse_distance_histogram`` -- page reuse distances from a full access
    trace (simulation mode).  Reuse distance of a pair of consecutive
    accesses to the same page = number of accesses to *other* pages in
    between (paper §III-C).  Distances are binned at a coarse granularity
    ("1000s of data accesses", §IV-D) and sub-bin distances (intra-burst
    re-touches of the page just accessed) are dropped -- they are invisible
    at page-scheduling timescales and would otherwise dominate the weighted
    average.

  * ``loop_duration_histogram`` -- the practical system-level proxy: the
    durations of (dynamic executions of) the application's primary loops.
    Our trace generators emit these alongside the trace; on a real system
    they come from compiler/binary instrumentation (§IV-A).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional, Tuple

import numpy as np

__all__ = [
    "ReuseHistogram",
    "StreamingReuseCollector",
    "reuse_distances",
    "reuse_distance_histogram",
    "loop_duration_histogram",
    "prune_insignificant",
]


@dataclasses.dataclass(frozen=True)
class ReuseHistogram:
    """Histogram of observed reuses.

    values:  representative reuse (bin centre), ascending, unit = accesses
             (trace mode) or loop-duration unit (proxy mode).
    counts:  appearance count per bin ("repeat_i" in Eq. 1).
    """

    values: np.ndarray
    counts: np.ndarray
    bin_width: int

    def __post_init__(self):
        assert self.values.shape == self.counts.shape

    @property
    def num_bins(self) -> int:
        return int(self.values.shape[0])


def reuse_distances(pages: np.ndarray) -> np.ndarray:
    """Per-access reuse distance (accesses to other pages since the previous
    access to the same page).  First touches are excluded.

    Vectorized: stable-sort accesses by page id; consecutive entries with the
    same page are consecutive accesses of that page.
    """
    pages = np.asarray(pages, dtype=np.int64)
    n = pages.shape[0]
    if n < 2:
        return np.empty(0, dtype=np.int64)
    order = np.argsort(pages, kind="stable")
    sp = pages[order]
    si = order.astype(np.int64)
    same = sp[1:] == sp[:-1]
    d = si[1:] - si[:-1] - 1
    return d[same]


def _bin(values: np.ndarray, bin_width: int, drop_sub_bin: bool
         ) -> Tuple[np.ndarray, np.ndarray]:
    if values.size == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    bins = values // bin_width
    if drop_sub_bin:
        bins = bins[bins > 0]
    if bins.size == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    uniq, counts = np.unique(bins, return_counts=True)
    centres = uniq * bin_width + bin_width // 2
    return centres, counts


def reuse_distance_histogram(pages: np.ndarray, bin_width: int = 1000,
                             drop_sub_bin: bool = True) -> ReuseHistogram:
    """Histogram of page reuse distances at `bin_width`-access granularity."""
    d = reuse_distances(pages)
    values, counts = _bin(d, bin_width, drop_sub_bin)
    return ReuseHistogram(values.astype(np.float64), counts.astype(np.float64),
                          bin_width)


def loop_duration_histogram(loop_durations: np.ndarray, bin_width: int = 1000,
                            drop_sub_bin: bool = False) -> ReuseHistogram:
    """Histogram of loop durations (the Reuse Collector's practical proxy)."""
    d = np.asarray(loop_durations, dtype=np.int64)
    values, counts = _bin(d, bin_width, drop_sub_bin)
    return ReuseHistogram(values.astype(np.float64), counts.astype(np.float64),
                          bin_width)


class StreamingReuseCollector:
    """Online Reuse Collector: sliding-window reuse gaps in the step domain.

    Feed one decode step at a time (``observe`` with the accessed page ids,
    or ``observe_mass`` with the raw per-page attention masses from the
    serving monitor).  A reuse gap is recorded whenever a page is re-accessed
    -- the step-domain analogue of the paper's reuse distance -- and gaps
    older than ``window`` steps are evicted, so the histogram always reflects
    the recent workload phase.  With ``window=None`` (or a window spanning
    the whole run) the histogram is identical to the batch computation over
    the full access log, which is the invariant the tests pin down.
    """

    def __init__(self, n_pages: int, window: Optional[int] = None,
                 bin_width: int = 4):
        self.n_pages = n_pages
        self.window = window
        self.bin_width = bin_width
        self.last_access = np.full(n_pages, -1, np.int64)
        self.step = 0
        self._gaps: Deque[Tuple[int, int]] = collections.deque()  # (t, gap)

    def observe(self, accessed_ids: np.ndarray, dt: int = 1) -> None:
        """Record one observation of accessed page ids.

        ``dt`` is the number of token-steps this observation covers
        (1 on the per-token path; the macro length when the serving loop
        samples accessed bits once per movement period).  The clock
        advances by ``dt``, so reuse gaps stay denominated in TOKEN
        steps either way -- the macro path quantises a gap to macro
        boundaries (the paper's accessed-bit scan has the same
        period-granularity quantisation), but the unit matches the one
        the derived period is applied in."""
        ids = np.asarray(accessed_ids, np.int64)
        prev = self.last_access[ids]
        t = self.step
        for g in (t - prev[prev >= 0]).tolist():
            self._gaps.append((t, g))
        self.last_access[ids] = t
        self.step += max(1, int(dt))
        if self.window is not None:
            horizon = self.step - self.window
            while self._gaps and self._gaps[0][0] < horizon:
                self._gaps.popleft()

    def observe_mass(self, page_mass: np.ndarray, threshold: float = 0.05,
                     relative: bool = False, dt: int = 1) -> None:
        """Record a step from raw per-page attention masses (the serving
        monitor's output): mass >= threshold counts as an access.

        With ``relative=True`` the threshold is a fraction of the step's
        maximum page mass instead of an absolute level.  The fully-paged
        serving path aggregates masses over ALL attention layers
        (head-normalised, layer-averaged, so each request's row sums to
        ~1 regardless of head count or depth); a relative threshold keeps
        the accessed-set size stable when the number of in-flight
        requests -- and hence the absolute mass a single page can draw --
        shifts."""
        mass = np.asarray(page_mass)
        if relative:
            threshold = threshold * float(mass.max(initial=0.0))
            threshold = max(threshold, np.finfo(np.float32).tiny)
        self.observe(np.nonzero(mass >= threshold)[0], dt=dt)

    @property
    def num_samples(self) -> int:
        return len(self._gaps)

    def histogram(self, significance: float = 0.05) -> ReuseHistogram:
        """Histogram of the windowed gaps (pruned, ready for Eq. 1)."""
        gaps = np.fromiter((g for _, g in self._gaps), np.int64,
                           count=len(self._gaps))
        h = loop_duration_histogram(gaps, bin_width=self.bin_width)
        return prune_insignificant(h, significance)

    def forget(self, ids: np.ndarray) -> None:
        """Invalidate specific pages (used when a logical page ID is freed
        and may be recycled for a different request: a later access by the
        new owner must not pair with the old owner's last access into a
        bogus reuse gap).  Gaps already recorded stay -- they were real."""
        self.last_access[np.asarray(ids, np.int64)] = -1

    def reset(self) -> None:
        """Forget all state (used when a phase change is detected)."""
        self.last_access.fill(-1)
        self.step = 0
        self._gaps.clear()


def prune_insignificant(hist: ReuseHistogram, frac: float = 0.05
                        ) -> ReuseHistogram:
    """Keep only reuse bins with *significant* appearances (>= frac of the
    largest bin).  The paper keys the insight on "page reuse distances with
    significant appearances" (SIII-C); sampling-noise tails (e.g. the
    geometric tail of hot-page re-touch gaps) would otherwise skew Eq. 1.
    Falls back to the unpruned histogram if everything would be pruned."""
    if hist.num_bins == 0:
        return hist
    thresh = float(hist.counts.max()) * frac
    keep = hist.counts >= thresh
    if not keep.any():
        return hist
    return ReuseHistogram(hist.values[keep], hist.counts[keep], hist.bin_width)
