"""Headline paper claims, validated end-to-end on full-size traces.

Claim 1 (SIII-A): fixed Table-I frequencies leave a 10%-100% performance gap
  vs the optimal frequency, and no single proposed value is near-best for
  every (application, scheduler).
Claim 2 (SV-A):  Cori lands within a few % of the optimal frequency.
Claim 3 (SV-B):  Cori needs several-fold fewer tuning trials than the
  insight-less Eq.-3 baselines (paper: 5x, from ~25 down to ~5).
Claim 4 (SIII-C): periods shorter than the dominant reuse hurt a reactive
  scheduler ("don't break the data reuse").

Full-size traces (N ~ 200k-420k requests) are needed so the Table-I periods
(100 ... 100 000 requests) stay distinct after clipping at Runtime/2; the
module-scoped fixture computes each study once.
"""
import numpy as np
import pytest

from repro.core import (SimConfig, baseline_trials_all, bin_trace,
                        dominant_reuse, generate, reuse_distance_histogram,
                        simulate, study)

APPS = ["backprop", "lud", "kmeans"]
SCHEDS = ["reactive", "predictive"]


@pytest.fixture(scope="module")
def studies():
    return {(name, sched): study(name, sched)
            for name in APPS for sched in SCHEDS}


def test_claim1_performance_gap(studies):
    """Worst Table-I frequency costs >=10% vs optimal in every cell and
    >=80% somewhere (paper: 10%-100%)."""
    worst_overall = 0.0
    for st in studies.values():
        gaps = st.table_i_slowdowns()
        worst = max(gaps.values())
        assert worst >= 0.10, f"{st.trace}/{st.scheduler}: worst gap {worst:.2%}"
        worst_overall = max(worst_overall, worst)
    assert worst_overall >= 0.80


def test_claim1_no_single_winner(studies):
    """Every Table-I value is >1% off the per-cell Table-I best somewhere."""
    near_best_everywhere = None
    for st in studies.values():
        gaps = st.table_i_slowdowns()
        best = min(gaps.values())
        near = {k for k, v in gaps.items() if v <= best + 0.01}
        near_best_everywhere = (near if near_best_everywhere is None
                                else near_best_everywhere & near)
    assert near_best_everywhere == set(), near_best_everywhere


def test_claim2_cori_near_optimal(studies):
    """Cori within 5% of optimal on average (paper: 3%), never >15% off."""
    slacks = [st.cori_slowdown_vs_optimal for st in studies.values()]
    assert np.mean(slacks) <= 0.05, f"mean slack {np.mean(slacks):.2%}"
    assert max(slacks) <= 0.15, f"max slack {max(slacks):.2%}"


def test_claim3_cori_fewer_trials(studies):
    """Cori's trials-to-best is several-fold below the Eq.-3 baselines
    averaged over orders (paper: 5x, 25 -> 5 trials)."""
    cori_trials, base_trials = [], []
    for (name, sched), st in studies.items():
        cori_trials.append(st.cori_trials_to_best)
        bins = bin_trace(generate(name))
        base_trials.extend(baseline_trials_all(bins, sched, seeds=3).values())
    ratio = np.mean(base_trials) / np.mean(cori_trials)
    assert ratio >= 3.0, (f"cori {np.mean(cori_trials):.1f} vs base "
                          f"{np.mean(base_trials):.1f} (ratio {ratio:.1f}x)")
    assert np.mean(cori_trials) <= 8.0


def test_claim4_dont_break_the_reuse():
    """Reactive scheduler: periods < dominant reuse are never better than the
    DR itself, and move more data for it (backprop, Fig. 6 insight)."""
    tr = generate("backprop", num_pages=512, sweeps=10, accesses_per_page=4)
    bins = bin_trace(tr)
    dr = dominant_reuse(reuse_distance_histogram(tr.pages, bin_width=1000))
    below = simulate(bins, max(100, int(dr / 4)), "reactive")
    at_dr = simulate(bins, int(dr), "reactive")
    assert below.runtime > at_dr.runtime
    assert below.data_moved_pages >= at_dr.data_moved_pages


def test_predictive_prefers_shorter_periods_than_reactive():
    """SIII-C: predictive schedulers peak at shorter (or equal) periods."""
    tr = generate("kmeans", num_pages=512, iters=8, accesses_per_page=3,
                  centroid_pages=16)
    bins = bin_trace(tr)
    from repro.core import exhaustive_periods, sweep
    periods = exhaustive_periods(bins, 48)
    r = sweep(bins, periods, "reactive")
    p = sweep(bins, periods, "predictive")
    best_r = min(r, key=lambda k: r[k].runtime)
    best_p = min(p, key=lambda k: p[k].runtime)
    assert best_p <= best_r


def test_cori_robust_across_capacity_ratios():
    """Cori's guidance holds at other DRAM:PMEM splits (G3 robustness)."""
    for frac in (0.1, 0.35):
        st = study("backprop", "reactive", cfg=SimConfig(fast_frac=frac),
                   num_pages=512, sweeps=10, accesses_per_page=4)
        assert st.cori_slowdown_vs_optimal <= 0.10, (frac,
                                                     st.cori_slowdown_vs_optimal)
