"""Training step: loss, gradient accumulation, compressed cross-pod DP.

``make_train_step`` builds a jittable ``(state, batch) -> (state, metrics)``
closure for a ModelConfig:

  * microbatching -- ``accum_steps`` splits the per-step batch and
    accumulates grads with ``lax.scan`` (bounds activation memory; the
    340B-class configs need it to fit v5e HBM -- see EXPERIMENTS.md).
  * remat         -- per-layer ``jax.checkpoint`` inside the model.
  * compressed cross-pod DP -- when the mesh has a "pod" axis and
    ``grad_compression=True``, the step runs under ``shard_map`` with the
    pod axis manual and all other axes auto: each pod computes grads for
    its pod-local batch (data/model parallelism inside stays automatic),
    and the cross-pod gradient reduction -- the only DCN-crossing
    collective -- goes through the int8 error-feedback ``compressed_psum``.

Loss: softmax cross-entropy, targets == IGNORE (-1) masked out (used for
VLM image-prefix positions and padding).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compat as _compat  # jax.shard_map on 0.4.x
from repro.distributed.collectives import compressed_psum
from repro.models import model as mdl
from repro.models.config import ModelConfig
from repro.train import optim

_compat.install()

IGNORE = -1


def cross_entropy(logits, targets):
    """Mean CE over non-ignored targets.  logits: [B,S,V] (any float dtype),
    targets: [B,S] int32 with IGNORE for masked positions."""
    logits = logits.astype(jnp.float32)
    mask = (targets != IGNORE)
    tgt = jnp.where(mask, targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Any], mesh=None,
            shard=lambda x, n: x, param_specs=None, pshard=None):
    logits, aux = mdl.forward(
        params, cfg, batch["tokens"],
        extra_embeds=batch.get("extra_embeds"), cond=batch.get("cond"),
        mesh=mesh, shard=shard, param_specs=param_specs, pshard=pshard)
    ce = cross_entropy(logits, batch["targets"])
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    return ce + aux_w * aux, {"ce": ce, "aux": aux}


def _split_microbatches(batch, accum: int):
    return jax.tree.map(
        lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch)


def cast_params_tree(params, dtype=jnp.bfloat16):
    """Cast f32 weight leaves to `dtype` (cast-before-gather: the FSDP
    all-gather then moves 2-byte words -- half the collective volume of
    gathering f32 masters).  Grads still accumulate into f32 masters via
    the cast's transpose."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a, params)


def make_train_step(cfg: ModelConfig, ocfg: optim.OptConfig, mesh=None,
                    shard=lambda x, n: x, accum_steps: int = 1,
                    grad_compression: bool = False, param_specs=None,
                    cast_params: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).
    state = {"params", "opt", "step"}."""
    from repro.distributed import sharding as _SHX
    pshard = _SHX.make_param_shard_fn(mesh) if param_specs is not None else None

    def grads_of(params, batch):
        if cast_params:
            params = cast_params_tree(params)
        if accum_steps == 1:
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, batch, mesh, shard, param_specs, pshard)
            return g, l, m

        micro = _split_microbatches(batch, accum_steps)

        def body(carry, mb):
            gsum, lsum = carry
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, mb, mesh, shard, param_specs, pshard)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                gsum, g)
            return (gsum, lsum + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
        g = jax.tree.map(lambda a: a / accum_steps, gsum)
        return g, lsum / accum_steps, {}

    use_pod = (grad_compression and mesh is not None
               and "pod" in mesh.axis_names and mesh.shape["pod"] > 1)

    def plain_step(state, batch):
        g, loss, _ = grads_of(state["params"], batch)
        new_p, new_opt, om = optim.update(g, state["opt"], state["params"],
                                          ocfg)
        metrics = {"loss": loss, **om}
        return {"params": new_p, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    if not use_pod:
        return plain_step

    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as _SH

    inner_shard = _SH.make_shard_fn(mesh, exclude=("pod",))

    def grads_of_pod(params, batch):
        if accum_steps == 1:
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, batch, mesh, inner_shard)
            return g, l, m
        micro = _split_microbatches(batch, accum_steps)

        def body(carry, mb):
            gsum, lsum = carry
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, mb, mesh, inner_shard)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                gsum, g)
            return (gsum, lsum + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
        return (jax.tree.map(lambda a: a / accum_steps, gsum),
                lsum / accum_steps, {})

    def pod_local(params, opt, step, batch):
        g, loss, _ = grads_of_pod(params, batch)
        # int8 error-feedback all-reduce across pods (the only DCN hop)
        g = jax.tree.map(lambda x: compressed_psum(x, "pod"), g)
        loss = jax.lax.pmean(loss, "pod")
        new_p, new_opt, om = optim.update(g, opt, params, ocfg)
        return new_p, new_opt, step + 1, {"loss": loss, **om}

    def pod_step(state, batch):
        fn = jax.shard_map(
            pod_local, mesh=mesh,
            in_specs=(P(), P(), P(), P("pod")),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
            axis_names={"pod"},
        )
        new_p, new_opt, step, metrics = fn(state["params"], state["opt"],
                                           state["step"], batch)
        return {"params": new_p, "opt": new_opt, "step": step}, metrics

    return pod_step


def init_state(key, cfg: ModelConfig, ocfg: optim.OptConfig):
    params, specs = mdl.init(key, cfg)
    opt = optim.init(params, ocfg)
    return ({"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)},
            specs)


def state_specs(param_specs, ocfg: optim.OptConfig):
    return {"params": param_specs,
            "opt": optim.state_specs(param_specs, ocfg),
            "step": None}
