"""Trace-driven hybrid-memory simulator (paper §II-B), JAX implementation.

Models a flat DRAM+PMEM system in the *request domain*: a period is a fixed
number of memory requests (paper: "we assume that a period is the time
duration when a fixed number of memory requests are issued").  Runtime is the
aggregate access latency under the current placement, plus bandwidth-pressure
delays, plus constant per-migration and per-period scheduler overheads
(values in the spirit of [22], [30]).

Defaults follow the paper exactly where stated:
  * fast:slow latency ratio 1:3, bandwidth ratio 1:0.37  (§II-B, from [19])
  * fast capacity = 20% of the application footprint      (Figs. 1/3/5/6)
  * interleaved initial placement                         (§II-B)
  * per-period swap of hot pages in / LRU pages out, capped by the fast
    capacity (swaps are (hot, LRU) pairs)                 (§II-B)

Two page schedulers (paper §II-B):
  * reactive   -- EMA ("exponential moving average ... of the page's accessed
                 history") over past periods ranks pages.
  * predictive -- oracular knowledge of the upcoming period's counts ([11],
                 [30] oracular baseline).

Implementation strategy: the trace is pre-binned once into fixed-size blocks
(`TraceBins`), so one compiled `lax.scan` serves every candidate period
length (periods are whole numbers of blocks, padded to a power of two).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.traces import Trace

__all__ = [
    "SimConfig",
    "TraceBins",
    "SimResult",
    "bin_trace",
    "simulate",
    "sweep",
    "sweep_loop",
    "simulate_reference",
    "SCHEDULERS",
]

SCHEDULERS = ("reactive", "predictive")

# Default monitoring block: 100 requests == the finest period in Table I
# (Kleio).  All candidate periods are multiples of this block.
DEFAULT_BLOCK = 100


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Hybrid memory + page scheduler cost model.

    Time unit == one fast-memory access.
    """

    fast_frac: float = 0.20        # DRAM share of footprint (20%:80% paper split)
    lat_fast: float = 1.0
    lat_slow: float = 3.0          # 1:3 latency ratio (paper §II-B)
    bw_slow: float = 0.37          # slow tier serves 0.37 req/unit vs 1.0 fast
    bw_penalty: float = 3.0        # extra units per over-bandwidth slow request
    # Scheduler overheads ([22],[30]): one unit == one fast access (~100 ns
    # LLC miss).  A move_pages() swap is us-scale -> ~20 units; every period
    # the scheduler scans the whole footprint's PTE accessed bits -> cost
    # proportional to the footprint, plus a fixed wakeup.
    mig_cost: float = 20.0         # constant delay per page migration
    period_cost: float = 10.0      # fixed delay per period (wakeup)
    scan_cost_per_page: float = 0.25  # PTE-scan cost x footprint, per period
    ema_alpha: float = 0.5         # smoothing factor for the accessed-history EMA

    def fast_capacity(self, num_pages: int) -> int:
        return max(1, int(round(num_pages * self.fast_frac)))

    def period_overhead(self, num_pages: int) -> float:
        return self.period_cost + self.scan_cost_per_page * num_pages


@dataclasses.dataclass(frozen=True)
class TraceBins:
    """Per-block page-access histogram of a trace (computed once per trace,
    shared by every candidate period / scheduler)."""

    name: str
    block_hist: np.ndarray  # float32[num_blocks, num_pages]
    block: int              # requests per block
    num_accesses: int
    num_pages: int

    @property
    def num_blocks(self) -> int:
        return int(self.block_hist.shape[0])


@dataclasses.dataclass(frozen=True)
class SimResult:
    runtime: float           # simulated time units
    data_moved_pages: float  # pages migrated (both directions of each swap)
    migrations: float        # swap count
    fast_hits: float         # requests serviced from fast memory
    num_accesses: int
    period_requests: int
    scheduler: str

    @property
    def slowdown_vs_infinite_dram(self) -> float:
        return self.runtime / (self.num_accesses * 1.0)

    @property
    def fast_hitrate(self) -> float:
        return self.fast_hits / max(1, self.num_accesses)


def bin_trace(trace: Trace, block: int = DEFAULT_BLOCK,
              impl: str = "numpy") -> TraceBins:
    """Bin a trace into [num_blocks, num_pages] access counts.

    impl:
      * "numpy"     -- vectorised bincount on host (default; fastest on CPU).
      * "interpret" / "pallas" -- the fused ``kernels/page_hist`` histogram
        kernel, one invocation per monitoring block (the accelerator path:
        on TPU the access slice never leaves the device).
    """
    pages = np.asarray(trace.pages, dtype=np.int64)
    n = pages.shape[0]
    num_blocks = (n + block - 1) // block
    if impl == "numpy":
        blk = np.arange(n, dtype=np.int64) // block
        flat = blk * trace.num_pages + pages
        hist = np.bincount(flat, minlength=num_blocks * trace.num_pages)
        hist = hist.reshape(num_blocks, trace.num_pages).astype(np.float32)
    else:
        hist = _bin_trace_page_hist(pages, trace.num_pages, num_blocks, block,
                                    impl)
    return TraceBins(trace.name, hist, block, n, trace.num_pages)


def _bin_trace_page_hist(pages: np.ndarray, num_pages: int, num_blocks: int,
                         block: int, impl: str) -> np.ndarray:
    """Per-block binning through the Pallas ``page_hist`` kernel."""
    from repro.kernels import ops
    pad = num_blocks * block - pages.shape[0]
    ids = np.concatenate([pages, np.full(pad, -1, np.int64)])
    ids = jnp.asarray(ids.reshape(num_blocks, block), jnp.int32)
    zeros = jnp.zeros((num_pages,), jnp.float32)
    counts = jax.lax.map(
        lambda i: ops.page_hist(i, zeros, impl=impl)[0], ids)
    return np.asarray(counts, np.float32)


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1)).bit_length()


def _aggregate_periods(bins: TraceBins, k_blocks: int) -> Tuple[np.ndarray, int]:
    """Sum consecutive k blocks into periods; pad period count to pow2."""
    nb, npg = bins.block_hist.shape
    num_periods = (nb + k_blocks - 1) // k_blocks
    pad_blocks = num_periods * k_blocks - nb
    h = bins.block_hist
    if pad_blocks:
        h = np.concatenate([h, np.zeros((pad_blocks, npg), np.float32)], axis=0)
    ph = h.reshape(num_periods, k_blocks, npg).sum(axis=1)
    p2 = _next_pow2(num_periods)
    if p2 > num_periods:
        ph = np.concatenate([ph, np.zeros((p2 - num_periods, npg), np.float32)],
                            axis=0)
    return ph, num_periods


def interleaved_indices(num_pages: int, capacity: int) -> np.ndarray:
    """The paper's SII-B initial placement: `capacity` page indices evenly
    interleaved over the footprint.  Single source of truth shared by the
    simulator, the symbolic tiering replay and the physical page pools."""
    return (np.arange(capacity, dtype=np.int64) * num_pages) // max(1,
                                                                    capacity)


def _interleaved_init(num_pages: int, capacity: int) -> np.ndarray:
    """Initial interleaved placement as a residency mask."""
    init = np.zeros(num_pages, dtype=bool)
    init[interleaved_indices(num_pages, capacity)] = True
    return init


def _scan_one(period_hist, num_real, init_fast, *, predictive: bool,
              capacity: int, lat_fast, lat_slow, bw_slow, bw_penalty,
              mig_cost, period_overhead, ema_alpha):
    """Scan over periods.  Carry = placement / hotness / recency / totals."""
    num_pages = period_hist.shape[1]

    def step(carry, inp):
        in_fast, hotness, last_access, i = carry
        counts = inp
        valid = i < num_real

        # --- scheduler decision at period start -------------------------
        rank = counts if predictive else hotness
        # Lexicographic tiebreak: primary hotness, then recency (LRU evict),
        # then residency (avoid gratuitous swaps).  Recency term in [0,1).
        recency = (last_access + 1.0) / (i + 2.0)
        score = rank * 1e6 + recency + 0.5 * in_fast.astype(jnp.float32)
        _, top_idx = jax.lax.top_k(score, capacity)
        new_fast = jnp.zeros((num_pages,), jnp.bool_).at[top_idx].set(True)
        new_fast = jnp.where(valid, new_fast, in_fast)

        swaps = jnp.sum(jnp.logical_and(new_fast, ~in_fast).astype(jnp.float32))

        # --- service this period's accesses -----------------------------
        lat = jnp.where(new_fast, lat_fast, lat_slow)
        total = jnp.sum(counts)
        n_fast = jnp.sum(counts * new_fast.astype(jnp.float32))
        n_slow = total - n_fast
        latency = n_fast * lat_fast + n_slow * lat_slow
        bw_extra = jnp.maximum(0.0, n_slow - bw_slow * total) * bw_penalty
        period_rt = latency + bw_extra + swaps * mig_cost + period_overhead
        period_rt = jnp.where(valid, period_rt, 0.0)
        swaps = jnp.where(valid, swaps, 0.0)
        n_fast = jnp.where(valid, n_fast, 0.0)

        # --- post-period state updates ----------------------------------
        hotness = jnp.where(valid, ema_alpha * counts + (1 - ema_alpha) * hotness,
                            hotness)
        last_access = jnp.where(jnp.logical_and(valid, counts > 0),
                                jnp.float32(i), last_access)
        carry = (new_fast, hotness, last_access, i + 1)
        return carry, (period_rt, swaps, n_fast)

    init = (
        init_fast,
        jnp.zeros((num_pages,), jnp.float32),
        jnp.full((num_pages,), -1.0, jnp.float32),
        jnp.int32(0),
    )
    _, (rts, swaps, fast_hits) = jax.lax.scan(step, init, period_hist)
    return jnp.sum(rts), jnp.sum(swaps), jnp.sum(fast_hits)


_sim_scan = functools.partial(jax.jit, static_argnames=("predictive",
                                                        "capacity"))(_scan_one)


@functools.partial(jax.jit, static_argnames=("predictive", "capacity"))
def _sim_scan_batch(period_hists, num_reals, init_fast, *, predictive: bool,
                    capacity: int, lat_fast, lat_slow, bw_slow, bw_penalty,
                    mig_cost, period_overhead, ema_alpha):
    """vmap of `_scan_one` over a [C, P, num_pages] candidate stack.

    Every candidate shares the block grid, the initial placement and the
    cost constants; only its period histogram (aggregated at its own period
    length, zero-padded to the stack's P) and real-period count differ.  One
    compile + one fused scan replaces C sequential `simulate` calls."""
    one = functools.partial(
        _scan_one, predictive=predictive, capacity=capacity,
        lat_fast=lat_fast, lat_slow=lat_slow, bw_slow=bw_slow,
        bw_penalty=bw_penalty, mig_cost=mig_cost,
        period_overhead=period_overhead, ema_alpha=ema_alpha)
    return jax.vmap(lambda ph, nr: one(ph, nr, init_fast))(period_hists,
                                                           num_reals)


@functools.partial(jax.jit,
                   static_argnames=("predictive", "capacity", "lat_fast",
                                    "lat_slow", "bw_slow", "bw_penalty",
                                    "mig_cost", "period_overhead",
                                    "ema_alpha", "interpret"))
def _sim_scan_batch_fused(period_hists, num_reals, init_fast, *,
                          predictive: bool, capacity: int, lat_fast,
                          lat_slow, bw_slow, bw_penalty, mig_cost,
                          period_overhead, ema_alpha,
                          interpret: bool = False):
    """The Pallas port of ``_sim_scan_batch``: candidates on the kernel
    grid, the period scan carried in VMEM scratch, placement selection by
    rank (exact ``lax.top_k`` membership) -- see ``kernels.sim_step``.
    Bit-identical results; one fused launch per candidate stack."""
    from repro.kernels.sim_step import sim_scan
    return sim_scan(period_hists, num_reals, init_fast,
                    predictive=predictive, capacity=capacity,
                    lat_fast=lat_fast, lat_slow=lat_slow, bw_slow=bw_slow,
                    bw_penalty=bw_penalty, mig_cost=mig_cost,
                    period_overhead=period_overhead, ema_alpha=ema_alpha,
                    interpret=interpret)


def simulate(bins: TraceBins, period_requests: int, scheduler: str = "reactive",
             cfg: SimConfig = SimConfig()) -> SimResult:
    """Simulate one (trace, period, scheduler) combination."""
    if scheduler not in SCHEDULERS:
        raise ValueError(f"scheduler must be one of {SCHEDULERS}")
    k = max(1, int(round(period_requests / bins.block)))
    period_hist, num_periods = _aggregate_periods(bins, k)
    capacity = cfg.fast_capacity(bins.num_pages)
    init_fast = jnp.asarray(_interleaved_init(bins.num_pages, capacity))
    rt, swaps, fast_hits = _sim_scan(
        jnp.asarray(period_hist), jnp.int32(num_periods), init_fast,
        predictive=(scheduler == "predictive"), capacity=capacity,
        lat_fast=cfg.lat_fast, lat_slow=cfg.lat_slow, bw_slow=cfg.bw_slow,
        bw_penalty=cfg.bw_penalty, mig_cost=cfg.mig_cost,
        period_overhead=cfg.period_overhead(bins.num_pages),
        ema_alpha=cfg.ema_alpha)
    return SimResult(
        runtime=float(rt), data_moved_pages=float(swaps) * 2.0,
        migrations=float(swaps), fast_hits=float(fast_hits),
        num_accesses=bins.num_accesses, period_requests=k * bins.block,
        scheduler=scheduler)


def sweep_loop(bins: TraceBins, periods, scheduler: str = "reactive",
               cfg: SimConfig = SimConfig()) -> Dict[int, SimResult]:
    """Per-candidate `simulate` loop (the pre-batching reference path).

    Each distinct period aggregation has its own scan length, so this path
    pays one XLA compile per candidate -- kept as the equivalence oracle and
    the benchmark baseline for the batched `sweep`."""
    out: Dict[int, SimResult] = {}
    for p in periods:
        r = simulate(bins, int(p), scheduler, cfg)
        out[r.period_requests] = r
    return out


@functools.partial(jax.jit, static_argnames=("m",))
def _agg_rows(h, *, m: int):
    """Sum every m consecutive rows (device-side period aggregation)."""
    p = h.shape[0]
    pp = -(-p // m) * m
    if pp > p:
        h = jnp.pad(h, ((0, pp - p), (0, 0)))
    return h.reshape(pp // m, m, h.shape[1]).sum(axis=1)


# Device-resident prefix sums of each TraceBins' block histogram, keyed by
# object identity and evicted when the bins are collected: tuners call
# `sweep` many times on the same trace, and the transfer + cumsum is by far
# the most expensive part of a warm sweep.
_CUM_CACHE: Dict[int, jnp.ndarray] = {}


def _cum_hist(bins: TraceBins) -> jnp.ndarray:
    import weakref
    key = id(bins)
    cum = _CUM_CACHE.get(key)
    if cum is None:
        cum = jnp.cumsum(jnp.asarray(bins.block_hist), axis=0)
        _CUM_CACHE[key] = cum
        weakref.finalize(bins, _CUM_CACHE.pop, key, None)
    return cum


def _device_period_hists(bins: TraceBins, ks) -> Dict[int, Tuple[jnp.ndarray,
                                                                 int]]:
    """Period histograms for every candidate, aggregated on device.

    The block histogram crosses to the device once and is prefix-summed
    along the block axis; each candidate's period rows are then differences
    of the cumulative sums at its own period boundaries -- O(periods)
    gathers per candidate instead of a full pass over the block grid.
    Counts are integer-valued, so as long as per-page cumulative counts stay
    below 2**24 the float32 prefix sums (and hence the diffs) are exact and
    the result is bit-identical to host-side `_aggregate_periods`; beyond
    that the per-candidate reshape-sum path is used instead."""
    ks = sorted(set(ks))
    if bins.num_accesses >= 2 ** 24:   # cumsum no longer exact in float32
        base = jnp.asarray(bins.block_hist)
        return {k: (_agg_rows(base, m=k), -(-bins.num_blocks // k))
                for k in ks}
    cum = _cum_hist(bins)
    zero = jnp.zeros((1, bins.num_pages), cum.dtype)
    out: Dict[int, Tuple[jnp.ndarray, int]] = {}
    for k in ks:
        nr = -(-bins.num_blocks // k)
        ends = np.minimum(np.arange(1, nr + 1) * k, bins.num_blocks) - 1
        at_ends = cum[jnp.asarray(ends)]
        out[k] = (at_ends - jnp.concatenate([zero, at_ends[:-1]]), nr)
    return out


# Candidate stacks are chunked so a single [C, P, num_pages] stack never
# exceeds this many float32 elements (~256 MB).
_SWEEP_CHUNK_ELEMS = 64 * 1024 * 1024


def sweep(bins: TraceBins, periods, scheduler: str = "reactive",
          cfg: SimConfig = SimConfig(), impl: str = "jax"
          ) -> Dict[int, SimResult]:
    """Simulate a set of candidate periods (requests) in one batched pass.

    The per-candidate `simulate` loop (kept as `sweep_loop`) re-reads and
    re-aggregates the full block histogram on host and launches one scan per
    candidate.  Here the whole ladder is evaluated one-shot: device-side
    hierarchical aggregation (`_device_period_hists`), then candidates with
    equal pow2-padded period counts are stacked and driven through a single
    `jax.vmap`-batched scan (`_sim_scan_batch`).  Results match `sweep_loop`
    exactly -- same per-period math, padded periods masked by each
    candidate's real count.

    ``impl`` selects the scan engine: "jax" (the vmapped ``lax.scan``,
    default), or "pallas"/"interpret" for the fused ``kernels.sim_step``
    kernel (candidates on the grid, carry in VMEM scratch; bit-identical
    selection via rank instead of ``lax.top_k``)."""
    if scheduler not in SCHEDULERS:
        raise ValueError(f"scheduler must be one of {SCHEDULERS}")
    ks = sorted({max(1, int(round(int(p) / bins.block))) for p in periods})
    if not ks:
        return {}
    capacity = cfg.fast_capacity(bins.num_pages)
    init_fast = jnp.asarray(_interleaved_init(bins.num_pages, capacity))
    hists = _device_period_hists(bins, ks)
    # Group candidates whose pow2-padded period counts coincide: within a
    # group the stack has zero padding waste, so the batch does the same
    # arithmetic as the loop in 1/C the scan iterations.
    groups: Dict[int, List[int]] = {}
    for k in ks:
        groups.setdefault(_next_pow2(hists[k][1]), []).append(k)
    out: Dict[int, SimResult] = {}
    for p2, group in groups.items():
        max_c = max(1, _SWEEP_CHUNK_ELEMS // (p2 * bins.num_pages))
        for lo in range(0, len(group), max_c):
            chunk = group[lo: lo + max_c]
            stack = jnp.stack(
                [jnp.pad(hists[k][0], ((0, p2 - hists[k][0].shape[0]), (0, 0)))
                 for k in chunk])
            nreals = jnp.asarray([hists[k][1] for k in chunk], jnp.int32)
            scan_fn = (_sim_scan_batch if impl == "jax"
                       else functools.partial(
                           _sim_scan_batch_fused,
                           interpret=(impl == "interpret")))
            rts, swaps, hits = scan_fn(
                stack, nreals, init_fast,
                predictive=(scheduler == "predictive"), capacity=capacity,
                lat_fast=cfg.lat_fast, lat_slow=cfg.lat_slow,
                bw_slow=cfg.bw_slow, bw_penalty=cfg.bw_penalty,
                mig_cost=cfg.mig_cost,
                period_overhead=cfg.period_overhead(bins.num_pages),
                ema_alpha=cfg.ema_alpha)
            for i, k in enumerate(chunk):
                out[k * bins.block] = SimResult(
                    runtime=float(rts[i]),
                    data_moved_pages=float(swaps[i]) * 2.0,
                    migrations=float(swaps[i]), fast_hits=float(hits[i]),
                    num_accesses=bins.num_accesses,
                    period_requests=k * bins.block, scheduler=scheduler)
    return out


def exhaustive_periods(bins: TraceBins, max_candidates: int = 128) -> np.ndarray:
    """The O(N) candidate space at block granularity: every period in
    [block, N/2], geometrically subsampled to `max_candidates` values."""
    lo, hi = bins.block, max(bins.block, bins.num_accesses // 2)
    ks = np.unique(np.round(np.geomspace(lo, hi, max_candidates)
                            / bins.block).astype(np.int64))
    # Same snapping as `simulate` (round-to-block), endpoint included.
    ks = np.unique(np.concatenate(
        [ks[ks >= 1], [max(1, int(round(hi / bins.block)))]]))
    return ks * bins.block


# ----------------------------------------------------------------------------
# Pure-python reference (oracle for tests; mirrors _sim_scan step for step).
# ----------------------------------------------------------------------------

def simulate_reference(bins: TraceBins, period_requests: int,
                       scheduler: str = "reactive",
                       cfg: SimConfig = SimConfig()) -> SimResult:
    k = max(1, int(round(period_requests / bins.block)))
    period_hist, num_periods = _aggregate_periods(bins, k)
    num_pages = bins.num_pages
    capacity = cfg.fast_capacity(num_pages)
    in_fast = _interleaved_init(num_pages, capacity)
    hotness = np.zeros(num_pages, np.float64)
    last_access = np.full(num_pages, -1.0)
    runtime = swaps_total = fast_hits = 0.0
    for i in range(num_periods):
        counts = period_hist[i].astype(np.float64)
        rank = counts if scheduler == "predictive" else hotness
        recency = (last_access + 1.0) / (i + 2.0)
        # float32 scoring to match the jitted scan bit-for-bit on ties.
        score = (np.float32(1e6) * rank.astype(np.float32)
                 + recency.astype(np.float32)
                 + np.float32(0.5) * in_fast.astype(np.float32))
        top = np.argsort(-score, kind="stable")[:capacity]
        new_fast = np.zeros(num_pages, bool)
        new_fast[top] = True
        swaps = float(np.sum(new_fast & ~in_fast))
        total = counts.sum()
        n_fast = float(counts[new_fast].sum())
        n_slow = total - n_fast
        runtime += (n_fast * cfg.lat_fast + n_slow * cfg.lat_slow
                    + max(0.0, n_slow - cfg.bw_slow * total) * cfg.bw_penalty
                    + swaps * cfg.mig_cost + cfg.period_overhead(num_pages))
        swaps_total += swaps
        fast_hits += n_fast
        hotness = cfg.ema_alpha * counts + (1 - cfg.ema_alpha) * hotness
        last_access = np.where(counts > 0, float(i), last_access)
        in_fast = new_fast
    return SimResult(runtime=runtime, data_moved_pages=swaps_total * 2,
                     migrations=swaps_total, fast_hits=fast_hits,
                     num_accesses=bins.num_accesses,
                     period_requests=k * bins.block, scheduler=scheduler)
