"""Continuous-batching serving driver: online Cori tuned by real traffic.

Two stages:

  1. A model-backed ``ContinuousBatcher`` serves a handful of requests
     FULLY PAGED through one shared HBM page pool (admission mid-flight
     with batched prefills, retire on length, every attention layer
     decoding off the pool's slot tables, all-layer masses merged into
     the global page table) and cross-checks every request's tokens
     against per-request ``generate`` -- the scheduler must be invisible
     to the output.
  2. A model-free ``TrafficScheduler`` replays a long Poisson stream
     whose mix shifts mid-run, with the ``OnlineTuner`` re-tuning the
     shared pool's migration period from the merged traffic reuse.

    PYTHONPATH=src python examples/serve_traffic.py [--steps 1000]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import OnlineTuner, shifting_mix_stream
from repro.memtier import SharedPagedPools, TierConfig, TieringManager
from repro.models import model as mdl
from repro.serve.engine import generate
from repro.serve.sched import (ContinuousBatcher, Request, TrafficMonitor,
                               TrafficScheduler)


def serve_batched(args):
    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    page = 4
    pools = SharedPagedPools.create(64, 24)
    mgr = TieringManager(64, TierConfig(page_size=page, hbm_pages=24,
                                        period_steps=2))
    tuner = OnlineTuner(64, default_period=2, profile_steps=8, trial_steps=4)
    batcher = ContinuousBatcher(params, cfg, max_active=args.batch,
                                max_len=48, page_size=page,
                                monitor=TrafficMonitor(pools, mgr, tuner))
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(6, 14))).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(6, 12)),
                            key=jax.random.PRNGKey(100 + i)))
        batcher.submit(reqs[-1])
    got = batcher.run()
    ok = all(
        np.asarray(generate(params, cfg, jnp.asarray(r.prompt)[None],
                            steps=r.max_new_tokens,
                            key=jax.random.PRNGKey(100 + r.rid))
                   )[0].tolist() == got[r.rid]
        for r in reqs)
    mode = "fully-paged" if batcher.paged else "dense"
    print(f"batched serve ({mode}): {len(got)} requests over "
          f"{batcher.step_idx} scheduler steps on {args.batch} rows; "
          f"token-identical to per-request generate: {ok}")
    print(f"  shared pool: {mgr.migrations} migrations, {mgr.hits} hits / "
          f"{mgr.misses} misses, peak {pools.peak_allocated} pages, "
          f"tuner={tuner.state} period={tuner.period}")


def serve_traffic(args):
    n_logical, hbm, page = 256, 32, 16
    phase = args.steps // 2
    specs = shifting_mix_stream(
        [(phase, 0.1, {"random": 1.0}), (phase, 0.1, {"sink": 1.0})],
        prompt_len=(16, 48), new_tokens=(40, 100), seed=0)
    pools = SharedPagedPools.create(n_logical, hbm)
    mgr = TieringManager(n_logical, TierConfig(page_size=page,
                                               hbm_pages=hbm,
                                               period_steps=8))
    tuner = OnlineTuner(n_logical, default_period=8,
                        drift_ratio=1.5, drift_patience=3)
    sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr, tuner),
                             page_size=page, max_active=8)
    sched.run(args.steps)
    print(f"\ntraffic: {sched.completed}/{len(specs)} requests completed "
          f"over {args.steps} steps (mix shift at step {phase})")
    print(f"  online Cori: state={tuner.state} period={tuner.period}, "
          f"{tuner.retunes} tune cycles, DR={tuner.dominant_reuse}")
    print(f"  period history (step, period): {tuner.history}")
    print(f"  shared pool: {mgr.migrations} migrations, modeled time "
          f"{mgr.modeled_time:.0f}, hit rate "
          f"{mgr.hits / max(1, mgr.hits + mgr.misses):.2f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1000,
                    help="traffic-replay decode steps")
    ap.add_argument("--batch", type=int, default=3,
                    help="continuous-batch rows (max in-flight requests)")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args(argv)
    serve_batched(args)
    serve_traffic(args)


if __name__ == "__main__":
    main()
