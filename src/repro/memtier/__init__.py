"""Cori-tuned KV-page tiering runtime (the paper's technique on TPU).

``replay`` drives a TieringManager over a per-step page-access workload
(real attention masses from ``repro.serve``'s monitor, or synthetic
patterns from ``workload``); ``cori_tune_period`` runs the full Cori loop
(profile -> DR -> candidate ladder -> trial windows) against it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core import cori
from repro.memtier.tiering import (PagedPools, SharedPagedPools, TierConfig,
                                   TieringManager, bucket_pages,
                                   write_pages_batched, write_state_pages)

__all__ = ["PagedPools", "SharedPagedPools", "TierConfig", "TieringManager",
           "bucket_pages", "write_pages_batched", "write_state_pages",
           "replay", "online_replay", "cori_tune_period",
           "resident_mask", "interleaved_resident"]


def interleaved_resident(n: int, hbm_pages: int) -> np.ndarray:
    """Interleaved initial symbolic residency (paper SII-B placement)."""
    from repro.core.sim import interleaved_indices
    resident = np.zeros(n, bool)
    resident[interleaved_indices(n, hbm_pages)] = True
    return resident


def resident_mask(mgr: TieringManager, pools: Optional[PagedPools]):
    if pools is None:
        return np.zeros(mgr.n, bool)
    return pools.slot_of >= 0


def replay(page_mass_seq: np.ndarray, cfg: TierConfig,
           pools: Optional[PagedPools] = None) -> TieringManager:
    """Run the tiering loop over a [steps, n_logical] attention-mass
    sequence.  When `pools` is None, residency is tracked symbolically
    (no physical copies) -- used for fast period trials; the physical
    gather/scatter path is exercised by tests/serve."""
    steps, n = page_mass_seq.shape
    mgr = TieringManager(n, cfg)
    symbolic = pools is None
    if symbolic:
        resident = interleaved_resident(n, cfg.hbm_pages)
    for t in range(steps):
        if symbolic:
            mgr.on_step(page_mass_seq[t], resident)
            mgr.maybe_tier_symbolic(resident)
        else:
            mgr.on_step(page_mass_seq[t], resident_mask(mgr, pools))
            pools = mgr.maybe_tier(pools)
    return mgr


def online_replay(page_mass_seq: np.ndarray, cfg: TierConfig,
                  tuner: Optional[cori.OnlineTuner] = None,
                  ) -> "tuple[TieringManager, cori.OnlineTuner]":
    """Closed-loop replay: an ``OnlineTuner`` drives the tiering period live.

    Each decode step feeds the tuner the page masses and the step's measured
    cost (modeled-time delta, including any migration burst the tier just
    paid); the period it returns is applied to the manager *before* the next
    step.  This is the in-system analogue of ``cori_tune_period`` -- no
    oracle re-simulation, the trials are lived through by the running
    manager.  Returns (manager, tuner)."""
    steps, n = page_mass_seq.shape
    mgr = TieringManager(n, cfg)
    if tuner is None:
        tuner = cori.OnlineTuner(n, default_period=cfg.period_steps,
                                 access_threshold=cfg.access_threshold)
    resident = interleaved_resident(n, cfg.hbm_pages)
    for t in range(steps):
        before = mgr.modeled_time
        mgr.on_step(page_mass_seq[t], resident)
        mgr.maybe_tier_symbolic(resident)
        period = tuner.on_step(page_mass_seq[t],
                               cost=mgr.modeled_time - before)
        mgr.set_period(period)
    return mgr, tuner


def cori_tune_period(page_mass_seq: np.ndarray, cfg: TierConfig,
                     patience: int = 2,
                     max_trials: Optional[int] = None):
    """Full Cori loop over the tiering runtime.

    1. Reuse Collector: one profiling window (tiering at the default
       period) collects the access log.
    2. Frequency Generator: DR + candidate ladder in the step domain.
    3. Tuner: trial windows at each candidate period, stop on
       no-improvement.

    Returns (TuneResult, dominant_reuse)."""
    profile = replay(page_mass_seq, cfg)
    cands = profile.cori_candidates(horizon_steps=page_mass_seq.shape[0])

    def evaluate(period: float) -> float:
        p = max(1, int(round(period)))
        mgr = replay(page_mass_seq,
                     dataclasses.replace(cfg, period_steps=p))
        return mgr.modeled_time

    tuner = cori.Tuner(evaluate, patience=patience, max_trials=max_trials)
    hist = profile.reuse_histogram()
    return tuner.run(cands), cori.dominant_reuse(hist)


class AdaptiveTuner:
    """Offline-resimulation re-tuning (the earlier SIV-D sketch): buffer a
    window of masses, watch the hit rate, and re-run the *offline* Cori
    loop (``cori_tune_period``, i.e. oracle replays of the buffered window)
    when it drifts.

    Superseded for in-loop use by ``repro.core.cori.OnlineTuner`` +
    ``online_replay`` (see docs/online_tuning.md), which live-trials
    candidates against the running manager instead of re-simulating, and is
    where drift/measurement improvements land.  Kept as the cheap
    buffered-window variant for replayed mass sequences."""

    def __init__(self, cfg: TierConfig, window: int = 64,
                 retune_ratio: float = 0.7):
        self.cfg = cfg
        self.window = window
        self.retune_ratio = retune_ratio
        self.period = cfg.period_steps
        self.baseline_hit = None
        self.retunes = 0
        self._buf = []

    def _hitrate(self, masses: "np.ndarray") -> float:
        import dataclasses as _dc
        mgr = replay(masses, _dc.replace(self.cfg, period_steps=self.period))
        return mgr.hits / max(mgr.hits + mgr.misses, 1)

    def observe(self, page_mass) -> int:
        """Feed one decode step's page masses; returns the current period."""
        import dataclasses as _dc
        self._buf.append(page_mass)
        if len(self._buf) >= self.window:
            import numpy as _np
            masses = _np.stack(self._buf)
            self._buf = []
            hit = self._hitrate(masses)
            if self.baseline_hit is None:
                self.baseline_hit = hit
            elif hit < self.retune_ratio * self.baseline_hit:
                res, _dr = cori_tune_period(
                    masses, _dc.replace(self.cfg, period_steps=self.period))
                self.period = max(1, int(round(res.chosen_period)))
                self.baseline_hit = self._hitrate(masses)
                self.retunes += 1
        return self.period
