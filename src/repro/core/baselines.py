"""Frequency-tuning baselines (paper Table I and §V-B Eq. 3).

Table I maps prior systems' wall-clock periods onto the simulator's
request-domain analogy (the paper's own mapping: 10 sec == 100 000 requests
... 0.01 sec == 100 requests).

The insight-less step-search baselines (Eq. 3) explore
``[timestep, 2*timestep, ..., Runtime/2]`` in three priority orders:

  base-right   high frequency -> low  (short periods first, like Cori)
  base-left    low frequency -> high  (long periods first)
  base-random  random order (reported as an average over seeds)
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

__all__ = [
    "TABLE_I_PERIODS",
    "base_candidates",
    "ordered_candidates",
    "BASELINE_ORDERS",
]

# requests per period (paper Table I, right column)
TABLE_I_PERIODS: Dict[str, int] = {
    "thermostat": 100_000,  # 10 s
    "nimble": 50_000,       # 5 s
    "ingens": 20_000,       # 2 s
    "hma": 10_000,          # 1 s
    "hetero-os": 1_000,     # 0.1 s
    "kleio": 100,           # 0.01 s
}

BASELINE_ORDERS = ("base-right", "base-left", "base-random")


def base_candidates(num_requests: int, timestep: int) -> np.ndarray:
    """Eq. 3: periods at every multiple of `timestep` up to Runtime/2."""
    hi = num_requests // 2
    if timestep >= hi:
        return np.array([hi], dtype=np.int64)
    return np.arange(timestep, hi + 1, timestep, dtype=np.int64)


def ordered_candidates(num_requests: int, timestep: int, order: str,
                       seed: int = 0) -> np.ndarray:
    cands = base_candidates(num_requests, timestep)
    if order == "base-right":
        return cands                      # short periods (high freq) first
    if order == "base-left":
        return cands[::-1].copy()         # long periods (low freq) first
    if order == "base-random":
        rng = np.random.default_rng(seed)
        return rng.permutation(cands)
    raise ValueError(f"order must be one of {BASELINE_ORDERS}")


def table_i_periods_for(num_requests: int) -> Dict[str, int]:
    """Table I periods clipped to this trace's feasible range [1, N/2]."""
    hi = max(1, num_requests // 2)
    return {k: min(v, hi) for k, v in TABLE_I_PERIODS.items()}
