"""Supervisor: restart-from-checkpoint orchestration for node failures.

Runs the training driver as a child process; on non-zero exit (crash,
injected fault, OOM-kill) or a stale heartbeat (hang), it relaunches.  The
driver restores from the newest checkpoint at startup, so each restart
loses at most ``ckpt_every`` steps of work.  At real multi-pod scale this
process runs per-slice under the cluster scheduler; the logic is the same.
"""
from __future__ import annotations

import dataclasses
import pathlib
import subprocess
import sys
import time
from typing import List, Optional

from repro.ft.monitor import Heartbeat

__all__ = ["SupervisorConfig", "supervise"]


@dataclasses.dataclass
class SupervisorConfig:
    max_restarts: int = 3
    hang_timeout_s: float = 0.0      # 0 = no hang detection
    poll_s: float = 0.5


@dataclasses.dataclass
class RunReport:
    restarts: int
    exit_code: int
    history: List[int]               # child exit codes in order


def supervise(cmd: List[str], workdir, cfg: SupervisorConfig = SupervisorConfig(),
              env=None) -> RunReport:
    """Run `cmd` under restart supervision.  Returns the final report."""
    workdir = pathlib.Path(workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    hb_path = workdir / "heartbeat"
    history: List[int] = []
    restarts = 0
    while True:
        proc = subprocess.Popen(cmd, env=env)
        code: Optional[int] = None
        while code is None:
            try:
                code = proc.wait(timeout=cfg.poll_s)
            except subprocess.TimeoutExpired:
                if (cfg.hang_timeout_s > 0
                        and Heartbeat.age(hb_path) > cfg.hang_timeout_s):
                    proc.kill()
                    code = -9
        history.append(code)
        if code == 0:
            return RunReport(restarts, 0, history)
        restarts += 1
        if restarts > cfg.max_restarts:
            return RunReport(restarts - 1, code, history)
        print(f"[supervisor] child exited {code}; restart "
              f"{restarts}/{cfg.max_restarts}", file=sys.stderr)
        time.sleep(cfg.poll_s)
