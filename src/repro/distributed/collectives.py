"""Distributed-optimization collectives.

``compressed_psum``: int8-quantised all-reduce for the cross-pod (DCN)
gradient reduction.  The wire format is int8 (all_gather of int8 shards +
local fp32 accumulate), cutting DCN bytes 4x vs fp32 / 2x vs bf16; the
quantisation scale is agreed with one scalar pmax.  ``*_ef`` keeps an
error-feedback residual so the quantisation error is re-injected next step
(1-bit-Adam-style convergence behaviour).

These run inside ``shard_map`` bodies (manual axes).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed import compat as _compat  # jax.shard_map on 0.4.x

_compat.install()

__all__ = ["compressed_psum", "compressed_psum_ef"]

# 0.4.x's SPMD partitioner dies on all_gather inside a *partial-manual*
# shard_map body (Check failed: IsManualSubgroup mismatch in
# HandleAllGather) -- the exact shape the pod-compressed train step uses
# (pod manual, data/model auto).  On those versions reduce the int32-
# widened shards with psum instead: the integer accumulation is
# bit-identical (the scale is globally agreed beforehand), only the wire
# format widens from int8 to int32 until the jax pin moves.
_ALL_GATHER_OK = jax.__version_info__ >= (0, 5)


def _int_sum(q, axis_name: str):
    """Sum the int8 shards over ``axis_name`` in int32, exactly."""
    if _ALL_GATHER_OK:
        allq = jax.lax.all_gather(q, axis_name)      # int8 on the wire
        return jnp.sum(allq.astype(jnp.int32), axis=0)
    return jax.lax.psum(q.astype(jnp.int32), axis_name)


def _quantize_global(x, axis_name: str):
    """int8-quantise with a scale agreed across `axis_name`."""
    amax = jnp.max(jnp.abs(x))
    gmax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(gmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean over `axis_name` with int8 wire format.

    all_gather moves int8 (the compressed payload); the accumulation runs
    locally in int32 -> fp32.  Returns the *mean* (DP semantics)."""
    n = jax.lax.axis_size(axis_name)
    if n == 1:
        return x
    q, scale = _quantize_global(x.astype(jnp.float32), axis_name)
    total = _int_sum(q, axis_name).astype(jnp.float32)
    return (total * scale / n).astype(x.dtype)


def compressed_psum_ef(x: jnp.ndarray, ef: jnp.ndarray, axis_name: str
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback variant: compresses (x + ef), returns (mean, new_ef)
    where new_ef is this step's local quantisation residual."""
    n = jax.lax.axis_size(axis_name)
    if n == 1:
        return x, ef
    xf = x.astype(jnp.float32) + ef.astype(jnp.float32)
    q, scale = _quantize_global(xf, axis_name)
    sent = q.astype(jnp.float32) * scale
    new_ef = (xf - sent).astype(ef.dtype)
    total = _int_sum(q, axis_name).astype(jnp.float32)
    return (total * scale / n).astype(x.dtype), new_ef
