"""Quickstart: the paper's pipeline end-to-end in one page.

Generates the backprop trace, collects the reuse histogram, computes the
dominant reuse (Eq. 1), builds the candidate ladder (Eq. 2), tunes the
page-scheduling period against the hybrid-memory simulator, and compares
against the fixed frequencies of prior systems (Table I).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (bin_trace, candidate_periods, dominant_reuse,
                        generate, optimal_runtime, prune_insignificant,
                        reuse_distance_histogram, run_cori, table_i_runtimes)


def main():
    # 1. Reuse Collector: one profiling run
    trace = generate("backprop")
    bins = bin_trace(trace)
    hist = prune_insignificant(
        reuse_distance_histogram(trace.pages, bin_width=1000))
    print(f"trace: {trace.name}, {trace.num_accesses:,} accesses over "
          f"{trace.num_pages:,} pages")
    print("reuse histogram:",
          {int(v): int(c) for v, c in zip(hist.values, hist.counts)})

    # 2. Frequency Generator: Eq. 1 + Eq. 2
    dr = dominant_reuse(hist)
    ladder = candidate_periods(dr, trace.num_accesses)
    print(f"dominant reuse DR = {dr:,.0f} requests")
    print(f"candidate periods: {[int(p) for p in ladder[:6]]} ...")

    # 3. Tuner: trial candidates against the system (simulator here)
    for sched in ("reactive", "predictive"):
        crun = run_cori(bins, trace, sched)
        opt = optimal_runtime(bins, sched)
        slack = crun.result.best_runtime_tried / opt["runtime"] - 1
        print(f"\n[{sched}] Cori chose period {crun.chosen_period:,.0f} in "
              f"{crun.trials} trials -> {slack:.1%} from optimal "
              f"(optimal period {opt['period']:,.0f})")
        t1 = table_i_runtimes(bins, sched)
        for name, r in sorted(t1.items(), key=lambda kv: kv[1].runtime):
            gap = r.runtime / opt["runtime"] - 1
            print(f"    {name:10s} period={r.period_requests:7d}  "
                  f"gap={gap:7.1%}")


if __name__ == "__main__":
    main()
