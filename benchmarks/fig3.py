"""Fig. 3: page-reuse-distance histograms + runtime slowdown across all
period durations, with Cori's candidate periods marked (paper SIII-C).

Numbers sufficient to re-render the figure: per app the histogram
(values, counts), the period->slowdown curve for both schedulers, and the
Cori candidate ladder."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_json
from repro.core import (bin_trace, candidate_periods, dominant_reuse,
                        exhaustive_periods, generate, prune_insignificant,
                        reuse_distance_histogram, sweep)

FIG3_APPS = ["backprop", "lud", "cpd", "pennant", "kmeans"]


def run(apps=FIG3_APPS, quick: bool = False):
    apps = apps[:2] if quick else apps
    out = {}
    for app in apps:
        tr = generate(app)
        bins = bin_trace(tr)
        hist = prune_insignificant(
            reuse_distance_histogram(tr.pages, bin_width=1000))
        dr = dominant_reuse(hist)
        cands = candidate_periods(dr, float(bins.num_accesses),
                                  min_period=float(bins.block))
        periods = exhaustive_periods(bins, 64)
        curves = {}
        for sched in ("reactive", "predictive"):
            res = sweep(bins, periods, sched)
            inf = bins.num_accesses * 1.0
            curves[sched] = {
                "periods": [int(p) for p in res],
                "slowdown_vs_infinite_dram":
                    [res[p].runtime / inf for p in res],
            }
            best = min(res.values(), key=lambda r: r.runtime)
            curves[sched]["best_period"] = best.period_requests
        out[app] = {
            "histogram": {"values": hist.values.tolist(),
                          "counts": hist.counts.tolist()},
            "dominant_reuse": dr,
            "cori_candidates": cands.tolist()[:16],
            "curves": curves,
        }
    save_json("fig3", out)
    return out


if __name__ == "__main__":
    o = run()
    for app, d in o.items():
        print(f"{app:11s} DR={d['dominant_reuse']:9.0f} "
              f"best_r={d['curves']['reactive']['best_period']:8d} "
              f"best_p={d['curves']['predictive']['best_period']:8d}")
