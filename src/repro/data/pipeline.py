"""Deterministic synthetic data pipeline.

Design points that matter at scale:
  * **Elastic determinism** -- batch ``i`` of a run is a pure function of
    (seed, i, global_batch), never of host count or restart point, so
    elastic rescaling and checkpoint-restart see the identical stream.
  * **Shard-local generation** -- each data shard materialises only its
    slice (no host ever holds the global batch).
  * **Background prefetch** -- a depth-``prefetch`` thread queue overlaps
    host generation with device steps.

The stream is a mixture of repeated n-gram motifs (so small models can
overfit in a few hundred steps -- used by the quickstart example) plus
uniform noise.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig

IGNORE = -1


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    motif_vocab_frac: float = 0.5   # motifs drawn from low token ids
    motif_len: int = 8
    noise_frac: float = 0.1
    prefetch: int = 2


def _gen_batch(cfg: DataConfig, model_cfg: ModelConfig, index: int,
               shard: int = 0, num_shards: int = 1) -> Dict[str, Any]:
    """Generate (this shard's slice of) batch `index` deterministically."""
    assert cfg.global_batch % num_shards == 0
    b_local = cfg.global_batch // num_shards
    v = model_cfg.vocab_size
    mv = max(4, int(v * cfg.motif_vocab_frac))
    out_tok = np.empty((b_local, cfg.seq_len), np.int32)
    for r in range(b_local):
        g = cfg.global_batch * index + shard * b_local + r
        rng = np.random.default_rng((cfg.seed, g))
        motif = rng.integers(0, mv, cfg.motif_len)
        reps = -(-cfg.seq_len // cfg.motif_len)
        row = np.tile(motif, reps)[: cfg.seq_len]
        noise = rng.random(cfg.seq_len) < cfg.noise_frac
        row[noise] = rng.integers(0, v, noise.sum())
        out_tok[r] = row
    targets = np.concatenate(
        [out_tok[:, 1:], np.full((b_local, 1), IGNORE, np.int32)], axis=1)
    batch = {"tokens": out_tok, "targets": targets}
    p = model_cfg.prefix_len or 0
    if p:
        rng = np.random.default_rng((cfg.seed, -1 - index))
        batch["extra_embeds"] = rng.standard_normal(
            (b_local, p, model_cfg.d_model)).astype(np.float32) * 0.02
        batch["targets"] = np.concatenate(
            [np.full((b_local, p), IGNORE, np.int32), targets], axis=1)
    if model_cfg.cond_len:
        rng = np.random.default_rng((cfg.seed, -10_000 - index))
        batch["cond"] = rng.standard_normal(
            (b_local, model_cfg.cond_len,
             model_cfg.cond_dim or model_cfg.d_model)).astype(np.float32) * 0.02
    return batch


class DataPipeline:
    """Iterator with background prefetch; resumable via ``start_index``."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig,
                 shard: int = 0, num_shards: int = 1, start_index: int = 0):
        self.cfg, self.model_cfg = cfg, model_cfg
        self.shard, self.num_shards = shard, num_shards
        self.index = start_index
        self._q: queue.Queue = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        i = self.index
        while not self._stop.is_set():
            b = _gen_batch(self.cfg, self.model_cfg, i, self.shard,
                           self.num_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((i, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            i += 1

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        i, b = self._q.get()
        self.index = i + 1
        return b

    def close(self):
        self._stop.set()


def batch_at(cfg: DataConfig, model_cfg: ModelConfig, index: int,
             shard: int = 0, num_shards: int = 1) -> Dict[str, Any]:
    """Pure accessor (no thread) -- used by tests and restarts."""
    return _gen_batch(cfg, model_cfg, index, shard, num_shards)
