"""Mixture-of-Experts layers.

Two implementations sharing identical routing math (softmax over top-k
logits, renormalised):

  * ``dense``     -- computes every expert for every token; the numerics
                     oracle used by smoke/property tests (tiny configs only).
  * ``shard_map`` -- production expert-parallel path: activations are
                     replicated across the ``model`` mesh axis (TP), experts
                     are sharded over it; each shard sort-dispatches tokens
                     to its local experts under a capacity bound and the
                     partial outputs are ``psum``-combined.  Communication
                     profile == one TP all-reduce per MoE layer, no
                     all-to-all -- the right trade on ICI-rich TPU meshes.

Both are fully differentiable (capacity drops use stop-gradient-free
masking; indices are non-differentiable by construction).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compat as _compat  # jax.shard_map on 0.4.x
from repro.models.config import ModelConfig
from repro.models.layers import _dense_init, split_tree

_compat.install()

Params = Dict[str, Any]


def moe_init(key, cfg: ModelConfig):
    mo = cfg.moe
    d, e, f = cfg.d_model, mo.num_experts, mo.d_expert
    ks = jax.random.split(key, 5)
    tree = {
        "router": _dense_init(ks[0], (d, e), ("embed", None)),
        "wi_gate": _dense_init(ks[1], (e, d, f), ("expert", "embed", "mlp")),
        "wi_up": _dense_init(ks[2], (e, d, f), ("expert", "embed", "mlp")),
        "wo": _dense_init(ks[3], (e, f, d), ("expert", "mlp", "embed")),
    }
    if mo.num_shared:
        fs = (mo.d_shared or mo.d_expert) * mo.num_shared
        k5, k6, k7 = jax.random.split(ks[4], 3)
        tree["shared"] = {
            "wi_gate": _dense_init(k5, (d, fs), ("embed", "mlp")),
            "wi_up": _dense_init(k6, (d, fs), ("embed", "mlp")),
            "wo": _dense_init(k7, (fs, d), ("mlp", "embed")),
        }
    return split_tree(tree)


def _route(x, router_w, top_k: int):
    """Common routing: returns (weights [T,k], idx [T,k], probs [T,E])."""
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p.astype(x.dtype), top_i, probs


def _aux_loss(probs, top_i, num_experts: int):
    """Switch-style load-balance loss."""
    me = jnp.mean(probs, axis=0)                        # mean router prob
    ce = jnp.mean(jax.nn.one_hot(top_i[:, 0], num_experts), axis=0)
    return num_experts * jnp.sum(me * ce)


def _shared_out(p, x):
    h = jax.nn.silu(x @ p["wi_gate"].astype(x.dtype)) * (
        x @ p["wi_up"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------


def moe_apply_dense(p: Params, cfg: ModelConfig, x) -> Tuple[Any, Any]:
    """x: [B,S,d] -> (y, aux_loss).  Computes all experts (oracle)."""
    mo = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    w, idx, probs = _route(xt, p["router"], mo.top_k)
    h = jnp.einsum("td,edf->tef", xt, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xt, p["wi_up"].astype(x.dtype))
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u,
                       p["wo"].astype(x.dtype))        # [T,E,d]
    sel = jnp.take_along_axis(y_all, idx[:, :, None], axis=1)  # [T,k,d]
    y = jnp.sum(sel * w[:, :, None], axis=1)
    if mo.num_shared:
        y = y + _shared_out(p["shared"], xt)
    return y.reshape(b, s, d), _aux_loss(probs, idx, mo.num_experts)


# ---------------------------------------------------------------------------
# shard_map expert-parallel path
# ---------------------------------------------------------------------------


def _local_dispatch(xt, w, idx, e0, e_local: int, capacity: int):
    """Build the [E_local, C, d] buffer for this shard's experts.

    xt: [T,d]; w/idx: [T,k].  Token-expert pairs whose expert lives on this
    shard are ranked FCFS; pairs beyond `capacity` are dropped (standard
    capacity-factor semantics)."""
    t, k = idx.shape
    pairs_e = idx.reshape(-1)                      # [T*k] global expert id
    pairs_w = w.reshape(-1)
    pairs_t = jnp.repeat(jnp.arange(t), k)
    local = (pairs_e >= e0) & (pairs_e < e0 + e_local)
    le = jnp.where(local, pairs_e - e0, e_local)   # e_local == trash bin
    onehot = jax.nn.one_hot(le, e_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1           # position within expert
    pos = jnp.take_along_axis(pos, le[:, None], axis=1)[:, 0]
    keep = local & (pos < capacity)
    le_c = jnp.where(keep, le, e_local)            # clamp for scatter
    pos_c = jnp.where(keep, pos, 0)
    buf = jnp.zeros((e_local + 1, capacity, xt.shape[1]), xt.dtype)
    buf = buf.at[le_c, pos_c].add(jnp.where(keep[:, None], xt[pairs_t], 0))
    return buf[:e_local], (pairs_t, le_c, pos_c, pairs_w, keep)


def _local_combine(y_buf, meta, t: int, d: int):
    pairs_t, le_c, pos_c, pairs_w, keep = meta
    gathered = y_buf[jnp.minimum(le_c, y_buf.shape[0] - 1), pos_c]
    contrib = jnp.where(keep[:, None], gathered * pairs_w[:, None], 0)
    return jnp.zeros((t, d), y_buf.dtype).at[pairs_t].add(contrib)


def moe_apply_shard_map(p: Params, cfg: ModelConfig, x, mesh,
                        model_axis: str = "model") -> Tuple[Any, Any]:
    """Expert-parallel MoE.  x: [B,S,d] sharded on batch only (replicated
    over `model_axis`); experts sharded over `model_axis`."""
    mo = cfg.moe
    b, s, d = x.shape
    n_model = mesh.shape[model_axis]
    assert mo.num_experts % n_model == 0, (mo.num_experts, n_model)
    e_local = mo.num_experts // n_model

    batch_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    P = jax.sharding.PartitionSpec

    def shard_fn(xt, router_w, wi_gate, wi_up, wo):
        # xt: [T_local, d] (batch-sharded, model-replicated)
        t = xt.shape[0]
        wgt, idx, probs = _route(xt, router_w, mo.top_k)
        e0 = jax.lax.axis_index(model_axis) * e_local
        capacity = max(1, int(np.ceil(t * mo.top_k / mo.num_experts
                                      * mo.capacity_factor)))
        buf, meta = _local_dispatch(xt, wgt, idx, e0, e_local, capacity)
        h = jnp.einsum("ecd,edf->ecf", buf, wi_gate.astype(xt.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wi_up.astype(xt.dtype))
        y_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                           wo.astype(xt.dtype))
        y = _local_combine(y_buf, meta, t, d)
        y = jax.lax.psum(y, model_axis)
        # global load-balance loss: pmean the *means*, then the product
        me = jax.lax.pmean(jnp.mean(probs, axis=0), batch_axes)
        ce = jax.lax.pmean(
            jnp.mean(jax.nn.one_hot(idx[:, 0], mo.num_experts), axis=0),
            batch_axes)
        aux = mo.num_experts * jnp.sum(me * ce)
        return y, aux

    # Shared experts are computed OUTSIDE the shard_map as a plain TP MLP
    # (their mlp dim is sharded over `model_axis` by the param specs);
    # computing them replicated inside and psum'ing would overcount.
    shared_y = None
    if mo.num_shared:
        shared_y = _shared_out(p["shared"], x.reshape(b * s, d))

    fn = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(batch_axes, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(batch_axes, None), P()),
    )
    y, aux = fn(x.reshape(b * s, d), p["router"], p["wi_gate"], p["wi_up"],
                p["wo"])
    if shared_y is not None:
        y = y + shared_y
    return y.reshape(b, s, d), aux


def moe_apply(p: Params, cfg: ModelConfig, x, mesh=None):
    if cfg.moe_impl == "shard_map" and mesh is not None:
        return moe_apply_shard_map(p, cfg, x, mesh)
    return moe_apply_dense(p, cfg, x)
