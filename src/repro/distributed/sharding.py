"""Logical-axis sharding rules and their resolution to mesh axes.

Parallelism encoded here:
  * DP    -- activation "batch" over ("pod", "data")
  * FSDP  -- param "embed" dim over "data" (ZeRO-3-style weight sharding;
             params stay *within-pod* sharded and pod-replicated, so the
             per-layer all-gathers ride ICI while only the once-per-step
             gradient all-reduce crosses the DCN pod axis)
  * TP    -- param "mlp"/"heads"/"vocab" (and fallbacks) over "model"
  * EP    -- param "expert" over "model" (expert-parallel MoE)
  * SP/CP -- decode KV cache "kv_seq" over "model" (context parallelism)

Resolution is divisibility-aware with per-dim fallback: each logical name
maps to a list of candidate mesh axes; a dim takes the first candidate
whose size divides it and which is not already used by another dim of the
same tensor.  E.g. Qwen3's 40 heads don't divide a 16-way model axis, so
the attention projections shard their 128-wide head_dim instead.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# candidate mesh axes per logical axis name, in priority order
PARAM_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "lru": ("model",),
    "q_lora": (),
    "kv_lora": (),
    "layers": (),
    "cond": (),
    "qblocks": ("data",),
}

ACT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    # Sequence parallelism: the residual stream (and thus every remat-saved
    # layer input) shards its seq dim over "model"; XLA inserts the
    # all-gather before attention / reduce-scatter after -- SP semantics.
    # Cut nemotron train_4k temp from 69 GB to HBM scale (EXPERIMENTS SPerf).
    "seq": ("model",),
    "embed": (),
    "vocab": ("model",),
    "kv_seq": ("model",),
    "heads": ("model",),
    "layers": (),
}


def _resolve(axes: Optional[Sequence[Optional[str]]], shape: Tuple[int, ...],
             rules: Dict[str, Tuple[str, ...]], mesh: Mesh) -> P:
    """Resolve a logical-axis tuple to a PartitionSpec for `shape`."""
    if axes is None:
        return P()
    assert len(axes) == len(shape), (axes, shape)
    used: set = set()
    out = []
    for name, dim in zip(axes, shape):
        if name is None:
            out.append(None)
            continue
        cands = rules.get(name, ())
        if name == "batch":
            # batch may take several axes jointly (pod x data)
            take = [a for a in cands
                    if a in mesh.axis_names and a not in used]
            sz = int(np.prod([mesh.shape[a] for a in take])) if take else 1
            if take and dim % sz == 0:
                used.update(take)
                out.append(tuple(take) if len(take) > 1 else take[0])
            else:
                # try the largest single axis that divides
                picked = None
                for a in take:
                    if dim % mesh.shape[a] == 0:
                        picked = a
                        break
                if picked:
                    used.add(picked)
                out.append(picked)
            continue
        picked = None
        for a in cands:
            if a in mesh.axis_names and a not in used and dim % mesh.shape[a] == 0:
                picked = a
                break
        if picked:
            used.add(picked)
        out.append(picked)
    return P(*out)


def param_spec(axes, shape, mesh: Mesh) -> P:
    return _resolve(axes, shape, PARAM_RULES, mesh)


def act_spec(axes, shape, mesh: Mesh) -> P:
    return _resolve(axes, shape, ACT_RULES, mesh)


def tree_shardings(spec_tree, shape_tree, mesh: Mesh,
                   rules: Dict[str, Tuple[str, ...]] = PARAM_RULES):
    """NamedSharding tree from a logical-spec tree + ShapeDtypeStruct tree."""
    is_axes = lambda x: x is None or (isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x))

    def one(axes, shaped):
        return NamedSharding(mesh, _resolve(axes, shaped.shape, rules, mesh))

    return jax.tree.map(one, spec_tree, shape_tree, is_leaf=is_axes)


def make_param_shard_fn(mesh: Optional[Mesh]):
    """Constraint fn for (sliced) layer params inside scan bodies: keeps
    the FSDP all-gather per-layer (defeats XLA's slice-of-gather hoist that
    would materialise every layer's gathered weights at once)."""
    if mesh is None:
        return None

    def shard(x, axes):
        spec = _resolve(axes, x.shape, PARAM_RULES, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


def act_rules_for(step_kind: str) -> Dict[str, Tuple[str, ...]]:
    """SP (seq over model) stays on for every sequence-mode step: measured
    on stablelm prefill_32k, SP cuts collectives 142 GB -> 103 GB (AG+RS
    replaces the 2x-volume TP all-reduce -- the Megatron-SP identity) *and*
    temp 8.3 -> 3.6 GB.  The iteration that scoped SP to train only was
    REFUTED by measurement (EXPERIMENTS.md SPerf it.4)."""
    return ACT_RULES


def make_shard_fn(mesh: Optional[Mesh], exclude: Tuple[str, ...] = (),
                  rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Activation-constraint fn: shard(x, logical_names) -> x.
    `exclude` drops mesh axes from the rules (e.g. axes that are Manual
    inside an enclosing shard_map and so must not appear in constraints)."""
    if mesh is None:
        return lambda x, names: x
    rules = dict(rules if rules is not None else ACT_RULES)
    rules = {k: tuple(a for a in v if a not in exclude)
             for k, v in rules.items()}

    def shard(x, names):
        spec = _resolve(names, x.shape, rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


def input_sharding(mesh: Mesh, *axes_names) -> NamedSharding:
    """Sharding for a step input given logical names (divisibility left to
    the caller -- used for token/target arrays)."""
    out = []
    used: set = set()
    for name in axes_names:
        if name is None:
            out.append(None)
            continue
        cands = [a for a in ACT_RULES.get(name, ()) if a in mesh.axis_names
                 and a not in used]
        used.update(cands)
        out.append(tuple(cands) if len(cands) > 1 else
                   (cands[0] if cands else None))
    return NamedSharding(mesh, P(*out))
