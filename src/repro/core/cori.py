"""Cori: Frequency Generator + Tuner (paper §IV-B, §IV-C).

Dominant reuse (Eq. 1), with reuses sorted ascending so that the extra
``(N - i)`` weight favours shorter reuse distances:

            sum_i (N - i) * repeat_i * reuse_i
    DR  =  ------------------------------------        i = 1..N
            sum_i (N - i) * repeat_i

Candidate periods (Eq. 2):  [DR, 2*DR, 3*DR, ..., Runtime/2], emitted
shortest period first (highest frequency first) -- this priority ordering is
essential to Cori's trial efficiency (§IV-B).

The Tuner (§IV-C) trials candidates in order against the actual system (here:
the hybrid-memory simulator, or any callable ``period -> runtime``) and stops
either when a trial budget is hit or when performance stops improving
("performance ... shows no significant variation from the last trial",
§IV-D).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.reuse import ReuseHistogram

__all__ = [
    "dominant_reuse",
    "candidate_periods",
    "TuneResult",
    "Tuner",
    "trials_to_best",
]


def dominant_reuse(hist: ReuseHistogram) -> float:
    """Eq. 1: weighted average of reuses, biased towards short ones."""
    if hist.num_bins == 0:
        raise ValueError("empty reuse histogram: nothing to tune from")
    order = np.argsort(hist.values)
    reuse = hist.values[order].astype(np.float64)
    repeat = hist.counts[order].astype(np.float64)
    n = reuse.shape[0]
    if n == 1:
        return float(reuse[0])
    w = (n - np.arange(1, n + 1, dtype=np.float64)) * repeat  # (N - i) * repeat_i
    denom = w.sum()
    if denom <= 0:  # degenerate: all weight on the longest reuse
        return float(reuse[0])
    return float((w * reuse).sum() / denom)


def candidate_periods(dr: float, runtime: float, max_candidates: int = 64,
                      min_period: float = 1.0) -> np.ndarray:
    """Eq. 2: multiples of DR up to Runtime/2, shortest first.

    `runtime` and the returned periods are in whatever domain DR is measured
    in (requests for the simulator, seconds / decode-steps on a system).
    """
    dr = max(float(dr), float(min_period))
    hi = runtime / 2.0
    if dr > hi:
        return np.array([hi], dtype=np.float64)
    n = int(hi // dr)
    ks = np.arange(1, n + 1, dtype=np.float64)
    if n > max_candidates:
        # Keep the ladder's head exact (the critical low-multiples region),
        # thin the tail geometrically -- same endpoints as Eq. 2.
        head = ks[: max_candidates // 2]
        tail = np.unique(np.geomspace(head[-1] + 1, n,
                                      max_candidates - head.shape[0]).round())
        ks = np.concatenate([head, tail])
    return ks * dr


@dataclasses.dataclass(frozen=True)
class TuneResult:
    chosen_period: float
    chosen_runtime: float
    trials: int                      # trials actually executed
    tried_periods: np.ndarray
    tried_runtimes: np.ndarray
    candidates: np.ndarray           # full candidate ladder

    @property
    def best_runtime_tried(self) -> float:
        return float(np.min(self.tried_runtimes))


class Tuner:
    """Cori's Tuner: trial candidates in order, stop on no-improvement.

    Args:
      evaluate: callable(period) -> runtime (lower is better).  For the
        simulator this wraps `core.sim.simulate`; for the serving runtime it
        wraps a measured window of decode steps.
      patience: stop after this many consecutive non-improving trials
        (the flexible stopping policy of §IV-D).
      rel_tol: a trial must beat the best-so-far by this fraction to count
        as an improvement.
      max_trials: hard trial budget (None = whole ladder).
    """

    def __init__(self, evaluate: Callable[[float], float], patience: int = 2,
                 rel_tol: float = 0.01, max_trials: Optional[int] = None):
        self.evaluate = evaluate
        self.patience = patience
        self.rel_tol = rel_tol
        self.max_trials = max_trials

    def run(self, candidates: Sequence[float]) -> TuneResult:
        candidates = np.asarray(list(candidates), dtype=np.float64)
        best_rt = np.inf
        best_p = float(candidates[0])
        tried_p: List[float] = []
        tried_rt: List[float] = []
        stale = 0
        for p in candidates:
            rt = float(self.evaluate(float(p)))
            tried_p.append(float(p))
            tried_rt.append(rt)
            if rt < best_rt * (1.0 - self.rel_tol):
                best_rt, best_p, stale = rt, float(p), 0
            else:
                stale += 1
                if stale >= self.patience:
                    break
            if self.max_trials is not None and len(tried_p) >= self.max_trials:
                break
        if not np.isfinite(best_rt):
            best_rt, best_p = tried_rt[0], tried_p[0]
        return TuneResult(best_p, best_rt, len(tried_p),
                          np.asarray(tried_p), np.asarray(tried_rt), candidates)


def trials_to_best(runtimes_in_order: Sequence[float], tol: float = 0.005
                   ) -> int:
    """Number of trials until a candidate within `tol` of the sequence's own
    best has been tried (the Fig. 5a metric)."""
    rts = np.asarray(list(runtimes_in_order), dtype=np.float64)
    if rts.size == 0:
        return 0
    target = rts.min() * (1.0 + tol)
    return int(np.argmax(rts <= target)) + 1
