"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import pathlib
import time

OUT = pathlib.Path(__file__).resolve().parent / "out"

APPS = ["backprop", "quicksilver", "lud", "cpd", "pennant", "kmeans",
        "hotspot", "bfs", "bptree"]
SCHEDS = ["reactive", "predictive"]


def out_dir() -> pathlib.Path:
    """Result directory, overridable via ``REPRO_BENCH_OUT``.  CI smoke
    runs point this at a temp dir so throwaway results can never be
    diffed against (or silently shadow) committed artifacts -- results
    are local scratch, not version-controlled (see .gitignore)."""
    return pathlib.Path(os.environ.get("REPRO_BENCH_OUT", OUT))


def save_json(name: str, payload) -> pathlib.Path:
    d = out_dir()
    d.mkdir(parents=True, exist_ok=True)
    p = d / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p


def load_json(name: str):
    p = out_dir() / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0

    @property
    def us(self) -> float:
        return self.seconds * 1e6
