"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs(arch, shape)`` returns the full argument pytree for the cell's
step function -- weak-type-correct, shardable, zero allocation.  Modality
frontends are stubs per the assignment: the VLM cell gets precomputed patch
embeddings, the audio cell gets a conditioning sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import model as mdl
from repro.models.config import ModelConfig
from repro.train import optim, step as tstep

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    step_kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


def cell(arch: str, shape: str) -> Cell:
    cfg = C.get(arch)
    sh = C.SHAPES[shape]
    return Cell(arch, shape, cfg, sh["step"], sh["seq_len"],
                sh["global_batch"])


def batch_specs(c: Cell) -> Dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs."""
    cfg, b = c.cfg, c.global_batch
    s = c.seq_len
    out: Dict[str, Any] = {}
    p = cfg.prefix_len or 0
    text = s - p
    out["tokens"] = SDS((b, text), jnp.int32)
    if c.step_kind == "train":
        out["targets"] = SDS((b, s), jnp.int32)
    if p:
        out["extra_embeds"] = SDS((b, p, cfg.d_model), jnp.bfloat16)
    if cfg.cond_len:
        out["cond"] = SDS((b, cfg.cond_len, cfg.d_model), jnp.bfloat16)
    return out


def decode_specs(c: Cell) -> Dict[str, Any]:
    """Decode-step inputs: one new token against a seq_len KV cache."""
    cfg, b = c.cfg, c.global_batch
    cache = jax.eval_shape(
        lambda: mdl.init_cache(cfg, b, c.seq_len, jnp.bfloat16))
    out = {"cache": cache,
           "tokens": SDS((b, 1), jnp.int32),
           "cur_pos": SDS((b,), jnp.int32)}
    if cfg.cond_len:
        out["cond"] = SDS((b, cfg.cond_len, cfg.d_model), jnp.bfloat16)
    return out


def state_specs_shapes(cfg: ModelConfig, ocfg: optim.OptConfig):
    """(state ShapeDtypeStruct tree, logical spec tree) without allocation."""
    def build():
        return tstep.init_state(jax.random.PRNGKey(0), cfg, ocfg)[0]

    shapes = jax.eval_shape(build)
    pspecs = mdl.init_specs_only(cfg)
    return shapes, tstep.state_specs(pspecs, ocfg)
