"""Serving-domain benchmark: Cori tuning the KV-tiering period (the
technique integrated as a framework feature -- DESIGN.md S3).

Workloads: synthetic decode access patterns + real attention masses from a
reduced-model generation run.  Reports modeled time for Cori's period vs
fixed periods (the serving analogue of Fig. 1)."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import save_json
from repro.memtier import TierConfig, cori_tune_period, replay
from repro.memtier import workload as W

CFG = TierConfig(hbm_pages=16, period_steps=8)
FIXED = (1, 4, 16, 64, 200)


def _real_masses(steps=48):
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import monitored_generate
    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    _, mass = monitored_generate(params, cfg, prompts, steps=steps,
                                 page_size=4)
    return mass


def run(quick: bool = False):
    steps, n = (200, 64) if quick else (400, 64)
    sources = {
        "attention_sink": W.attention_sink(steps, n),
        "periodic_context": W.periodic_context(steps, n),
        "random_lookup": W.random_lookup(steps, n),
    }
    if not quick:
        sources["real_gemma3_attention"] = _real_masses()
    out = {}
    for name, wl in sources.items():
        cfg = CFG
        if name == "real_gemma3_attention":
            cfg = dataclasses.replace(CFG, hbm_pages=max(
                2, wl.shape[1] // 4))
        res, dr = cori_tune_period(wl, cfg)
        fixed = {str(p): replay(
            wl, dataclasses.replace(cfg, period_steps=min(p, wl.shape[0] - 1))
        ).modeled_time for p in FIXED}
        best_fixed = min(fixed.values())
        out[name] = {
            "dominant_reuse_steps": dr,
            "cori_period_steps": res.chosen_period,
            "cori_trials": res.trials,
            "cori_time": res.chosen_runtime,
            "fixed_times": fixed,
            "cori_vs_best_fixed": res.chosen_runtime / best_fixed,
            "cori_vs_worst_fixed": res.chosen_runtime / max(fixed.values()),
        }
    save_json("tiering", out)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k:24s} DR={v['dominant_reuse_steps']:6.1f} "
              f"period={v['cori_period_steps']:6.1f} "
              f"x_best={v['cori_vs_best_fixed']:.2f} "
              f"x_worst={v['cori_vs_worst_fixed']:.2f}")
