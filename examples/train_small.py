"""End-to-end training driver on a small dense model.

Uses the full production stack -- deterministic data pipeline, AdamW,
checkpoint/restart, straggler monitor -- via ``repro.launch.train``.  The
model is a reduced qwen3-family config; on a real TPU slice the same
driver trains the full configs (see repro/launch/dryrun.py for the
production mesh lowering).  A few hundred steps overfit the motif stream,
demonstrating real learning:

    PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse

from repro.launch import train as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args(argv)
    report = T.main([
        "--arch", "qwen3-14b", "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64",
        "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
        "--log-every", "25",
    ])
    drop = report["first_loss"] - report["final_loss"]
    print(f"\nloss {report['first_loss']:.3f} -> {report['final_loss']:.3f} "
          f"({drop:+.3f}); checkpoints in {args.ckpt_dir}")
    assert drop > 0.5, "model failed to learn the motif stream"


if __name__ == "__main__":
    main()
