"""Serving layer: generation engine + continuous-batching scheduler.

``engine`` holds the single-stream paths (``generate`` /
``monitored_generate``); ``sched`` is the traffic layer -- a
``ContinuousBatcher`` admitting and retiring requests mid-flight over one
shared HBM page pool, feeding the online Cori tuner from the aggregate
mix (see docs/serving.md).
"""
from repro.serve.engine import (generate, make_monitor, monitor_slot,
                                monitored_generate, page_mass_from_attention)
from repro.serve.sched import (ContinuousBatcher, Request, TrafficMonitor,
                               TrafficScheduler, WORKLOAD_KINDS)

__all__ = [
    "ContinuousBatcher", "Request", "TrafficMonitor", "TrafficScheduler",
    "WORKLOAD_KINDS", "generate", "make_monitor", "monitor_slot",
    "monitored_generate", "page_mass_from_attention",
]
