"""Hybrid-memory simulator: JAX scan vs pure-python oracle + invariants.

Property-style coverage runs as deterministic ``pytest.mark.parametrize``
cases over seeded random traces (no optional ``hypothesis`` dependency)."""
import numpy as np
import pytest

from repro.core import (SimConfig, Trace, bin_trace, generate, simulate,
                        simulate_reference, sweep, sweep_loop)


def _small_trace(seed=0):
    return generate("backprop", seed=seed, num_pages=256, sweeps=6,
                    accesses_per_page=3)


@pytest.mark.parametrize("scheduler", ["reactive", "predictive"])
@pytest.mark.parametrize("period", [100, 700, 2300])
def test_scan_matches_reference(scheduler, period):
    bins = bin_trace(_small_trace())
    a = simulate(bins, period, scheduler)
    b = simulate_reference(bins, period, scheduler)
    assert a.migrations == b.migrations
    assert a.fast_hits == b.fast_hits
    np.testing.assert_allclose(a.runtime, b.runtime, rtol=1e-5)


def test_runtime_lower_bound():
    """Runtime can never beat every access hitting fast memory."""
    bins = bin_trace(_small_trace())
    for p in [100, 1000, 3000]:
        r = simulate(bins, p, "predictive")
        assert r.runtime >= r.num_accesses * SimConfig().lat_fast


def test_predictive_beats_reactive_on_strides():
    """Oracle knowledge of the next period can only help on a strided
    pattern (paper SIII-C: reactive breaks the reuse)."""
    bins = bin_trace(_small_trace())
    p = 1000
    pred = simulate(bins, p, "predictive")
    reac = simulate(bins, p, "reactive")
    assert pred.runtime <= reac.runtime


def test_short_period_overhead_dominates():
    """Very short periods reveal monitoring+movement overheads (SIII-C)."""
    bins = bin_trace(_small_trace())
    shortest = simulate(bins, 100, "reactive")
    mid = simulate(bins, 2000, "reactive")
    assert shortest.runtime > mid.runtime


def test_fast_hits_bounded_by_capacity_share():
    """With uniform sweeps, hitrate can't exceed 1.0; data moved is capped
    by capacity per period."""
    cfg = SimConfig()
    bins = bin_trace(_small_trace())
    r = simulate(bins, 500, "reactive", cfg)
    assert 0.0 <= r.fast_hitrate <= 1.0
    capacity = cfg.fast_capacity(bins.num_pages)
    num_periods = -(-bins.num_accesses // 500)
    assert r.migrations <= capacity * num_periods


@pytest.mark.parametrize("seed", range(20))
def test_property_random_traces(seed):
    """Invariants over random traces: scan==oracle, bounded hitrate,
    nonnegative overhead decomposition."""
    rng = np.random.default_rng(seed)
    n_pages = int(rng.integers(8, 65))
    n = int(rng.integers(200, 2001))
    pages = rng.integers(0, n_pages, size=n).astype(np.int32)
    tr = Trace("rand", pages, n_pages, np.array([n]))
    bins = bin_trace(tr, block=50)
    period = int(rng.choice([50, 100, 250]))
    sched = ["reactive", "predictive"][seed % 2]
    a = simulate(bins, period, sched)
    b = simulate_reference(bins, period, sched)
    np.testing.assert_allclose(a.runtime, b.runtime, rtol=1e-4)
    assert a.migrations == b.migrations
    assert 0.0 <= a.fast_hitrate <= 1.0
    assert a.runtime >= n * 1.0


@pytest.mark.parametrize("scheduler", ["reactive", "predictive"])
def test_batched_sweep_matches_loop(scheduler):
    """The one-shot vmap-batched sweep must reproduce the per-candidate
    simulate loop exactly (acceptance: within 1e-6 on the seed traces)."""
    bins = bin_trace(_small_trace())
    periods = [100, 300, 700, 1000, 2300]
    a = sweep_loop(bins, periods, scheduler)
    b = sweep(bins, periods, scheduler)
    assert set(a) == set(b)
    for p in a:
        np.testing.assert_allclose(a[p].runtime, b[p].runtime, rtol=1e-6)
        assert a[p].migrations == b[p].migrations
        assert a[p].fast_hits == b[p].fast_hits


def test_batched_sweep_empty_and_duplicates():
    bins = bin_trace(_small_trace())
    assert sweep(bins, []) == {}
    # periods snapping to the same block count collapse to one result
    out = sweep(bins, [100, 120, 149])
    assert list(out) == [100]


def test_bin_trace_pallas_matches_numpy():
    """The Pallas page_hist binning path == the bincount path."""
    tr = _small_trace()
    a = bin_trace(tr)
    b = bin_trace(tr, impl="interpret")
    np.testing.assert_array_equal(a.block_hist, b.block_hist)


def test_capacity_respected_in_placement():
    """The simulator never claims more fast hits than a 100% hitrate and the
    reference's fast set is exactly the configured capacity."""
    tr = _small_trace()
    bins = bin_trace(tr)
    cfg = SimConfig(fast_frac=0.5)
    r = simulate(bins, 1000, "predictive", cfg)
    assert r.fast_hits <= r.num_accesses
    assert r.fast_hitrate > 0.3  # 50% capacity must produce real hits


def test_period_snapping():
    bins = bin_trace(_small_trace())
    r = simulate(bins, 149, "reactive")
    assert r.period_requests == 100
    r = simulate(bins, 151, "reactive")
    assert r.period_requests == 200
