"""End-to-end serving driver (the paper's kind: memory-system serving).

Serves a small gemma3-family model with batched requests while the
Cori-tuned tiering runtime manages the KV-page working set:

  1. prefill + batched decode with the attention monitor on,
  2. profile window -> reuse histogram -> DR -> candidate periods,
  3. Cori tunes the tiering period; the tiered pool is then replayed with
     physical page migrations (gather/scatter) and validated against the
     paged_attention kernel.

With ``--online`` the offline profile/replay split disappears: an
``OnlineTuner`` rides the decode loop itself (through
``monitored_generate``'s ``on_mass`` hook), re-deriving dominant reuse from
a sliding window and re-trialing candidate periods against the live
TieringManager, so the migration period adapts while tokens are still being
generated.

    PYTHONPATH=src python examples/serve_tiered.py [--steps 48] [--online]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import OnlineTuner
from repro.memtier import (PagedPools, TierConfig, TieringManager,
                           cori_tune_period, replay)
from repro.models import model as mdl
from repro.serve.engine import monitored_generate


def serve_online(params, cfg, prompts, args):
    """Closed-loop path: tiering + tuning run inside the decode loop."""
    prefix = cfg.prefix_len or 0
    max_len = prompts.shape[1] + prefix + args.steps
    n_pages = -(-max_len // args.page_size)
    tc = TierConfig(page_size=args.page_size,
                    hbm_pages=max(2, n_pages // 4), period_steps=4)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    key = jax.random.PRNGKey(2)
    k_pages = jax.random.normal(key, (n_pages, args.page_size, kv, hd))
    v_pages = jax.random.normal(jax.random.fold_in(key, 1), k_pages.shape)
    pools = PagedPools.create(k_pages, v_pages, tc.hbm_pages)
    mgr = TieringManager(n_pages, tc)
    tuner = OnlineTuner(n_pages, default_period=tc.period_steps,
                        profile_steps=max(8, args.steps // 4),
                        trial_steps=max(4, args.steps // 8),
                        access_threshold=tc.access_threshold)

    def on_mass(i, m):
        nonlocal pools
        before = mgr.modeled_time
        mgr.on_step(m, pools.slot_of >= 0)
        pools = mgr.maybe_tier(pools)
        mgr.set_period(tuner.on_step(m, cost=mgr.modeled_time - before))

    tokens, mass = monitored_generate(params, cfg, prompts, steps=args.steps,
                                      page_size=args.page_size,
                                      on_mass=on_mass)
    print(f"generated {tokens.shape[1]} tokens/request with the online "
          f"tuner in the loop")
    print(f"online Cori: state={tuner.state} period={tuner.period} "
          f"(DR={tuner.dominant_reuse}, {len(tuner.tried)} live trials, "
          f"{tuner.retunes} tune cycles)")
    print(f"period history (step, period): {tuner.history}")
    print(f"tiering: {mgr.migrations} page swaps, "
          f"{mgr.data_moved_pages} pages moved, modeled time "
          f"{mgr.modeled_time:.0f}, "
          f"{int((pools.slot_of >= 0).sum())}/{n_pages} pages resident")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--online", action="store_true",
                    help="closed-loop tuning inside the decode loop")
    args = ap.parse_args(argv)

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, 16), 0, cfg.vocab_size)

    print(f"serving {cfg.name} (reduced): batch={args.batch}, "
          f"decode steps={args.steps}")
    if args.online:
        serve_online(params, cfg, prompts, args)
        return
    tokens, mass = monitored_generate(params, cfg, prompts,
                                      steps=args.steps,
                                      page_size=args.page_size)
    n_pages = mass.shape[1]
    print(f"generated {tokens.shape[1]} tokens/request; monitored "
          f"{mass.shape[0]} steps x {n_pages} KV pages")

    tc = TierConfig(hbm_pages=max(2, n_pages // 4), period_steps=4)
    res, dr = cori_tune_period(mass, tc)
    print(f"\nCori: dominant reuse = {dr:.1f} decode steps; "
          f"chose tiering period {res.chosen_period:.0f} in {res.trials} "
          f"trials")
    for p in (1, 4, 16):
        t = replay(mass, dataclasses.replace(tc, period_steps=p)).modeled_time
        print(f"    fixed period {p:3d}: modeled time {t:10.0f}")
    print(f"    cori period {res.chosen_period:3.0f}: modeled time "
          f"{res.chosen_runtime:10.0f}")

    # physical migration pass over real KV pages of the monitor layer
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    key = jax.random.PRNGKey(2)
    k_pages = jax.random.normal(key, (n_pages, args.page_size, kv, hd))
    v_pages = jax.random.normal(jax.random.fold_in(key, 1), k_pages.shape)
    pools = PagedPools.create(k_pages, v_pages, tc.hbm_pages)
    mgr = TieringManager(n_pages, dataclasses.replace(
        tc, period_steps=max(1, int(res.chosen_period))))
    for t in range(mass.shape[0]):
        mgr.on_step(mass[t], pools.slot_of >= 0)
        pools = mgr.maybe_tier(pools)
    print(f"\nphysical pass: {mgr.migrations} page swaps, "
          f"{mgr.data_moved_pages} pages moved, "
          f"{int((pools.slot_of >= 0).sum())}/{n_pages} pages resident")


if __name__ == "__main__":
    main()
