"""Serving-traffic request streams (the aggregate-workload generator).

The paper tunes the movement period against one application's reuse; a
serving system sees a *mix* of requests arriving over time, each with its
own prompt length, output budget and KV access pattern.  This module
generates those streams: Poisson arrivals per decode step, mixed
prompt/output lengths, and a per-request workload ``kind`` naming the
access pattern (resolved by the consumer -- ``repro.serve.sched`` maps
kinds onto ``repro.memtier.workload`` mass generators).

``poisson_request_stream`` generates one stationary phase; concatenate
calls with different rates/mixes (``shifting_mix_stream``) to model the
traffic-mix shifts the online tuner must survive.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["RequestSpec", "poisson_request_stream", "shifting_mix_stream"]


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One request of a traffic stream (all lengths in tokens/steps)."""

    rid: int
    arrival: int                  # decode step the request arrives at
    prompt_len: int
    new_tokens: int               # output budget (retire on length)
    kind: str                     # access-pattern name (consumer-resolved)
    seed: int

    def total_tokens(self, prefix_len: int = 0) -> int:
        return prefix_len + self.prompt_len + self.new_tokens

    def n_pages(self, page_size: int, prefix_len: int = 0) -> int:
        """KV pages the request occupies (page-aligned allocation)."""
        return -(-self.total_tokens(prefix_len) // page_size)


def poisson_request_stream(steps: int, rate: float,
                           kinds: Dict[str, float], *,
                           prompt_len: Tuple[int, int] = (16, 64),
                           new_tokens: Tuple[int, int] = (32, 128),
                           start: int = 0, rid0: int = 0,
                           seed: int = 0) -> List[RequestSpec]:
    """One stationary traffic phase: per decode step, ``Poisson(rate)``
    requests arrive; each draws its kind from the ``kinds`` weight map and
    its prompt/output lengths uniformly from the given inclusive ranges.
    Arrivals are offset by ``start`` and request ids by ``rid0`` so phases
    concatenate cleanly."""
    rng = np.random.default_rng(seed)
    names = sorted(kinds)
    w = np.asarray([kinds[k] for k in names], np.float64)
    w = w / w.sum()
    specs: List[RequestSpec] = []
    rid = rid0
    for t in range(steps):
        for _ in range(int(rng.poisson(rate))):
            specs.append(RequestSpec(
                rid=rid, arrival=start + t,
                prompt_len=int(rng.integers(prompt_len[0],
                                            prompt_len[1] + 1)),
                new_tokens=int(rng.integers(new_tokens[0],
                                            new_tokens[1] + 1)),
                kind=names[int(rng.choice(len(names), p=w))],
                seed=int(rng.integers(0, 2 ** 31 - 1))))
            rid += 1
    return specs


def shifting_mix_stream(phases: Sequence[Tuple[int, float, Dict[str, float]]],
                        *, prompt_len: Tuple[int, int] = (16, 64),
                        new_tokens: Tuple[int, int] = (32, 128),
                        seed: int = 0) -> List[RequestSpec]:
    """Concatenate stationary phases ``(steps, rate, kind_weights)`` into
    one stream whose arrival mix shifts at each phase boundary -- the
    workload the scheduler-fed online tuner is benchmarked against."""
    specs: List[RequestSpec] = []
    start = 0
    for i, (steps, rate, kinds) in enumerate(phases):
        specs.extend(poisson_request_stream(
            steps, rate, kinds, prompt_len=prompt_len,
            new_tokens=new_tokens, start=start, rid0=len(specs),
            seed=seed + 7919 * i))
        start += steps
    return specs
