"""Continuous-batching serving scheduler over one shared KV page pool.

The paper's tuner wants the *aggregate* workload, not one request: this
module is the layer that owns a shared hybrid-memory pool across many
in-flight requests and feeds online Cori from the merged traffic.

  * ``ContinuousBatcher`` -- the model-backed scheduler: requests join the
    running batch between decode steps (admission is per-step, prefills
    of a step's joiners run as ONE packed forward pass, and each
    request's KV occupies whole bucket-rounded page runs of the shared
    pool, so joins are page-aligned by construction), decode runs over
    the whole request set, and requests retire on EOS or length,
    returning their pages.  In **fully-paged mode** (the default) the
    shared pool is the ONLY state store for EVERY cache geometry:
    plain/local attention gathers (k, v) token pages, MLA gathers
    compressed (ckv, krope) pages, recurrent cells read/write one packed
    state page per request, prefix architectures map shared read-only
    prefix pages prefilled once -- all through the pool's ``slot_of``
    tables, and the per-page masses feeding the tuner come from ALL
    state-bearing layers of that same decode step.
  * ``TrafficScheduler`` -- the model-free twin for traffic simulation:
    each request is a synthetic per-step page-mass pattern
    (``repro.memtier.workload``), so thousands of scheduler steps replay
    without touching KV bytes.  Same admission, bucket-rounded
    allocation, merge and retirement path.
  * ``TrafficMonitor`` -- the traffic-level monitor: merges per-request
    page masses into the global logical-page ID space and drives ONE
    ``TieringManager`` (+ optional ``OnlineTuner``) for the whole mix.

Invariants (pinned by tests/test_sched.py):

  * **Page-ID recycling contract.**  A retiring request's global IDs are
    released *everywhere* -- pool slots, manager hotness, the tuner's
    reuse collector -- before the allocator may recycle them, so a
    recycled ID starts cold and never inherits the old owner's reuse
    chain (``TrafficMonitor.release`` is the single choke point).
  * **Active-mask semantics.**  Tiering ranks only pages of in-flight
    requests (``pools.allocated_mask``); bucket-tail pages a request
    holds but has not yet written are allocated (and thus rankable) but
    carry no mass, so they tier out naturally.
  * **Token parity.**  A request's emitted stream is identical to
    per-request ``engine.generate`` with the same prompt/key -- across
    dense vs fully-paged decode, staggered admission, batched prefill,
    row reuse and temperature sampling.
  * **Residency before decode.**  In fully-paged mode every page the
    step's attention can touch is made HBM-resident first
    (``ensure_resident``, charged as on-demand fetch misses); the kernel
    never gathers a host-only page.  Admission is gated so the in-flight
    footprint fits the HBM slot pool.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cori
from repro.core.traffic import RequestSpec
from repro.ft.inject import NULL_PLAN
from repro.ft.monitor import StepTimer
from repro.kernels import ops
from repro.memtier import workload as W
from repro.memtier.tiering import (PAGE_DROP, SharedPagedPools,
                                   TieringManager, bucket_pages,
                                   write_pages_batched)
from repro.models import model as mdl
from repro.obs import telemetry as _obs
from repro.serve import engine as E
from repro.serve.pipeline import DecisionWorker

__all__ = ["Request", "TrafficMonitor", "ContinuousBatcher",
           "TrafficScheduler", "WORKLOAD_KINDS"]


# ---------------------------------------------------------------------------
# traffic-level monitor: merged masses -> one manager/tuner
# ---------------------------------------------------------------------------


class TrafficMonitor:
    """Merges per-request page masses into the global page-ID space and
    feeds one ``TieringManager`` + optional ``OnlineTuner`` for the whole
    traffic mix -- the aggregation point between the scheduler and Cori."""

    def __init__(self, pools: SharedPagedPools, manager: TieringManager,
                 tuner: Optional[cori.OnlineTuner] = None):
        if manager.n != pools.n_logical:
            raise ValueError("manager and pools disagree on the logical "
                             f"page space ({manager.n} vs {pools.n_logical})")
        self.pools = pools
        self.manager = manager
        self.tuner = tuner

    def merge(self, contributions: Sequence[Tuple[np.ndarray, np.ndarray]]
              ) -> np.ndarray:
        """Scatter per-request (gids, local_mass) rows into one global
        f32[n_logical] mass vector (max-merge: a page is as hot as its
        hottest accessor, matching the engine's batch reduction)."""
        mass = np.zeros(self.pools.n_logical, np.float32)
        for gids, local in contributions:
            np.maximum.at(mass, np.asarray(gids, np.int64),
                          np.asarray(local, np.float32)[: len(gids)])
        return mass

    def on_step(self, global_mass: np.ndarray,
                n_active: Optional[float] = None, *,
                n_tokens: Optional[int] = None,
                force_tier: bool = False, fetched: int = 0,
                degraded: int = 0) -> int:
        """Feed one scheduler step's merged masses: accounting, periodic
        tiering over the shared pool, and the closed tuning loop.  Returns
        the tiering period now in force.

        With ``n_active`` the tuner is fed the *per-request* step cost.
        Aggregate cost scales with however many requests happen to be in
        flight, so a burst of arrivals (or a drain of retirements) looks
        exactly like workload drift and makes the tuner churn through
        re-profiles on a perfectly stable mix; per-request cost is the
        load-invariant serving metric the drift detector should watch.
        ``n_tokens`` declares how many token-steps this feed spans (the
        macro length): the tuner's clock and reuse gaps advance by it and
        the manager's service-cost accounting scales by it, keeping the
        derived period in the token-step units it is actuated in and the
        per-token cost comparable across period lengths.  ``fetched``
        demand-fetch page misses are charged INSIDE the cost window (the
        macro path prefetches its horizon up front -- those misses are
        the price of the current period and must reach the tuner).  They
        are priced at ``fetch_cost``, not ``miss_penalty``: the pools
        batch every ``ensure_resident`` call's host->HBM copies into one
        gathered transfer, so a prefetched page is cheaper than the
        synchronous mid-decode stall ``miss_penalty`` models.  Every
        fetch path routes through here so the pricing cannot fork.
        ``force_tier`` tiers regardless of the step cadence.

        The tuner's adversarial-traffic defenses (cost-spike guardrail,
        variance-scaled trial windows, warm re-tunes -- see
        ``OnlineTuner``) apply unchanged here: both the per-token and
        the macro path route every cost observation through
        ``tuner.on_step``, so a flash crowd poisoning a TRIAL mid-sweep
        aborts to the last-good period on either path.  A non-finite
        merged mass (a NaN'd attention row) is clamped to zero before it
        can corrupt the reuse collector's accessed-set thresholding."""
        mgr = self.manager
        if not np.all(np.isfinite(global_mass)):
            global_mass = np.nan_to_num(global_mass, nan=0.0,
                                        posinf=0.0, neginf=0.0)
        before = mgr.modeled_time
        if fetched:
            mgr.misses += fetched
            mgr.modeled_time += fetched * mgr.cfg.fetch_cost
        if degraded:
            # retry-exhausted fetches lost the batched-transfer discount:
            # top their price up from fetch_cost to the synchronous
            # miss_penalty, INSIDE the tuner's window, so Cori re-plans
            # around the failing pages instead of seeing them as cheap
            mgr.modeled_time += degraded * max(
                0.0, mgr.cfg.miss_penalty - mgr.cfg.fetch_cost)
        mgr.on_step(global_mass, self.pools.resident_mask,
                    weight=float(n_tokens or 1))
        mgr.maybe_tier(self.pools, active=self.pools.allocated_mask,
                       force=force_tier)
        if self.tuner is not None:
            cost = mgr.modeled_time - before
            if n_active is not None:
                cost /= max(1, n_active)
            mgr.set_period(self.tuner.on_step(global_mass, cost=cost,
                                              dt=n_tokens or 1))
        return mgr.period

    def on_macro_step(self, global_mass: np.ndarray,
                      n_active: Optional[float] = None,
                      n_tokens: int = 1, fetched: int = 0,
                      degraded: int = 0) -> int:
        """Feed one *macro step* (one movement period) of merged masses.

        The macro-step serving loop wakes the host exactly once per
        period, so this is one accounting step, a FORCED tier (every
        wakeup is a tiering boundary -- the period knob now controls the
        macro length itself, not a sub-cadence), and one tuner update
        spanning ``n_tokens`` token-steps: the tuner's reuse gaps and
        trial windows keep counting TOKENS (quantised to macro
        boundaries), so the period it derives means the same thing it
        does on the per-token path.  ``n_active`` is the mean number of
        in-flight requests over the macro (per-request cost
        normalisation, as on_step); ``fetched`` is the macro's up-front
        demand-fetch count, charged inside the tuner's cost window."""
        return self.on_step(global_mass, n_active, n_tokens=n_tokens,
                            force_tier=True, fetched=fetched,
                            degraded=degraded)

    def plan_step(self, global_mass: np.ndarray,
                  n_active: Optional[float] = None, *,
                  n_tokens: int = 1, fetched: int = 0,
                  degraded: int = 0,
                  resident: Optional[np.ndarray] = None,
                  n_free: int = 0,
                  active: Optional[np.ndarray] = None,
                  planes: int = 2):
        """The *worker half* of a pipelined macro boundary: identical
        accounting to ``on_macro_step`` (NaN clamp, fetch charge, manager
        feed, tuner update) except tiering stops at ``plan_tier`` -- no
        pool mutation -- so the whole call can run on the background
        ``DecisionWorker`` while the next scan is in flight.

        ``resident``/``n_free``/``active`` are snapshots the dispatch
        thread took at the boundary (the pools move on between plan and
        apply; ``TieringManager.apply_plan`` revalidates against the live
        state).  Thread-safety comes from the worker's strict-alternation
        protocol, not locks: the dispatch thread only touches the
        manager/tuner between ``wait`` and the next ``submit``, when the
        worker is idle.  Returns ``(period, plan)`` where ``plan`` is the
        ``(bring, evict)`` pair for ``apply_decision``."""
        mgr = self.manager
        if not np.all(np.isfinite(global_mass)):
            global_mass = np.nan_to_num(global_mass, nan=0.0,
                                        posinf=0.0, neginf=0.0)
        before = mgr.modeled_time
        if fetched:
            mgr.misses += fetched
            mgr.modeled_time += fetched * mgr.cfg.fetch_cost
        if degraded:
            mgr.modeled_time += degraded * max(
                0.0, mgr.cfg.miss_penalty - mgr.cfg.fetch_cost)
        mgr.on_step(global_mass, resident, weight=float(n_tokens or 1))
        plan = mgr.plan_tier(resident, n_free, active=active,
                             planes=planes, force=True)
        if self.tuner is not None:
            cost = mgr.modeled_time - before
            if n_active is not None:
                cost /= max(1, n_active)
            mgr.set_period(self.tuner.on_step(global_mass, cost=cost,
                                              dt=n_tokens or 1))
        return mgr.period, plan

    def apply_decision(self, plan) -> None:
        """The *dispatch half*: actuate a worker-planned tiering move on
        the live pools (``apply_plan`` revalidates each page first)."""
        if plan is not None:
            self.manager.apply_plan(self.pools, *plan)

    def release(self, gids: np.ndarray) -> None:
        """Retire a request's pages everywhere: pool slots freed, manager
        hotness cleared, reuse-collector entries invalidated (a recycled
        global ID must not inherit the old owner's reuse chain)."""
        self.manager.release(gids)
        if self.tuner is not None:
            self.tuner.forget_pages(gids)
        self.pools.free(gids)


# ---------------------------------------------------------------------------
# model-backed continuous batcher
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One serving request and its in-flight state."""

    rid: int
    prompt: np.ndarray                 # int32[plen]
    max_new_tokens: int
    eos_id: Optional[int] = None
    temperature: float = 0.0
    key: Optional[jax.Array] = None    # defaults to PRNGKey(0), as generate()
    #: deadline in scheduler steps from submission; None = no deadline.
    #: A request whose deadline passes while still QUEUED is shed
    #: (status "expired"); once admitted it always runs to completion
    #: (aborting mid-decode would break the token-parity contract)
    ttl_steps: Optional[int] = None
    # -- runtime state (owned by the batcher) --
    #: typed terminal status: "completed" | "shed" | "expired"
    status: str = ""
    deadline_step: int = -1            # absolute step the ttl resolves to
    row: int = -1
    gids: Optional[np.ndarray] = None  # pages the request OWNS (kv + state)
    n_pages: int = 0                   # exact page footprint
    n_alloc: int = 0                   # bucket-rounded pages actually held
    # paged mode: the pages the request's table maps (shared prefix pages
    # + own kv pages + state page) and their columns in the mass rows --
    # a superset of ``gids``: shared pages are mapped, never owned
    table_gids: Optional[np.ndarray] = None
    mass_cols: Optional[np.ndarray] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    _key: Optional[jax.Array] = None
    _i: int = 0                        # decode iterations done
    # pipelined admission: the lazily-sampled first token (a [1] device
    # array still chained behind the prefill) whose host bookkeeping --
    # the int() download, the tokens append, the emit -- is deferred to
    # the next macro boundary so activation never blocks the launch
    _first_tok: object = None
    _t_submit: float = 0.0             # wall clock at submit (deadline_ms)
    # preemption freeze-frame: the row state saved when the request is
    # frozen (pages stay allocated host-side; _key/_i live on the
    # request already, so reactivation is a pure row re-install)
    _frozen_pos: int = 0
    _frozen_tok: int = 0

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.max_new_tokens


@dataclasses.dataclass
class _PendingAdmit:
    """A reserved-but-not-yet-active admission of the pipelined loop:
    the row and pages are held (the HBM admission gate counts them) and
    the prefill is dispatched -- packed before the same boundary's
    launch, or chunk-by-chunk inside overlap windows for long prompts --
    after which the row activates lazily (the first-token sample chains
    behind the prefill; only its bookkeeping waits for a boundary)."""

    req: Request
    plen: int
    chunked: bool = False
    past: object = None          # accumulated chunk cache (chunked only)
    next_start: int = 0          # absolute position of the next chunk
    chunk_idx: int = 0
    logits: object = None        # lazy [1, 1, V] first-token logits
    ready: bool = False
    t_submit: float = 0.0


class ContinuousBatcher:
    """Continuous batching: a fixed-capacity request-set decoded together.

    ``max_active`` rows are decoded together; requests are admitted into
    free rows between decode steps and retired on EOS or length (pages
    released).  A step's joiners are prefilled as ONE packed right-padded
    forward pass (``model.prefill_batched``) whenever the architecture
    has no recurrent state.  Per-request sampling keys follow exactly
    ``engine.generate``'s schedule, so a request's token stream is
    identical to running ``generate`` alone with the same prompt/key --
    the property the traffic benchmark pins down.

    Decode data paths:

    * **Fully paged** (``paged=True``, the default whenever a monitor is
      attached -- every registered cache geometry is expressible on the
      shared slot pool): the shared pool is the ONLY state store.  Each
      request's token pages occupy a bucket-rounded run of global pages
      (``memtier.bucket_pages``); every state-bearing layer decodes
      through the pool's ``slot_of`` tables (``model.decode_step_paged``)
      with its own leaf geometry -- (k, v) token rows for plain/local
      attention, compressed (ckv, krope) rows for MLA, one packed state
      page per request for recurrent cells (mapped at a fixed table
      column past every token position, so attention never reads it),
      and ``prefix_len`` architectures map shared read-only prefix pages
      that are prefilled ONCE at batcher construction instead of
      re-prefilled per admission.  There is no dense per-row ``max_len``
      cache at all; peak cache memory is the sum of the in-flight
      bucket-rounded footprints plus the one shared prefix run.  The
      per-page masses feeding the tuner come from ALL state-bearing
      layers of the decode step itself (head-normalised attention mass,
      a unit state-page touch per recurrent layer, layer-averaged) --
      the true aggregate traffic, not a one-layer sample.  Before each
      step, every page the decode can touch is demand-fetched into HBM
      (charged as misses); admission is gated so the in-flight exact
      footprint fits the HBM slot pool.

      By default the paged path runs **macro-step decode** (``macro=True``):
      one device launch per movement period (``model.decode_macro_step``
      -- on-device sampling, EOS/length masking, mass accumulation), so
      the host only intervenes at tiering boundaries: tables upload once
      per macro, ``(tokens, summed mass, finished flags)`` download once,
      and the monitor merge collapses to one call per period.
      ``macro=False`` keeps the per-token paged loop (the measured
      baseline); ``macro_steps`` pins a fixed macro length instead of
      tracking the manager's live Cori period.

    * **Dense** (``paged=False``; the measured baseline): ``max_active``
      rows share one packed cache of ``max_len`` positions, the monitor
      layer's masses are recomputed per step (``engine.make_monitor``)
      and, with ``mirror_pages=True``, that layer's pages are
      write-through mirrored into the shared pool for ``paged_context``.

    With ``pipeline=True`` (macro mode only) the loop runs as a software
    pipeline: each scheduler step completes the *previous* macro, then
    launches the next one and does the boundary's host work -- tiering
    decision apply, admission prefill, next-horizon prefetch, table
    staging -- in the **overlap window** behind the in-flight scan
    (docs/serving.md, "Pipelined macro loop").  Tiering/tuner decisions
    move to a background ``DecisionWorker`` and land one boundary late
    (the stale-by-one contract); ``admit_chunk_tokens`` bounds how much
    long-prompt prefill any single window dispatches (the SLO admission
    knob; ``None`` keeps whole-prompt packed admission).  Overlap only
    changes *when* work happens, never *what* is computed: the emitted
    streams are token-identical to the synchronous loop.  In pipelined
    mode ``paged_context`` probes and manager/tuner reads are only safe
    between ``step()`` calls after ``run()`` returned (the worker may be
    mid-decision otherwise); call ``close()`` to tear the worker down.

    ``cond`` ([T, d] or [1, T, d]) is the serving session's shared
    cross-attention conditioning (musicgen-style archs); ``extra_embeds``
    ([prefix_len, d] or [1, prefix_len, d]) is the shared prefix, required
    whenever ``cfg.prefix_len > 0``.
    """

    def __init__(self, params, cfg, *, max_active: int = 4,
                 max_len: int = 128, page_size: int = 16,
                 monitor: Optional[TrafficMonitor] = None,
                 mirror_pages: bool = False,
                 paged: Optional[bool] = None,
                 paged_impl: str = "reference",
                 macro: Optional[bool] = None,
                 macro_steps: Optional[int] = None,
                 pipeline: bool = False,
                 admit_chunk_tokens: Optional[int] = None,
                 cond=None, extra_embeds=None,
                 fault_plan=None,
                 max_queue: Optional[int] = None,
                 watchdog_s: Optional[float] = None,
                 max_worker_restarts: int = 3):
        self.params, self.cfg = params, cfg
        self.page_size = page_size
        self.max_len = -(-max_len // page_size) * page_size
        self.max_active = max_active
        self.prefix = cfg.prefix_len or 0
        self.monitor = monitor
        self._has_state = mdl.has_state_pages(cfg)
        self._has_attn = mdl.has_attention(cfg)
        self._state_extra = 1 if self._has_state else 0
        # one extra table column holds the state page, PAST every token
        # position (col * page_size >= any length), so attention kernels
        # can never gather it
        self.n_row_pages = self.max_len // page_size + self._state_extra
        can_page = monitor is not None and mdl.paged_supported(cfg)
        self.paged = can_page if paged is None else bool(paged)
        if self.paged and not can_page:
            raise ValueError("fully-paged decode needs a TrafficMonitor "
                             f"({cfg.name})")
        if self.prefix % page_size:
            raise ValueError(f"prefix_len {self.prefix} must be page-"
                             f"aligned (page_size {page_size}) so request "
                             "pages start on a page boundary")
        if self.prefix and self._has_state:
            raise ValueError("shared prefix pages cannot seed recurrent "
                             "state (no such architecture is registered)")
        self._prefix_pages = self.prefix // page_size
        if self.prefix and extra_embeds is None:
            raise ValueError(f"{cfg.name}: serving needs the shared prefix "
                             "embeddings (extra_embeds [prefix_len, "
                             "d_model])")
        self._ex = None
        if extra_embeds is not None:
            ex = jnp.asarray(extra_embeds)
            self._ex = ex[None] if ex.ndim == 2 else ex
        self._cond = None
        self._cond_rows = None
        if cond is not None:
            c = jnp.asarray(cond)
            self._cond = c[None] if c.ndim == 2 else c
            self._cond_rows = jnp.broadcast_to(
                self._cond, (max_active,) + self._cond.shape[1:])
        # macro-step decode: the default hot loop whenever fully paged --
        # the host wakes once per movement period (``macro_steps`` pins a
        # fixed macro length; None tracks the manager's live Cori period).
        # ``macro=False`` keeps the per-token paged loop (the benchmark
        # baseline the macro path is measured against).
        self.macro = self.paged if macro is None else bool(macro)
        if self.macro and not self.paged:
            raise ValueError("macro-step decode runs on the fully-paged "
                             "path only")
        self.macro_steps = macro_steps
        # pipelined macro loop (opt-in): the synchronous loop stays the
        # measured baseline and keeps its pinned per-step contracts
        self.pipeline = bool(pipeline)
        if self.pipeline and not self.macro:
            raise ValueError("pipeline=True needs macro-step decode (the "
                             "overlap window is the macro's flight time)")
        self.admit_chunk_tokens = admit_chunk_tokens
        if admit_chunk_tokens is not None:
            if admit_chunk_tokens < 1:
                raise ValueError("admit_chunk_tokens must be >= 1")
            # page-aligned chunks: every pool page is written by exactly
            # one chunk's scatter
            self._chunk_width = -(-admit_chunk_tokens // page_size) \
                * page_size
        else:
            self._chunk_width = None
        # the write-through mirror needs the LEGACY single-layer arrays;
        # a layered-only pool is physical but has no k_host/k_hbm pair
        self.mirror_pages = (not self.paged) and mirror_pages \
            and monitor is not None and monitor.pools.k_host is not None
        self._batched_prefill = mdl.batched_prefill_supported(cfg)
        if self._batched_prefill:
            # admission prefills were dispatched eagerly (op-by-op) -- on
            # the serving path that dwarfed the decode itself.  Jit it;
            # prompt lengths are pow2-bucketed in _prefill so the compile
            # cache is bounded (causal padding cannot change valid rows)
            self._prefill_fn = jax.jit(functools.partial(
                mdl.prefill_batched, params, cfg))

        # macro-launch straggler detection (the serving twin of the
        # training loop's step timer); its name routes flags and the
        # step-time histogram into the flight recorder
        self.macro_timer = StepTimer(name="serve.macro")

        self.tok = jnp.zeros((max_active, 1), jnp.int32)
        self.pos = jnp.zeros((max_active,), jnp.int32)
        self.rows_free = list(range(max_active - 1, -1, -1))
        self.active: Dict[int, Request] = {}
        self.queue: "collections.deque[Request]" = collections.deque()
        self.step_idx = 0
        self.completed: List[Request] = []

        # -- overload-safety machinery (docs/robustness.md) --
        #: deterministic fault-injection plan; inert by default
        self.fault_plan = fault_plan if fault_plan is not None else NULL_PLAN
        if monitor is not None:
            monitor.pools.fault_plan = self.fault_plan
        #: bounded submit queue: a submit past this depth is shed
        #: immediately (status "shed") instead of queueing unboundedly
        self.max_queue = max_queue
        #: DecisionWorker watchdog: how long a boundary may wait for the
        #: background decision before declaring the worker hung, falling
        #: back to a synchronous decision and restarting it.  None keeps
        #: the untimed wait (the fault-free default)
        self.watchdog_s = watchdog_s
        self.max_worker_restarts = max_worker_restarts
        self._worker_restarts = 0
        self._worker_degraded = False   # restarts exhausted: stay sync
        #: live-epoch guard: bumped on every worker restart so a zombie
        #: worker thread that wakes after being abandoned sees a stale
        #: epoch in its payload and never touches the manager/tuner
        self._live_epoch = 0
        self._last_payload: Optional[Dict] = None
        #: preemption-frozen requests, FIFO (oldest reactivates first)
        self._frozen: List[Request] = []
        self.preemptions = 0
        self.shed = 0                   # queue-full sheds
        self.expired = 0                # deadline expiries while queued

        # epoch-keyed device table cache: (pools.slot_epoch, _rows_epoch)
        # unchanged => the staged upload is reused (a buffer swap), so a
        # boundary where tiering moved nothing skips the rebuild+upload
        self._rows_epoch = 0
        self._tables_key = None
        self._tables_dev = None
        # pipelined-loop state (inert when pipeline=False)
        self._inflight: Optional[Dict] = None
        self._pending_admits: List[_PendingAdmit] = []
        self._prefetched_next = 0
        self._decision_gen: Optional[int] = None
        self._chunk_fns: Dict[int, Callable] = {}
        self._decision_worker = (DecisionWorker(self._plan_decision)
                                 if self.pipeline else None)

        if self.paged:
            pools = monitor.pools
            if pools.kv_layers is None:
                pools.attach_layered(mdl.slot_leaf_specs(cfg, page_size),
                                     dtype=jnp.float32)
            self.cache = None
            self._hbm_need = 0     # exact pages the in-flight set can touch
            self._gid_tables = np.full((max_active, self.n_row_pages), -1,
                                       np.int32)
            # recurrent archs: every row's state page sits at the fixed
            # last table column (see n_row_pages above)
            self._state_cols = (jnp.full((max_active,), self.n_row_pages - 1,
                                         jnp.int32)
                                if self._has_state else None)
            # the kv pytree is dead after the call (set_kv replaces it):
            # donate it so XLA updates the pool buffers in place instead
            # of copying the whole layered store every step
            self._paged_fn = jax.jit(functools.partial(
                mdl.decode_step_paged, params, cfg,
                page_size=page_size, impl=paged_impl), donate_argnums=(0,))
            self._paged_impl = paged_impl
            # one compiled macro per scan length (bounded: lengths are the
            # tuner's period ladder, pow2-capped by the remaining work)
            self._macro_fns: Dict[int, Callable] = {}
            # shared read-only prefix: allocated + prefilled ONCE; every
            # request's table maps these pages, admission never
            # re-prefills the prefix
            self._prefix_gids: Optional[np.ndarray] = None
            if self._prefix_pages:
                g = pools.alloc(self._prefix_pages, -1)
                if g is None:
                    raise ValueError(
                        f"the logical space ({pools.n_logical}) cannot hold "
                        f"the {self._prefix_pages} shared prefix pages")
                self._prefix_gids = g
                self._hbm_need += self._prefix_pages
                self._prefill_prefix_pages()
        else:
            # prefill produces float32 caches on this substrate; the packed
            # cache must match or row writes would silently downcast
            self.cache = mdl.init_cache(cfg, max_active, self.max_len,
                                        dtype=jnp.float32)
            self._step_fn = jax.jit(
                lambda c, t, p, cond=None: mdl.decode_step(
                    params, cfg, c, t, p, cond=cond))
        self._mon_fn = (E.make_monitor(params, cfg, page_size,
                                       self.n_row_pages)
                        if monitor is not None and not self.paged else None)
        # the monitor SLOT only exists for architectures with a
        # full-attention layer; the fully-paged path monitors every layer
        # itself and only needs the slot for ``paged_context`` probes
        try:
            self._si, self._sj = E.monitor_slot(cfg)
        except ValueError:
            self._si = self._sj = None
        if self.mirror_pages and self._si is None:
            raise ValueError(f"{cfg.name}: mirror_pages needs a "
                             "full-attention monitor layer")

    # -- admission -----------------------------------------------------------
    def _pages_kv_exact(self, req: Request) -> int:
        """Exact token pages the request's own positions span.  In paged
        mode the shared prefix pages are NOT the request's (they are
        mapped, not owned, and the prefix is page-aligned so its own
        tokens start on a page boundary); pure-recurrent architectures
        keep no token pages at all."""
        if not self.paged:
            return -(-(self.prefix + req.total_len) // self.page_size)
        if not self._has_attn:
            return 0
        return -(-req.total_len // self.page_size)

    def _pages_exact(self, req: Request) -> int:
        """Exact own-page footprint: token pages plus the state page."""
        return self._pages_kv_exact(req) + (self._state_extra if self.paged
                                            else 0)

    def _pages_alloc(self, req: Request) -> int:
        """Bucket-rounded allocation size (power-of-two token pages,
        capped at one row, plus the un-bucketed state page): what the
        request actually holds in the shared pool."""
        if self.monitor is None:
            return 0
        if not self.paged:
            return bucket_pages(self._pages_exact(req), cap=self.n_row_pages)
        kv_exact = self._pages_kv_exact(req)
        cap = self.max_len // self.page_size - self._prefix_pages
        kv_alloc = bucket_pages(kv_exact, cap=cap) if kv_exact else 0
        return kv_alloc + self._state_extra

    def submit(self, req: Request) -> None:
        req._t_submit = time.monotonic()
        req.deadline_step = (self.step_idx + req.ttl_steps
                             if req.ttl_steps is not None else -1)
        if self.prefix + req.total_len > self.max_len:
            raise ValueError(f"request {req.rid} needs "
                             f"{self.prefix + req.total_len} positions, "
                             f"cache rows hold {self.max_len}")
        if self.monitor is not None:
            n_pages = self._pages_alloc(req)
            avail = self.monitor.pools.n_logical - self._prefix_pages
            if n_pages > avail:
                # would head-of-line-block the queue forever: alloc can
                # never succeed, not even with the pool fully drained
                raise ValueError(
                    f"request {req.rid} needs {n_pages} pages, the logical "
                    f"space holds {avail} beyond the shared prefix")
            if self.paged and (self._prefix_pages + self._pages_exact(req)
                               > self.monitor.pools.hbm_pages):
                raise ValueError(
                    f"request {req.rid} touches "
                    f"{self._prefix_pages + self._pages_exact(req)} "
                    f"pages, the HBM slot pool holds "
                    f"{self.monitor.pools.hbm_pages}: it can never decode "
                    "fully paged")
        if (self.max_queue is not None and len(self.queue) >= self.max_queue
                and self.fault_plan.fires("admit.flood") is None):
            # bounded queue: shed at submit time with a typed status
            # instead of queueing unboundedly.  An armed ``admit.flood``
            # fault bypasses the bound -- the chaos harness forces the
            # queue past its depth to prove downstream stages still shed
            # rather than stall.
            self._retire_unadmitted(req, "shed", "queue-full")
            return
        self.queue.append(req)

    def _retire_unadmitted(self, req: Request, status: str,
                           reason: str) -> None:
        """Terminate a request that never reached a row: load-shed at
        submit (``status="shed"``) or deadline-expired while queued
        (``status="expired"``).  It lands in ``completed`` with an empty
        token stream -- every submitted request terminates with a typed
        status, the no-hang contract tests/test_faults.py pins."""
        req.done = True
        req.status = status
        self.completed.append(req)
        if status == "shed":
            self.shed += 1
        else:
            self.expired += 1
        if (r := _obs.RECORDER).enabled:
            r.emit("serve.shed", step=self.step_idx, rid=req.rid,
                   reason=reason, queue_depth=len(self.queue))
            r.emit("serve.retire", step=self.step_idx, rid=req.rid,
                   tokens=0, status=status,
                   deadline_ms=(time.monotonic() - req._t_submit) * 1e3
                   if req._t_submit else 0.0)
            r.count("serve.shed_total")
            r.count("serve.retired")

    def _expire_queue(self) -> None:
        """Drop queued requests whose deadline has passed (admission-time
        TTL): they can no longer finish useful work, so spending rows and
        pages on them only delays in-deadline traffic.  Admitted requests
        are never aborted (token-parity contract)."""
        if not any(req.deadline_step >= 0 for req in self.queue):
            return
        keep: List[Request] = []
        for req in self.queue:
            if 0 <= req.deadline_step < self.step_idx:
                self._retire_unadmitted(req, "expired", "deadline")
            else:
                keep.append(req)
        self.queue = collections.deque(keep)

    def _admit(self) -> List[Tuple[int, int]]:
        self._expire_queue()
        batch: List[Request] = []
        while self.queue and self.rows_free:
            req = self.queue[0]
            n_exact = self._pages_exact(req)
            n_alloc = self._pages_alloc(req)
            gids = None
            if self.monitor is not None:
                # the gate runs against the EFFECTIVE capacity (equal to
                # hbm_pages unless a squeeze fault shrank it), so new
                # admissions respect the degraded budget
                if self.paged and (self._hbm_need + n_exact
                                   > self.monitor.pools.effective_hbm):
                    break              # head-of-line: keep arrival order
                gids = self.monitor.pools.alloc(n_alloc, req.rid)
                if gids is None:       # head-of-line: keep arrival order
                    break
            self.queue.popleft()
            row = self.rows_free.pop()
            req.row, req.gids, req.n_pages = row, gids, n_exact
            req.n_alloc = n_alloc
            if self.paged:
                self._hbm_need += n_exact
                self._map_row(req)
            batch.append(req)
        if not batch:
            return []
        t0 = time.monotonic()
        emitted = self._prefill(batch)
        if (r := _obs.RECORDER).enabled:
            r.emit("serve.admit", step=self.step_idx, joiners=len(batch),
                   pages=int(sum(b.n_alloc for b in batch)),
                   queue_depth=len(self.queue),
                   wall_ms=(time.monotonic() - t0) * 1e3)
            r.count("serve.admitted", len(batch))
            r.gauge("serve.queue_depth", len(self.queue))
        return emitted

    def _map_row(self, req: Request) -> None:
        """Build the request's logical page-table row: shared prefix
        pages first, its own token-page run next (bucket tail included),
        the state page at the fixed last column.  Also records the
        (gids, mass columns) the monitor merge reads -- exact pages only,
        so bucket-tail slack never accrues mass."""
        pp = self._prefix_pages
        kv_alloc = req.n_alloc - self._state_extra
        kv_own = req.gids[:kv_alloc]
        row = np.full(self.n_row_pages, -1, np.int32)
        if pp:
            row[:pp] = self._prefix_gids
        row[pp: pp + kv_alloc] = kv_own
        parts, cols = [], []
        if pp:
            parts.append(np.asarray(self._prefix_gids, np.int64))
            cols.append(np.arange(pp))
        kv_exact = self._pages_kv_exact(req)
        if kv_exact:
            parts.append(np.asarray(kv_own[:kv_exact], np.int64))
            cols.append(pp + np.arange(kv_exact))
        if self._state_extra:
            row[-1] = req.gids[-1]
            parts.append(np.asarray(req.gids[-1:], np.int64))
            cols.append(np.asarray([self.n_row_pages - 1]))
        self._gid_tables[req.row] = row
        req.table_gids = np.concatenate(parts)
        req.mass_cols = np.concatenate(cols).astype(np.int64)
        self._rows_epoch += 1

    def _slot_table(self, rows: Sequence[int]) -> np.ndarray:
        """Physical HBM slot tables for the given rows, derived from the
        logical ``_gid_tables`` (rebuilt per upload: tiering may have
        re-slotted any resident page)."""
        pools = self.monitor.pools
        tables = np.full((self.max_active, self.n_row_pages), -1, np.int32)
        for row in rows:
            g = self._gid_tables[row]
            m = g >= 0
            tables[row, m] = pools.table(g[m])
        return tables

    def _tables_for(self, rows: Sequence[int]):
        """Device-side ``(slot_table, gid_table)`` pair for a decode
        launch, cached across boundaries: rebuilt and re-uploaded only
        when tiering re-slotted a page (``pools.slot_epoch``) or the
        row->page mapping changed (admission/retire/activation bump
        ``_rows_epoch``).  A boundary where tiering moved zero pages
        becomes a buffer swap; the ``pool.table_upload.performed`` /
        ``.skipped`` counters measure the split.  ``rows`` is implied by
        the epochs (every active-set change bumps ``_rows_epoch``), so
        the key needs no row list."""
        pools = self.monitor.pools
        key = (int(getattr(pools, "slot_epoch", 0)), self._rows_epoch)
        track = (r := _obs.RECORDER).enabled
        if self._tables_key == key and self._tables_dev is not None:
            if track:
                r.count("pool.table_upload.skipped")
            return self._tables_dev
        self._tables_dev = (jnp.asarray(self._slot_table(rows)),
                            jnp.asarray(self._gid_tables))
        self._tables_key = key
        if track:
            r.count("pool.table_upload.performed")
        return self._tables_dev

    def _need(self, pos_np: np.ndarray, horizon: int,
              per_row: Optional[Dict[int, int]] = None) -> np.ndarray:
        """Every page the next ``horizon`` decode steps can touch: the
        shared prefix run, each row's token pages through its horizon
        (incl. the write pages) and its state page."""
        need: List[np.ndarray] = []
        if self._prefix_gids is not None:
            need.append(np.asarray(self._prefix_gids, np.int64))
        pp = self._prefix_pages
        for row, req in self.active.items():
            h = per_row.get(row, horizon) if per_row else horizon
            if self._has_attn:
                n_cols = -(-(int(pos_np[row]) + h) // self.page_size)
                kv_own = req.gids[: req.n_alloc - self._state_extra]
                need.append(np.asarray(kv_own[: max(0, n_cols - pp)],
                                       np.int64))
            if self._state_extra:
                need.append(np.asarray(req.gids[-1:], np.int64))
        if not need:
            return np.asarray([], np.int64)
        return np.concatenate(need)

    def _prefill(self, batch: List[Request]) -> List[Tuple[int, int]]:
        """Prefill a step's joiners as one packed forward pass, seed their
        rows/pages, and sample each first token."""
        plens = [len(r.prompt) for r in batch]
        if self._batched_prefill:
            # pow2-bucket BOTH packed dims -- width and joiner count --
            # so the jitted prefill (and the downstream page scatter)
            # compiles per shape class, not per admission.  Right-padding
            # is inert under causal attention and dummy joiner rows are
            # simply never read, so valid rows are bit-identical.
            smax = bucket_pages(max(plens))
            jp = bucket_pages(len(batch))
            toks = np.zeros((jp, smax), np.int32)
            plens_p = np.ones((jp,), np.int32)
            for i, r in enumerate(batch):
                toks[i, : plens[i]] = r.prompt
                # lengths INCLUDE the shared prefix: the last valid
                # position of row i sits at prefix + plen - 1
                plens_p[i] = self.prefix + plens[i]
            kw = {}
            if self._cond is not None:
                kw["cond"] = jnp.broadcast_to(
                    self._cond, (jp,) + self._cond.shape[1:])
            if self._ex is not None:
                kw["extra_embeds"] = jnp.broadcast_to(
                    self._ex, (jp,) + self._ex.shape[1:])
            logits_b, cache_b = self._prefill_fn(
                jnp.asarray(toks), jnp.asarray(plens_p), **kw)
        else:               # recurrent state: one request at a time
            logits_b, cache_b = None, None

        if self.paged and self._batched_prefill:
            # one on-device gather/scatter writes EVERY joiner's KV for
            # EVERY layer straight into the pool slots
            self._write_prefill_pages_batched(cache_b, batch, plens)

        emitted: List[Tuple[int, int]] = []
        for bi, req in enumerate(batch):
            row, plen = req.row, plens[bi]
            if self._batched_prefill:
                logits = logits_b[bi: bi + 1]
                if self.paged:
                    pass                 # pages already written (batched)
                else:
                    one = mdl.row_cache_from_batched(
                        cache_b, self.cfg, bi, self.prefix + plen,
                        self.max_len)
                    self.cache = jax.tree.map(
                        lambda full, o: full.at[:, row].set(o),
                        self.cache, one)
            else:
                prompt = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, cache1 = mdl.prefill(self.params, self.cfg, prompt,
                                             cond=self._cond,
                                             extra_embeds=self._ex)
                if self.paged:
                    self._write_prefill_pages_row(cache1, req, plen)
                else:
                    cache1 = mdl.pad_cache(cache1, self.cfg, self.max_len)
                    self.cache = jax.tree.map(
                        lambda full, o: full.at[:, row].set(o[:, 0]),
                        self.cache, cache1)
            req._key = (req.key if req.key is not None
                        else jax.random.PRNGKey(0))
            tok = E._sample(logits[:, 0], req._key, req.temperature)
            req.tokens.append(int(tok[0]))
            emitted.append((req.rid, int(tok[0])))
            self.tok = self.tok.at[row].set(tok)
            self.pos = self.pos.at[row].set(self.prefix + plen)
            self.active[row] = req
            if self.mirror_pages:
                self._mirror(req, range(-(-(self.prefix + plen)
                                          // self.page_size)))
            if req.max_new_tokens <= 1 or (req.eos_id is not None
                                           and req.tokens[-1] == req.eos_id):
                self._retire(req)
        return emitted

    def _prefill_leaves(self, cache, meta, start: int):
        """{leaf_name: per-slot cache rows} for ``write_pages_batched``,
        sliced from absolute position ``start`` (the shared prefix region
        is written once at construction, not per admission)."""
        leaves: Dict[str, List] = {}
        for li, (si, j, _, _, kind) in enumerate(meta):
            if not kind.is_attention:
                continue
            e = cache["segments"][si][j]
            for name in (("ckv", "krope") if kind.mla else ("k", "v")):
                leaves.setdefault(name, [None] * len(meta))[li] = \
                    e[name][:, :, start:]
        return leaves

    def _write_prefill_pages_batched(self, cache_b, batch: List[Request],
                                     plens: List[int]) -> None:
        """Scatter a whole admission's prefilled cache (every joiner,
        every geometry leaf, host + HBM tiers) into the shared pool in
        ONE jitted gather/scatter (``memtier.write_pages_batched``).
        Slots are assigned bookkeeping-only first (initial placement, not
        charged as misses) since the scatter overwrites both tiers --
        the prefill bytes never take the host detour."""
        pools = self.monitor.pools
        ps = self.page_size
        # own token pages only: the prefix is page-aligned, so each
        # prompt's pages start at cache position ``prefix``
        ns = [-(-p // ps) for p in plens]
        # both scatter dims pow2-bucketed (matching the prefill batch):
        # padded joiner rows / tail pages carry PAGE_DROP and vanish
        jp = cache_b["segments"][0][0]["pos"].shape[1]
        n_max = bucket_pages(max(ns))
        gids_m = np.full((jp, n_max), PAGE_DROP, np.int32)
        slots_m = np.full((jp, n_max), PAGE_DROP, np.int32)
        for i, (req, n) in enumerate(zip(batch, ns)):
            gids_m[i, :n] = req.gids[:n]
        flat = np.concatenate([req.gids[:n]
                               for req, n in zip(batch, ns)])
        slots_flat = pools.assign_slots(flat)
        o = 0
        for i, n in enumerate(ns):
            slots_m[i, :n] = slots_flat[o: o + n]
            o += n
        leaves = self._prefill_leaves(cache_b, mdl.state_slot_meta(self.cfg),
                                      self.prefix)
        pools.set_kv(write_pages_batched(
            pools.kv_view(), leaves, jnp.asarray(gids_m),
            jnp.asarray(slots_m)))

    def _write_prefill_pages_row(self, cache1, req: Request,
                                 plen: int) -> None:
        """Write ONE request's per-request prefill into the shared pool:
        the non-batched admission path of recurrent architectures.  Token
        pages scatter position-keyed (page = pos // ps, offset = pos %
        ps), which lands window-ring cache layouts correctly -- a clipped
        ring holds exactly the unmasked last-window positions, each
        tagged with its absolute position.  Recurrent slots pack their
        final cell state into the request's state page."""
        pools = self.monitor.pools
        ps = self.page_size
        kv_exact = self._pages_kv_exact(req)
        own = req.gids[: req.n_alloc - self._state_extra]
        touched = np.concatenate([own[:kv_exact],
                                  req.gids[-1:] if self._state_extra
                                  else np.asarray([], np.int64)])
        slots = pools.assign_slots(touched)
        kv_slots = slots[:kv_exact]
        kv = pools.kv_view()
        drop = int(PAGE_DROP)
        for li, (si, j, r, _, kind) in enumerate(
                mdl.state_slot_meta(self.cfg)):
            e = cache1["segments"][si][j]
            if kind.is_attention:
                pos = np.asarray(e["pos"][0, 0])      # same across repeats
                valid = pos >= 0
                page = np.clip(np.where(valid, pos, 0) // ps, 0,
                               max(kv_exact - 1, 0))
                rows_s = np.where(valid, kv_slots[page], drop)
                rows_g = np.where(valid, own[:kv_exact][page], drop)
                offs = np.where(valid, pos % ps, 0)
                for name in (("ckv", "krope") if kind.mla else ("k", "v")):
                    arr = e[name][:, 0]               # [R, T, ...]
                    kv[f"{name}_hbm"][li] = kv[f"{name}_hbm"][li].at[
                        :, rows_s, offs].set(arr, mode="drop")
                    kv[f"{name}_host"][li] = kv[f"{name}_host"][li].at[
                        :, rows_g, offs].set(arr, mode="drop")
            else:
                flat = jnp.stack([mdl.pack_state(
                    jax.tree.map(lambda a: a[rr], e))[0] for rr in range(r)])
                kv["state_hbm"][li] = kv["state_hbm"][li].at[
                    :, int(slots[-1])].set(flat)
                kv["state_host"][li] = kv["state_host"][li].at[
                    :, int(req.gids[-1])].set(flat)
        pools.set_kv(kv)

    def _prefill_prefix_pages(self) -> None:
        """Prefill the shared read-only prefix ONCE and write its KV into
        the shared pages every request's table maps.  Under the causal
        mask the prefix positions attend only the prefix embeddings, so
        one dummy-token prefill is exact for every future prompt --
        admission maps these pages instead of re-prefilling the prefix."""
        pools = self.monitor.pools
        dummy = jnp.zeros((1, 1), jnp.int32)
        _, cache1 = mdl.prefill(self.params, self.cfg, dummy,
                                extra_embeds=self._ex, cond=self._cond)
        slots = pools.assign_slots(self._prefix_gids)
        meta = mdl.state_slot_meta(self.cfg)
        leaves: Dict[str, List] = {}
        for li, (si, j, _, _, kind) in enumerate(meta):
            e = cache1["segments"][si][j]
            for name in (("ckv", "krope") if kind.mla else ("k", "v")):
                leaves.setdefault(name, [None] * len(meta))[li] = \
                    e[name][:, :, : self.prefix]
        pools.set_kv(write_pages_batched(
            pools.kv_view(), leaves,
            jnp.asarray(self._prefix_gids, jnp.int32)[None],
            jnp.asarray(slots, jnp.int32)[None]))

    # -- overload safety: fault clock, preemption, reactivation --------------
    def _fault_tick(self) -> None:
        """Advance the fault plan's logical clock once per scheduler step
        and actuate the capacity-squeeze fault: while a ``pool.squeeze``
        point fires, the pool's *effective* HBM capacity shrinks to the
        point's value, and every admission gate, tiering budget and the
        preemption loop run against that budget.  When the window closes
        the full capacity returns."""
        plan = self.fault_plan
        if not plan.enabled:
            return
        plan.tick()
        if self.monitor is not None and self.paged:
            pools = self.monitor.pools
            p = plan.fires("pool.squeeze")
            pools.effective_hbm = (max(1, int(p.value)) if p is not None
                                   else pools.hbm_pages)

    def _rebalance(self) -> None:
        """Pressure response at a scheduler boundary (docs/robustness.md,
        "Preemption semantics").  First reactivate frozen requests whose
        footprint fits the effective capacity again -- FIFO, oldest
        first, with a forced-progress escape: if nothing else is active
        or pending, one frozen request thaws regardless, so a squeeze
        below any single footprint still drains instead of deadlocking.
        Then, while the in-flight footprint exceeds the effective
        capacity, preempt the COLDEST victim -- the active request whose
        pages carry the least manager hotness (Cori page mass), ties to
        the newest rid -- until the remainder fits or one request is
        left (the last row never preempts: forward progress)."""
        if not self.paged or self.monitor is None:
            return
        pools = self.monitor.pools
        while self._frozen and self.rows_free:
            req = self._frozen[0]
            fits = self._hbm_need + req.n_pages <= pools.effective_hbm
            if not fits and (self.active or self._pending_admits):
                break
            self._thaw(self._frozen.pop(0))
        while (self._hbm_need > pools.effective_hbm
               and len(self.active) > 1):
            hot = self.monitor.manager.hotness
            victims = [req for req in self.active.values()
                       if req._first_tok is None]
            if len(victims) <= 1:
                break
            victim = min(victims,
                         key=lambda q: (float(hot[q.gids].sum()), -q.rid))
            self._preempt(victim)

    def _preempt(self, req: Request) -> None:
        """Freeze one active request: demote its own pages to host
        (releasing their HBM slots -- the write-through invariant means
        the host copies are already current, so this moves no data),
        free its row, and park it on the frozen list with the row state
        (position, last token) it needs to resume bit-identically.  Its
        pages stay ALLOCATED -- the KV survives host-side -- so
        reactivation is a row re-install plus demand fetches, never a
        re-prefill."""
        pools = self.monitor.pools
        row = req.row
        req._frozen_pos = int(np.asarray(self.pos)[row])
        req._frozen_tok = int(np.asarray(self.tok)[row, 0])
        hot = float(self.monitor.manager.hotness[req.gids].sum())
        released = pools.demote(req.gids)
        del self.active[row]
        self.rows_free.append(row)
        self._hbm_need -= req.n_pages
        self._gid_tables[row, :] = -1
        self._rows_epoch += 1
        req.row = -1
        self._frozen.append(req)
        self.preemptions += 1
        if (r := _obs.RECORDER).enabled:
            r.emit("serve.preempt", step=self.step_idx, rid=req.rid,
                   pages=int(released), mass=hot,
                   hbm_need=int(self._hbm_need),
                   hbm_cap=int(pools.effective_hbm))
            r.count("serve.preempted")

    def _thaw(self, req: Request) -> None:
        """Reactivate a frozen request into a free row.  ``_key``/``_i``
        never left the request, the pages never left the pool, and the
        saved (position, last token) re-install restores the row exactly
        -- the resumed stream is bit-identical to an uninterrupted run.
        The pages fetch back to HBM lazily through the next launch's
        ``ensure_resident`` (the Cori-visible cost of the preemption)."""
        row = self.rows_free.pop()
        req.row = row
        self._map_row(req)
        self._hbm_need += req.n_pages
        self.pos = self.pos.at[row].set(req._frozen_pos)
        self.tok = self.tok.at[row].set(req._frozen_tok)
        self.active[row] = req
        if (r := _obs.RECORDER).enabled:
            r.count("serve.thawed")

    # -- the per-step scheduler loop -----------------------------------------
    def step(self) -> List[Tuple[int, int]]:
        """One scheduler step: admit (one packed prefill), monitor+tier,
        decode the request set, sample, retire.  Returns the (rid, token)
        pairs emitted this step, including the prefill-sampled first token
        of newly admitted requests.  In pipelined mode a step instead
        completes the PREVIOUS in-flight macro, launches the next one and
        fills the overlap window behind it, so tokens surface one step
        after their macro launched."""
        track = (r := _obs.RECORDER).enabled
        t0 = time.monotonic() if track else 0.0
        self._fault_tick()
        if self.pipeline:
            emitted = self._step_pipelined()
        else:
            self._rebalance()
            emitted = self._admit()
            self.step_idx += 1
            if self.active:
                if self.paged:
                    emitted += (self._step_paged_macro() if self.macro
                                else self._step_paged())
                else:
                    emitted += self._step_dense()
        if track:
            r.observe("serve.step_s", time.monotonic() - t0)
        return emitted

    def _step_dense(self) -> List[Tuple[int, int]]:
        emitted: List[Tuple[int, int]] = []
        if self.monitor is not None:
            masses = np.asarray(self._mon_fn(self.cache, self.tok, self.pos))
            merged = self.monitor.merge(
                [(r.gids[: r.n_pages], masses[r.row, : r.n_pages])
                 for r in self.active.values()])
            self.monitor.on_step(merged, n_active=len(self.active))

        pos_before = np.asarray(self.pos)
        logits, self.cache = self._step_fn(self.cache, self.tok, self.pos,
                                           self._cond_rows)
        self.pos = self.pos + 1
        new_tok = self.tok
        for row, req in list(self.active.items()):
            req._key = jax.random.fold_in(req._key, req._i)
            req._i += 1
            tok = E._sample(logits[row: row + 1, 0], req._key,
                            req.temperature)
            req.tokens.append(int(tok[0]))
            new_tok = new_tok.at[row].set(tok)
            emitted.append((req.rid, int(tok[0])))
            if self.mirror_pages:
                self._mirror(req, [int(pos_before[row]) // self.page_size])
            if (len(req.tokens) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and req.tokens[-1] == req.eos_id)):
                self._retire(req)
        self.tok = new_tok
        return emitted

    def _step_paged(self) -> List[Tuple[int, int]]:
        """Fully-paged decode step: demand-fetch the in-flight working
        set, run every attention layer off the shared slot pool, feed the
        monitor the ALL-layer masses, sample, retire."""
        pools = self.monitor.pools
        pos_np = np.asarray(self.pos)

        # every page this step's decode can touch (shared prefix, token
        # pages incl. the write page, the state page) must be
        # HBM-resident; re-fetches after eviction are on-demand host
        # reads, charged inside the monitor feed below (fetch_cost: the
        # pools batch the copies into one gathered transfer)
        fetched = pools.ensure_resident(self._need(pos_np, 1))
        degraded = pools.degraded_fetches
        pools.degraded_fetches = 0

        # page tables re-upload only when a page re-slotted or the row
        # mapping changed since the last step (epoch-keyed cache)
        tables_dev, gids_dev = self._tables_for(list(self.active))
        cur = np.full((self.max_active,), -1, np.int32)
        for row in self.active:
            cur[row] = pos_np[row]

        logits, kv, masses = self._paged_fn(
            pools.kv_view(), tables_dev, gids_dev, self.tok,
            jnp.asarray(cur), cond=self._cond_rows,
            state_cols=self._state_cols)
        pools.set_kv(kv)
        masses = np.asarray(masses)
        merged = self.monitor.merge(
            [(r.table_gids, masses[r.row][r.mass_cols])
             for r in self.active.values()])
        self.monitor.on_step(merged, n_active=len(self.active),
                             fetched=fetched, degraded=degraded)

        self.pos = self.pos + 1
        emitted: List[Tuple[int, int]] = []
        new_tok = self.tok
        for row, req in list(self.active.items()):
            req._key = jax.random.fold_in(req._key, req._i)
            req._i += 1
            tok = E._sample(logits[row: row + 1, 0], req._key,
                            req.temperature)
            req.tokens.append(int(tok[0]))
            new_tok = new_tok.at[row].set(tok)
            emitted.append((req.rid, int(tok[0])))
            if (len(req.tokens) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and req.tokens[-1] == req.eos_id)):
                self._retire(req)
        self.tok = new_tok
        return emitted

    def _macro_fn(self, n_steps: int):
        fn = self._macro_fns.get(n_steps)
        if fn is None:
            fn = jax.jit(functools.partial(
                mdl.decode_macro_step, self.params, self.cfg,
                page_size=self.page_size, impl=self._paged_impl,
                n_steps=n_steps), donate_argnums=(0,))
            self._macro_fns[n_steps] = fn
        return fn

    def _step_paged_macro(self) -> List[Tuple[int, int]]:
        """Macro-step decode: ONE device launch runs up to a movement
        period's worth of tokens for the whole request set
        (``model.decode_macro_step``), with on-device sampling, mass
        accumulation and EOS/length masking.  The host uploads page
        tables once per macro step and downloads (tokens, summed mass,
        finished flags) once -- between tiering boundaries the loop is
        device-resident, and ``TrafficMonitor.merge`` collapses to one
        call per movement period.  (The pipelined loop splits the same
        launch/complete halves across scheduler steps so the boundary
        host work runs behind the in-flight scan.)"""
        emitted, _ = self._macro_complete(self._macro_launch(), sync=True)
        return emitted

    def _macro_launch(self) -> Dict:
        """Dispatch one macro scan over the current request set and
        return the in-flight record WITHOUT blocking on the result: the
        outputs (tokens, state, the donated-in/returned kv pytree) are
        lazy.  ``pools.set_kv`` publishes the lazy kv immediately, so
        any pool work dispatched before the blocking download -- the
        pipelined prefetch, a tiering apply, an admission chunk's page
        scatter -- consumes these arrays and therefore chains *after*
        the scan on device.  That data dependency is the entire overlap
        mechanism: host work reorders freely, device work cannot."""
        pools = self.monitor.pools
        pos_np = np.asarray(self.pos)
        rows = list(self.active.items())

        period = self.macro_steps or self.monitor.manager.period
        # a lazily-admitted row's first token is still in flight: it
        # counts against the budget (the device's emitted/eos init check
        # relies on it) but is not in req.tokens yet
        ect = {row: len(req.tokens) + (req._first_tok is not None)
               for row, req in rows}
        max_rem = max(req.max_new_tokens - ect[row] for row, req in rows)
        # The scan length is pow2-bucketed on BOTH sides -- the pow2
        # floor of the live period (a non-pow2 period quantises to
        # slightly shorter macros rather than minting a compile per
        # ladder value: the tuner walks arbitrary DR multiples, and each
        # distinct n_steps is a full-model XLA compile) and the pow2
        # ceiling of the remaining work (rows that finish early freeze,
        # and whole overshoot steps short-circuit on device).  The jit
        # cache is therefore log-bounded.
        n_steps = max(1, min(1 << max(0, int(period).bit_length() - 1),
                             bucket_pages(max_rem)))

        # every page the macro's decode can touch (through each row's
        # horizon, incl. the write pages, the shared prefix and state
        # pages) must be HBM-resident up front: the device never calls
        # home mid-macro.  Re-fetches after eviction are on-demand host
        # reads, charged as misses inside the monitor feed below so the
        # tuner's cost window sees them (they are the price of the
        # current period).
        horizons = {row: min(n_steps, req.max_new_tokens - ect[row])
                    for row, req in rows}
        fetched = pools.ensure_resident(
            self._need(pos_np, n_steps, per_row=horizons))
        # pages the pipelined overlap window already prefetched for this
        # macro count toward ITS fetch bill (they are the price of the
        # period, wherever the copy was dispatched)
        fetched += self._prefetched_next
        self._prefetched_next = 0
        # drain the pool's degraded-fetch counter (retry-exhausted,
        # host-pinned fetches -- wherever they were dispatched, incl. the
        # overlap prefetch) into this macro's cost bill: the monitor tops
        # their price up from fetch_cost to miss_penalty
        degraded = pools.degraded_fetches
        pools.degraded_fetches = 0

        # page tables upload once per macro step (tiering only runs at
        # macro boundaries, so no page can re-slot mid-macro) -- and only
        # when something actually changed since the last upload
        # (epoch-keyed cache; otherwise the staged buffer is swapped in)
        tables_dev, gids_dev = self._tables_for([row for row, _ in rows])
        cur = np.full((self.max_active,), -1, np.int32)
        keys = np.zeros((self.max_active, 2), np.uint32)
        iters = np.zeros((self.max_active,), np.int32)
        emitted_ct = np.zeros((self.max_active,), np.int32)
        max_new = np.zeros((self.max_active,), np.int32)
        eos = np.full((self.max_active,), -1, np.int32)
        temps = np.zeros((self.max_active,), np.float32)
        for row, req in rows:
            cur[row] = pos_np[row]
            keys[row] = np.asarray(req._key, np.uint32)
            iters[row] = req._i
            emitted_ct[row] = ect[row]
            max_new[row] = req.max_new_tokens
            eos[row] = -1 if req.eos_id is None else req.eos_id
            temps[row] = req.temperature

        n_flags = len(self.macro_timer.stragglers)
        self.macro_timer.start()
        toks, kv, st = self._macro_fn(n_steps)(
            pools.kv_view(), tables_dev, gids_dev, self.tok,
            jnp.asarray(cur), jnp.asarray(keys), jnp.asarray(iters),
            jnp.asarray(emitted_ct), jnp.asarray(max_new),
            jnp.asarray(eos), jnp.asarray(temps),
            cond=self._cond_rows, state_cols=self._state_cols)
        pools.set_kv(kv)
        return {"toks": toks, "st": st, "rows": rows, "n_steps": n_steps,
                "fetched": fetched, "degraded": degraded,
                "n_flags": n_flags, "horizons": horizons, "pos_np": pos_np}

    def _macro_complete(self, fl: Dict, sync: bool
                        ) -> Tuple[List[Tuple[int, int]], Optional[Dict]]:
        """Block on an in-flight macro's downloads and run the boundary:
        merge masses, restore device-side row state, append/emit tokens,
        retire finished requests.  ``sync=True`` (the synchronous loop)
        feeds the monitor inline -- tier + tune before the next launch.
        ``sync=False`` (the pipelined loop) instead returns the
        monitor-feed payload for the caller to hand to the
        ``DecisionWorker`` *after* the boundary's remaining manager
        touches (retire/release, activation) are done -- the worker's
        strict-alternation safety window."""
        st, rows, n_steps = fl["st"], fl["rows"], fl["n_steps"]
        toks_np = np.asarray(fl["toks"])
        mass_sum = np.asarray(st["mass_sum"])
        alive_steps = np.asarray(st["alive_steps"])
        stopped = np.asarray(st["stopped"])
        iters_out = np.asarray(st["iters"])
        # the downloads above force the device sync: the stop covers the
        # whole launch + transfer, which is what a straggler would slow
        macro_wall = self.macro_timer.stop(self.step_idx)
        straggler = len(self.macro_timer.stragglers) > fl["n_flags"]

        # ONE merge + monitor feed per movement period (mean mass over
        # the steps each row actually ran, so the per-step scale the
        # access threshold expects is preserved).  dt = the macro's span
        # in token-steps; the mean in-flight count normalises cost per
        # request as on the per-token path.
        merged = self.monitor.merge(
            [(r.table_gids,
              mass_sum[r.row][r.mass_cols]
              / max(1, int(alive_steps[r.row])))
             for _, r in rows])
        dt = max(1, int(alive_steps.max()))
        n_active = float(alive_steps.sum()) / dt
        if (plan := self.fault_plan).enabled \
                and plan.fires("mass.nonfinite") is not None:
            # corrupt the merged telemetry deterministically: the monitor
            # feed's NaN clamp must neutralise it before the reuse
            # collector / tuner see it (the defense this fault exercises)
            merged[::3] = np.nan
            merged[1::5] = np.inf
        payload: Optional[Dict] = None
        if sync:
            self.monitor.on_macro_step(merged, n_active=n_active,
                                       n_tokens=dt, fetched=fl["fetched"],
                                       degraded=fl["degraded"])
        else:
            # boundary snapshots for the worker's plan (apply_plan
            # revalidates against whatever moves before actuation).  The
            # free-slot budget is clamped to the squeezed capacity so a
            # worker-planned bring never overfills the effective pool.
            pools = self.monitor.pools
            n_free = int((pools.page_of_slot < 0).sum())
            n_free = min(n_free, max(0, pools.effective_hbm
                                     - pools.hbm_occupied))
            payload = dict(global_mass=merged, n_active=n_active,
                           n_tokens=dt, fetched=fl["fetched"],
                           degraded=fl["degraded"],
                           resident=pools.slot_of >= 0,
                           n_free=n_free,
                           active=pools.allocated_mask,
                           planes=int(getattr(pools, "move_planes", 2)))

        self.pos = st["pos"]
        self.tok = st["last_tok"]
        emitted: List[Tuple[int, int]] = []
        # resolve lazily-admitted rows' deferred first tokens: the
        # sample fed this macro's scan, so the download is a no-wait
        # read; it precedes the row's macro tokens in the stream
        for row, req in rows:
            if req._first_tok is not None:
                tk = int(req._first_tok[0])
                req._first_tok = None
                req.tokens.append(tk)
                emitted.append((req.rid, tk))
        for t in range(toks_np.shape[0]):
            for row, req in rows:
                tk = int(toks_np[t, row])
                if tk >= 0:
                    req.tokens.append(tk)
                    emitted.append((req.rid, tk))
        for row, req in rows:
            req._key = st["keys"][row]
            req._i = int(iters_out[row])
            if stopped[row]:
                self._retire(req)
        if (r := _obs.RECORDER).enabled:
            r.emit("serve.macro", step=self.step_idx, n_steps=int(n_steps),
                   tokens=len(emitted), active=n_active,
                   fetched=int(fl["fetched"]), wall_ms=macro_wall * 1e3,
                   straggler=straggler)
            r.count("serve.tokens", len(emitted))
        return emitted, payload

    # -- the pipelined macro loop --------------------------------------------
    def _plan_decision(self, payload: Dict):
        """Runs on the DecisionWorker thread.  Strict alternation (the
        dispatch thread only touches the manager/tuner between ``wait``
        and the next ``submit``) makes this lock-free by construction.

        The worker faults (injected delay / crash) fire BEFORE the
        manager/tuner are touched, so a watchdog recovery can recompute
        the boundary synchronously without double-feeding the tuner; the
        live-epoch guard then makes a zombie that wakes *after* a
        recovery publish an inert result instead of racing the dispatch
        thread on shared state."""
        plan = self.fault_plan
        if plan.enabled:
            if (p := plan.fires("worker.delay")) is not None:
                time.sleep(p.value)
            if plan.fires("worker.crash") is not None:
                raise RuntimeError("injected decision-worker crash")
        if payload.get("_epoch", self._live_epoch) != self._live_epoch:
            return self.monitor.manager.period, None
        kw = {k: v for k, v in payload.items() if k != "_epoch"}
        return self.monitor.plan_step(**kw)

    def _worker_recover(self, reason: str):
        """Watchdog recovery: the DecisionWorker hung past the deadline
        or its decision raised.  Walk away from the thread (``abandon``
        for a hang -- joining a wedged thread would stall the loop; a
        clean ``close`` for a crash), bump the live epoch so the zombie
        can never touch shared state, revert the tuner to its last-good
        period (the in-flight sweep's state is unreliable), recompute
        THIS boundary's decision synchronously from the stashed payload,
        and spawn a fresh worker -- unless ``max_worker_restarts`` is
        exhausted, after which the loop stays permanently synchronous
        (degraded mode: correct, just without overlap).  Returns the
        recomputed ``(period, plan)``."""
        self._live_epoch += 1
        w = self._decision_worker
        if reason == "hang":
            w.abandon()
        else:
            w.close(timeout=1.0)
        self._worker_restarts += 1
        self._worker_degraded = self._worker_restarts \
            > self.max_worker_restarts
        self._decision_worker = (None if self._worker_degraded
                                 else DecisionWorker(self._plan_decision))
        if self.monitor.tuner is not None:
            self.monitor.tuner.revert_last_good(
                reason=f"decision-worker-{reason}")
        kw = {k: v for k, v in self._last_payload.items() if k != "_epoch"}
        period, plan = self.monitor.plan_step(**kw)
        if (r := _obs.RECORDER).enabled:
            r.emit("serve.worker_restart", step=self.step_idx,
                   reason=reason, restarts=self._worker_restarts,
                   degraded=self._worker_degraded)
            r.count("serve.worker_restarts")
        return period, plan

    def _step_pipelined(self) -> List[Tuple[int, int]]:
        """One pipelined scheduler step.  Deterministic fixed order:

        1. complete the previous in-flight macro (blocking download,
           token append incl. deferred first tokens, retire) -- the
           worker is idle here, so the retire path's manager/tuner
           touches are safe;
        2. reserve new admissions off the queue (rows/pages held, same
           HBM gate as the synchronous loop);
        3. dispatch the packed prefill for the fresh reservations (an
           async device dispatch -- the host never waits on it);
        4. activate every ready admission LAZILY: the first-token sample
           is pure jnp chained behind its prefill, so a request joins
           the SAME macro its reservation preceded -- no one-macro
           utilisation hole -- and only the int() bookkeeping waits for
           the next boundary (``Request._first_tok``);
        5. launch the next macro over the active set (placement/period
           from the LAST boundary's applied decision -- stale-by-one);
        6. submit the completed macro's masses to the decision worker;
        7. the overlap window: wait+apply the previous decision, advance
           chunked admissions, prefetch the next horizon, stage tables
           -- all behind the scan launched in (5)."""
        fl, self._inflight = self._inflight, None
        emitted: List[Tuple[int, int]] = []
        payload = None
        if fl is not None:
            emitted, payload = self._macro_complete(fl, sync=False)
        self.step_idx += 1
        self._rebalance()
        self._admit_reserve()
        self._admit_prefill_fresh()
        emitted += self._admit_activate()
        if self.active:
            self._inflight = self._macro_launch()
        if payload is not None:
            if self._decision_worker is not None:
                # the payload carries the live epoch (the zombie guard)
                # and is stashed so a watchdog recovery can recompute
                # this boundary synchronously
                payload["_epoch"] = self._live_epoch
                self._last_payload = payload
                self._decision_gen = self._decision_worker.submit(payload)
            else:
                # degraded-permanent mode (restarts exhausted): the
                # boundary decision runs synchronously -- no overlap,
                # same computation
                period, plan = self.monitor.plan_step(
                    **{k: v for k, v in payload.items() if k != "_epoch"})
                self.monitor.apply_decision(plan)
        self._pipeline_overlap()
        return emitted

    def _pipeline_overlap(self) -> None:
        """The overlap window: host-side boundary work dispatched while
        the just-launched scan (if any) runs on device -- every device
        op here consumes the scan's lazy kv outputs and so chains after
        it.  Fixed stage order: the decision apply first (it moves
        placement), chunked-admission progress next, the prefetch last
        (it re-fetches anything the earlier stages evicted), then the
        table staging."""
        track = (r := _obs.RECORDER).enabled
        if self._decision_gen is not None:
            gen, self._decision_gen = self._decision_gen, None
            t0 = time.monotonic()
            try:
                (period, plan), waited = self._decision_worker.wait(
                    gen, timeout=self.watchdog_s)
            except TimeoutError:       # hung worker: watchdog recovery
                period, plan = self._worker_recover("hang")
                waited = time.monotonic() - t0
            except Exception:          # crashed worker
                if self.watchdog_s is None:
                    raise              # no watchdog: fail loud (close()
                                       # still tears down cleanly)
                period, plan = self._worker_recover("crash")
                waited = time.monotonic() - t0
            self.monitor.apply_decision(plan)
            if track:
                r.emit("serve.pipeline.decision", step=self.step_idx,
                       generation=gen, period=int(period),
                       bring=0 if plan is None else int(len(plan[0])),
                       evict=0 if plan is None else int(len(plan[1])),
                       wait_ms=waited * 1e3)
                r.emit("serve.pipeline.stage", step=self.step_idx,
                       stage="decision_wait",
                       wall_ms=(time.monotonic() - t0) * 1e3)
        if any(p.chunked and not p.ready for p in self._pending_admits):
            t0 = time.monotonic()
            self._admit_chunks()
            if track:
                r.emit("serve.pipeline.stage", step=self.step_idx,
                       stage="admit",
                       wall_ms=(time.monotonic() - t0) * 1e3)
        fl = self._inflight
        if fl is None:
            return
        # conservative prefetch for the NEXT macro: through this macro's
        # per-row horizon plus one more macro of the same length, capped
        # by each row's remaining budget.  Opportunistic, not a residency
        # guarantee -- the next launch's ensure_resident still backstops
        # (and if the pending decision changes the period, it picks up
        # the difference there, charged as launch-time fetches).
        t0 = time.monotonic()
        n_next = fl["n_steps"]
        per_row = {row: min(fl["horizons"][row] + n_next,
                            req.max_new_tokens - len(req.tokens))
                   for row, req in fl["rows"]}
        self._prefetched_next += self.monitor.pools.ensure_resident(
            self._need(fl["pos_np"], 0, per_row=per_row))
        if track:
            r.emit("serve.pipeline.stage", step=self.step_idx,
                   stage="prefetch",
                   wall_ms=(time.monotonic() - t0) * 1e3)
        # stage the next boundary's tables: if nothing above re-slotted a
        # page, the next launch's _tables_for is a pure buffer swap
        t0 = time.monotonic()
        self._tables_for([row for row, _ in fl["rows"]])
        if track:
            r.emit("serve.pipeline.stage", step=self.step_idx,
                   stage="tables",
                   wall_ms=(time.monotonic() - t0) * 1e3)

    def _admit_reserve(self) -> None:
        """Pop admittable requests into the pending set: rows and pages
        are reserved NOW (the HBM admission gate counts them, same rule
        as ``_admit``), but the prefill runs inside overlap windows and
        the row only activates at a macro boundary."""
        pools = self.monitor.pools
        self._expire_queue()
        while self.queue and self.rows_free:
            req = self.queue[0]
            n_exact = self._pages_exact(req)
            n_alloc = self._pages_alloc(req)
            if self._hbm_need + n_exact > pools.effective_hbm:
                break              # head-of-line: keep arrival order
            gids = pools.alloc(n_alloc, req.rid)
            if gids is None:       # head-of-line: keep arrival order
                break
            self.queue.popleft()
            row = self.rows_free.pop()
            req.row, req.gids, req.n_pages = row, gids, n_exact
            req.n_alloc = n_alloc
            self._hbm_need += n_exact
            self._map_row(req)
            plen = len(req.prompt)
            # chunking needs prefill_chunk's contract: batched-prefill
            # arch, no shared prefix (chunk-local positions must be
            # absolute), no extra embeds (prefill_chunk takes none)
            chunked = (self._chunk_width is not None
                       and self._batched_prefill and self.prefix == 0
                       and self._ex is None and plen > self._chunk_width)
            self._pending_admits.append(_PendingAdmit(
                req=req, plen=plen, chunked=chunked,
                t_submit=time.monotonic()))
        if (r := _obs.RECORDER).enabled:
            r.gauge("serve.queue_depth", len(self.queue))

    def _admit_prefill_fresh(self) -> None:
        """Boundary-side admission dispatch: one packed prefill over
        every fresh non-chunked reservation, with NO sample sync -- the
        logits stay lazy, so the host moves straight on to the macro
        launch and the scan chains after the prefill's page scatter on
        device (exactly the ordering the synchronous loop gets, minus
        the host stall)."""
        fresh = [p for p in self._pending_admits
                 if not p.ready and not p.chunked and p.logits is None]
        if not fresh:
            return
        if self._batched_prefill:
            self._dispatch_packed_prefill(fresh)
        else:                   # recurrent state: one request at a time
            for p in fresh:
                prompt = jnp.asarray(p.req.prompt, jnp.int32)[None]
                logits, cache1 = mdl.prefill(
                    self.params, self.cfg, prompt, cond=self._cond,
                    extra_embeds=self._ex)
                self._write_prefill_pages_row(cache1, p.req, p.plen)
                p.logits = logits
                p.ready = True

    def _admit_chunks(self) -> None:
        """Overlap-window admission work: ONE bounded chunk per chunked
        admission, dispatched behind the in-flight scan so long-prompt
        prefill never delays a launch.  ``admit_chunk_tokens`` is the
        SLO knob: it caps how much prefill compute any single window
        puts in front of the next boundary, trading admission latency
        for boundary stall."""
        for p in self._pending_admits:
            if p.chunked and not p.ready:
                self._dispatch_chunk(p)

    def _dispatch_packed_prefill(self, pending: List[_PendingAdmit]
                                 ) -> None:
        """Dispatch one packed prefill for a window's non-chunked pending
        admissions -- the same pow2-bucketed pass as ``_prefill``, minus
        the sampling sync (the lazy logits ride in the pending record
        until the boundary)."""
        plens = [p.plen for p in pending]
        smax = bucket_pages(max(plens))
        jp = bucket_pages(len(pending))
        toks = np.zeros((jp, smax), np.int32)
        plens_p = np.ones((jp,), np.int32)
        for i, p in enumerate(pending):
            toks[i, : plens[i]] = p.req.prompt
            plens_p[i] = self.prefix + plens[i]
        kw = {}
        if self._cond is not None:
            kw["cond"] = jnp.broadcast_to(
                self._cond, (jp,) + self._cond.shape[1:])
        if self._ex is not None:
            kw["extra_embeds"] = jnp.broadcast_to(
                self._ex, (jp,) + self._ex.shape[1:])
        logits_b, cache_b = self._prefill_fn(
            jnp.asarray(toks), jnp.asarray(plens_p), **kw)
        self._write_prefill_pages_batched(cache_b,
                                          [p.req for p in pending], plens)
        for i, p in enumerate(pending):
            p.logits = logits_b[i: i + 1]
            p.ready = True

    def _chunk_fn(self, start: int) -> Callable:
        """Jitted ``prefill_chunk`` per (static) chunk start; the
        compile cache is bounded by ``max_len / chunk_width``."""
        fn = self._chunk_fns.get(start)
        if fn is None:
            fn = jax.jit(functools.partial(mdl.prefill_chunk, self.params,
                                           self.cfg, start=start))
            self._chunk_fns[start] = fn
        return fn

    def _dispatch_chunk(self, p: _PendingAdmit) -> None:
        """Dispatch ONE bounded chunk of a long-prompt admission: a
        width-``_chunk_width`` slice of the prompt forward-passed against
        the accumulated past, its pages scattered into the pool, the
        past extended -- all lazy, queueing behind the in-flight scan.
        The chunk containing the prompt's final position contributes the
        first-token logits; the last chunk marks the admission ready."""
        t0 = time.monotonic()
        c = self._chunk_width
        lo = p.next_start
        w = min(c, p.plen - lo)
        toks = np.zeros((1, c), np.int32)
        toks[0, :w] = p.req.prompt[lo: lo + w]
        kw = {}
        if self._cond is not None:
            kw["cond"] = self._cond
        logits, cc = self._chunk_fn(lo)(
            jnp.asarray(toks), jnp.asarray([p.plen], jnp.int32), p.past,
            **kw)
        self._write_chunk_pages(p.req, cc, lo, p.plen)
        if lo <= p.plen - 1 < lo + c:
            p.logits = logits
        p.next_start = lo + c
        p.chunk_idx += 1
        done = p.next_start >= p.plen
        p.past = None if done else mdl.chunk_past_extend(p.past, cc)
        if done:
            p.ready = True
        if (r := _obs.RECORDER).enabled:
            r.emit("serve.pipeline.admit_chunk", step=self.step_idx,
                   rid=p.req.rid, chunk=p.chunk_idx - 1, tokens=int(w),
                   total=p.plen, wall_ms=(time.monotonic() - t0) * 1e3,
                   done=done)

    def _write_chunk_pages(self, req: Request, cache_chunk, lo: int,
                           plen: int) -> None:
        """Scatter one admission chunk's cache into the request's pages.
        Chunk starts and widths are page-aligned (the constructor rounds
        ``admit_chunk_tokens`` up), so every page is written by exactly
        one chunk; the final page's tail beyond ``plen`` carries padding
        garbage masked attention never reads (as in the packed
        scatter).  Chunked admission is gated to prefix-free configs, so
        chunk-local positions ARE absolute cache positions."""
        pools = self.monitor.pools
        ps = self.page_size
        npg = self._chunk_width // ps
        p0 = lo // ps
        n_valid = min(npg, -(-(plen - lo) // ps))
        gids_m = np.full((1, npg), PAGE_DROP, np.int32)
        gids_m[0, :n_valid] = req.gids[p0: p0 + n_valid]
        slots = pools.assign_slots(req.gids[p0: p0 + n_valid])
        slots_m = np.full((1, npg), PAGE_DROP, np.int32)
        slots_m[0, :n_valid] = slots
        leaves = self._prefill_leaves(cache_chunk,
                                      mdl.state_slot_meta(self.cfg), 0)
        pools.set_kv(write_pages_batched(
            pools.kv_view(), leaves, jnp.asarray(gids_m),
            jnp.asarray(slots_m)))

    def _admit_activate(self) -> List[Tuple[int, int]]:
        """Boundary half of pipelined admission: install every ready
        pending request's row WITHOUT forcing its first token.  The
        sample is pure jnp chained behind the request's prefill, so
        setting it into ``self.tok`` keeps the whole admission lazy and
        the row joins the macro launched later this same step; the
        int() download / tokens append / emit wait for the next
        boundary (``_macro_complete`` resolves ``req._first_tok``), and
        the device scan's init-time stop check covers a first token
        that already hits EOS or the budget.  MUST run after the
        boundary restored ``tok``/``pos`` from the macro's downloaded
        state, or the whole-array assignment would clobber fresh rows."""
        ready = [p for p in self._pending_admits if p.ready]
        if not ready:
            return []
        self._pending_admits = [p for p in self._pending_admits
                                if not p.ready]
        t0 = time.monotonic()
        emitted: List[Tuple[int, int]] = []
        for p in ready:
            req = p.req
            req._key = (req.key if req.key is not None
                        else jax.random.PRNGKey(0))
            tok = E._sample(p.logits[:, 0], req._key, req.temperature)
            self.tok = self.tok.at[req.row].set(tok)
            self.pos = self.pos.at[req.row].set(self.prefix + p.plen)
            self.active[req.row] = req
            self._rows_epoch += 1
            p.logits = None
            if req.max_new_tokens <= 1:
                # the row would only freeze at the scan's init check;
                # cheaper to force the (long-dispatched) sample here and
                # retire without ever joining a macro -- exactly the
                # synchronous admission path for a one-token request
                req.tokens.append(int(tok[0]))
                emitted.append((req.rid, req.tokens[-1]))
                self._retire(req)
            else:
                req._first_tok = tok
        if (r := _obs.RECORDER).enabled:
            now = time.monotonic()
            r.emit("serve.admit", step=self.step_idx, joiners=len(ready),
                   pages=int(sum(p.req.n_alloc for p in ready)),
                   queue_depth=len(self.queue),
                   wall_ms=(now - t0) * 1e3,
                   # the batch's WORST reservation-to-activation stall:
                   # the admission-latency price of deferring the sample
                   # sync to a boundary (what admit_chunk_tokens trades
                   # boundary stall against)
                   stall_ms=(now - min(p.t_submit for p in ready)) * 1e3)
            r.count("serve.admitted", len(ready))
            r.gauge("serve.queue_depth", len(self.queue))
        return emitted

    @property
    def idle(self) -> bool:
        """No work left: nothing queued, in flight, pending admission or
        active.  Drive loops (run(), benchmarks, tests) step until this
        holds -- the pipelined loop keeps tail state (an in-flight macro,
        reserved-but-not-activated admissions) past the last queue/active
        emptiness, so checking those two alone would under-drain it."""
        return not (self.queue or self.active or self._pending_admits
                    or self._frozen or self._inflight is not None)

    def run(self, max_steps: int = 10 ** 6) -> Dict[int, List[int]]:
        """Drive until every submitted request completed (or the step
        budget runs out).  Returns rid -> emitted tokens.  The pipelined
        loop additionally drains its in-flight macro and any pending
        (reserved-but-not-activated) admissions: every step ends with the
        decision worker idle, so post-run manager/tuner state is as
        deterministic as the synchronous loop's."""
        steps = 0
        while not self.idle and steps < max_steps:
            self.step()
            steps += 1
        return {r.rid: list(r.tokens) for r in self.completed}

    def close(self) -> None:
        """Tear down the pipelined loop's background decision worker
        (no-op for the synchronous loop).  Call after the last step;
        tests and benchmarks use it to avoid thread buildup.  Safe
        mid-macro and after a worker error: a pending decision
        generation is dropped (never waited on again), and the worker's
        drain-and-join runs even if its last ``fn`` raised -- the error
        stays published in the dead worker, not re-raised here."""
        self._decision_gen = None
        if self._decision_worker is not None:
            self._decision_worker.close()
            self._decision_worker = None

    def _retire(self, req: Request) -> None:
        req.done = True
        req.status = "completed"
        del self.active[req.row]
        self.rows_free.append(req.row)
        self.completed.append(req)
        if self.paged:
            self._hbm_need -= req.n_pages
            self._gid_tables[req.row, :] = -1
            self._rows_epoch += 1
        if self.monitor is not None:
            self.monitor.release(req.gids)
        if (r := _obs.RECORDER).enabled:
            r.emit("serve.retire", step=self.step_idx, rid=req.rid,
                   tokens=len(req.tokens), status=req.status,
                   deadline_ms=(time.monotonic() - req._t_submit) * 1e3
                   if req._t_submit else 0.0)
            r.count("serve.retired")

    # -- shared-pool data path -----------------------------------------------
    def _mirror(self, req: Request, pages) -> None:
        """Write-through the monitor layer's KV pages of one request from
        the packed cache into the shared pools (host + resident slots)."""
        c = self.cache["segments"][self._si][self._sj]
        ps = self.page_size
        for p in pages:
            if 0 <= p < req.n_pages:
                # slice on device: only the touched page crosses to host
                k = c["k"][-1, req.row, p * ps: (p + 1) * ps]
                v = c["v"][-1, req.row, p * ps: (p + 1) * ps]
                self.monitor.pools.write_page(int(req.gids[p]), k, v)

    def paged_context(self, rid: int, q, *, impl: str = "interpret"):
        """Monitor-layer attention context for one in-flight request,
        gathered by ``kernels.paged_attention`` *from the shared HBM pool*
        through the request's page table (``slot_of`` indirection).  Pages
        are demand-fetched first; returns (context [1,H,D], fetched).

        In fully-paged mode the pool IS the KV store, so this reads the
        monitor slot's layered HBM leaf; in dense mode it needs the
        ``mirror_pages`` write-through."""
        if not (self.paged or self.mirror_pages):
            raise ValueError("paged_context needs fully-paged decode or "
                             "mirror_pages=True over physical pools: "
                             "otherwise the shared pool holds no KV data")
        if self._si is None:
            raise ValueError(f"{self.cfg.name}: no full-attention layer "
                             "to probe with paged_context")
        req = next((r for r in self.active.values() if r.rid == rid), None)
        if req is None:
            raise KeyError(f"request {rid} is not in flight")
        length = int(np.asarray(self.pos)[req.row])
        n = -(-length // self.page_size)
        # paged mode: pages covering positions [0, length) in table order
        # (shared prefix first); dense-mirror mode: the request's own run
        gids = req.table_gids[:n] if self.paged else req.gids[:n]
        pools = self.monitor.pools
        fetched = pools.ensure_resident(gids)
        # demand-fetched pages are on-demand host reads: charge them
        mgr = self.monitor.manager
        mgr.misses += fetched
        mgr.modeled_time += fetched * mgr.cfg.miss_penalty
        table = jnp.asarray(pools.table(gids), jnp.int32)[None]
        lengths = jnp.asarray([length], jnp.int32)
        if self.paged:
            li = mdl.attn_slot_index(self.cfg, self._si, self._sj)
            k_hbm = pools.kv_layers["k_hbm"][li][-1]
            v_hbm = pools.kv_layers["v_hbm"][li][-1]
        else:
            k_hbm, v_hbm = pools.k_hbm, pools.v_hbm
        out = ops.paged_attention(q, k_hbm, v_hbm, table, lengths, impl=impl)
        return out, fetched


# ---------------------------------------------------------------------------
# model-free traffic simulation (same scheduling core, synthetic masses)
# ---------------------------------------------------------------------------


def _sink_pattern(spec: RequestSpec, n_pages: int) -> np.ndarray:
    return W.attention_sink(spec.new_tokens, n_pages,
                            sink_pages=min(2, n_pages),
                            window_pages=min(4, n_pages),
                            seed=spec.seed, drift_every=1)


def _periodic_pattern(spec: RequestSpec, n_pages: int) -> np.ndarray:
    span = max(1, min(8, n_pages - n_pages // 4))
    return W.periodic_context(spec.new_tokens, n_pages, span_pages=span,
                              period=16, seed=spec.seed)


def _random_pattern(spec: RequestSpec, n_pages: int) -> np.ndarray:
    return W.random_lookup(spec.new_tokens, n_pages,
                           touches=min(3, n_pages), seed=spec.seed)


WORKLOAD_KINDS: Dict[str, Callable[[RequestSpec, int], np.ndarray]] = {
    "sink": _sink_pattern,
    "periodic": _periodic_pattern,
    "random": _random_pattern,
}


@dataclasses.dataclass
class _SynthActive:
    spec: RequestSpec
    gids: np.ndarray
    pattern: np.ndarray                # [lifetime, n_pages]
    t: int = 0


class TrafficScheduler:
    """Model-free continuous batching over a ``core.traffic`` request
    stream: admission (Poisson arrivals, FIFO head-of-line), bucket-
    rounded page-aligned allocation from the shared pool, per-step mass
    merge through the ``TrafficMonitor``, retirement on length.
    Deterministic given the stream -- and admission never depends on
    residency or period, so fixed-period replays of the same stream are
    directly comparable (the brute-force sweep the benchmark ranks the
    online tuner against).

    Allocation mirrors the fully-paged batcher: a request holds
    ``bucket_pages(exact, cap=row_pages)`` global pages (its mass pattern
    only ever touches the exact footprint; the bucket tail is allocation
    slack).  ``row_pages`` defaults to the dense provisioning a packed
    ``max_len`` cache would need for this stream -- the longest request's
    page count -- so ``dense_cache_pages`` is the apples-to-apples
    baseline ``peak_cache_pages`` is compared against."""

    def __init__(self, specs: Sequence[RequestSpec], monitor: TrafficMonitor,
                 *, page_size: int = 16, max_active: int = 8,
                 kinds: Optional[Dict[str, Callable]] = None,
                 bucket: bool = True, row_pages: Optional[int] = None,
                 ttl_steps: Optional[int] = None):
        self.pending = collections.deque(
            sorted(specs, key=lambda s: (s.arrival, s.rid)))
        self.monitor = monitor
        self.page_size = page_size
        self.max_active = max_active
        self.kinds = dict(WORKLOAD_KINDS)
        if kinds:
            self.kinds.update(kinds)
        self.bucket = bucket
        self.row_pages = row_pages if row_pages is not None else max(
            (s.n_pages(page_size) for s in specs), default=1)
        #: admission TTL in steps past arrival: a request still queued
        #: ``ttl_steps`` after it arrived is shed (status "expired")
        #: instead of serving stale work under overload; None = FIFO
        #: forever (the fault-free baseline)
        self.ttl_steps = ttl_steps
        self.active: List[_SynthActive] = []
        self.now = 0
        self.admitted = 0
        self.completed = 0
        self.rejected = 0
        self.shed = 0

    @property
    def peak_cache_pages(self) -> int:
        """Peak pages simultaneously allocated (bucket-rounded rows)."""
        return self.monitor.pools.peak_allocated

    @property
    def dense_cache_pages(self) -> int:
        """What the dense packed-cache layout provisions up front:
        ``max_active`` rows of ``row_pages`` each, held for the whole
        run regardless of occupancy."""
        return self.max_active * self.row_pages

    def _pages_alloc(self, n_exact: int) -> int:
        if not self.bucket:
            return n_exact
        return bucket_pages(n_exact, cap=max(self.row_pages, n_exact))

    def step(self) -> None:
        if self.ttl_steps is not None:
            # expiry order is arrival order (the deque is arrival-sorted
            # and the TTL is uniform), so a head scan sheds exactly the
            # expired prefix
            while (self.pending
                   and self.now > self.pending[0].arrival + self.ttl_steps):
                spec = self.pending.popleft()
                self.rejected += 1
                self.shed += 1
                if (r := _obs.RECORDER).enabled:
                    r.emit("serve.shed", step=self.now, rid=spec.rid,
                           reason="deadline", queue_depth=len(self.pending))
                    r.emit("serve.retire", step=self.now, rid=spec.rid,
                           tokens=0, status="expired", deadline_ms=0.0)
                    r.count("serve.shed_total")
                    r.count("serve.retired")
        joiners = pages = 0
        while (self.pending and self.pending[0].arrival <= self.now
               and len(self.active) < self.max_active):
            spec = self.pending[0]
            n_pages = spec.n_pages(self.page_size)
            n_alloc = self._pages_alloc(n_pages)
            if n_alloc > self.monitor.pools.n_logical:
                # can never fit, not even fully drained: dropping it is the
                # only alternative to blocking the queue forever
                self.pending.popleft()
                self.rejected += 1
                continue
            gids = self.monitor.pools.alloc(n_alloc, spec.rid)
            if gids is None:           # head-of-line: keep arrival order
                break
            self.pending.popleft()
            pattern = self.kinds[spec.kind](spec, n_pages)
            self.admitted += 1
            joiners += 1
            pages += n_alloc
            if pattern.shape[0] == 0:      # zero-lifetime: retire at once
                self.monitor.release(gids)
                self.completed += 1
                continue
            self.active.append(_SynthActive(spec, gids, pattern))
        if joiners and (r := _obs.RECORDER).enabled:
            r.emit("serve.admit", step=self.now, joiners=joiners,
                   pages=pages, queue_depth=len(self.pending), wall_ms=0.0)
            r.count("serve.admitted", joiners)
            r.gauge("serve.queue_depth", len(self.pending))

        # idle steps are not fed to the monitor (matching the model-backed
        # batcher): an empty lull's near-zero cost would read as a phase
        # change and churn the tuner through spurious re-profiles
        if self.active:
            # mass patterns span the exact footprint only; a bucket's
            # tail pages are allocation slack and never accrue mass
            merged = self.monitor.merge(
                [(a.gids[: a.pattern.shape[1]], a.pattern[a.t])
                 for a in self.active])
            self.monitor.on_step(merged, n_active=len(self.active))
        self.now += 1

        still: List[_SynthActive] = []
        for a in self.active:
            a.t += 1
            if a.t >= a.pattern.shape[0]:
                self.monitor.release(a.gids)
                self.completed += 1
                if (r := _obs.RECORDER).enabled:
                    r.emit("serve.retire", step=self.now, rid=a.spec.rid,
                           tokens=int(a.pattern.shape[0]),
                           status="completed", deadline_ms=0.0)
                    r.count("serve.retired")
            else:
                still.append(a)
        self.active = still

    def run(self, steps: int) -> "TrafficScheduler":
        for _ in range(steps):
            self.step()
        return self
