"""Replay a flight-recorder JSONL log into a human-readable decision trace.

    PYTHONPATH=src python -m repro.obs.report LOG.jsonl [--perfetto OUT.json]
                                              [--all] [--limit N]

Prints, from the event log alone (no live process needed):

  * the tuner decision trace -- every PROFILE/TRIAL/HOLD transition with
    its reason, every trial result, guard trip (burst vs regime verdict,
    CV, the attested reference it tripped against), window extension,
    baseline attestation and revert;
  * with ``--all``, the serving/tiering lines interleaved (admissions,
    macro launches, stragglers, tier boundaries);
  * the metrics summary table (counters, gauges, histogram quantiles)
    from the log's closing ``metrics.summary`` record.

``--perfetto OUT.json`` additionally converts the log into a Chrome/
Perfetto ``trace_event`` file (load it at https://ui.perfetto.dev).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional

from repro.obs import export

__all__ = ["decision_trace", "metrics_table", "main"]


def _fmt(v, nd: int = 3) -> str:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return str(v)
    if isinstance(v, int):
        return str(v)
    if v != v:                     # NaN
        return "nan"
    if abs(v) >= 1e5 or (v != 0 and abs(v) < 1e-3):
        return f"{v:.2e}"
    return f"{v:.{nd}f}"


def _line_tuner(ev: dict) -> Optional[str]:
    typ, step = ev["type"], ev.get("step", "?")
    who = ev.get("tuner", "?")
    head = f"step {step:>7}  [{who}] "
    if typ == "tuner.transition":
        s = (head + f"{ev['frm'].upper()} -> {ev['to'].upper()} "
             f"[{ev['reason']}]  period={ev.get('period')}")
        if ev.get("detail"):
            s += f"  ({ev['detail']})"
        return s
    if typ == "tuner.trial":
        mark = "*" if ev.get("improved") else " "
        return (head + f"TRIAL p={ev['period']:<5} cost/step="
                f"{_fmt(ev['cost'])} {mark} best=(p={ev['best_period']}, "
                f"{_fmt(ev['best_cost'])}) stale={ev['stale']}")
    if typ == "tuner.guard":
        ratio = (ev["cost"] / ev["ref"] if ev.get("ref") else float("nan"))
        return (head + f"GUARD[{ev['where']}] {ratio:.1f}x attested "
                f"({_fmt(ev['cost'])} vs {_fmt(ev['ref'])}), bucket CV "
                f"{_fmt(ev.get('cv'), 2)} => {ev['verdict']}")
    if typ == "tuner.extend":
        return (head + f"TRIAL window extended -> {ev['win_target']} steps "
                f"(bucket CV {_fmt(ev.get('cv'), 2)})")
    if typ == "tuner.baseline":
        floor = " (floored by sweep winner)" if ev.get("floored") else ""
        return head + f"HOLD baseline attested: {_fmt(ev['cost'])}{floor}"
    if typ == "tuner.hold_window":
        if ev.get("kind") == "ok":
            return None            # the quiet steady state: keep the trace
        return (head + f"HOLD window: {ev['kind']} "          # readable
                f"(cost {_fmt(ev.get('cost'))}, baseline "
                f"{_fmt(ev.get('baseline'))}, strikes {ev.get('strikes')})")
    if typ == "tuner.period":
        return (head + f"period {ev.get('prev')} -> {ev['period']}")
    if typ == "tuner.profile_extend":
        return head + "PROFILE window empty: extending"
    return None


def _line_other(ev: dict) -> Optional[str]:
    typ = ev["type"]
    if typ == "tier.move":
        return (f"step {ev.get('step', '?'):>7}  [{ev.get('manager', '?')}] "
                f"tier: +{ev['promoted']} pages / -{ev['evicted']} evicted "
                f"(p={ev['period']}, {ev['pages_moved']} pages moved)")
    if typ == "serve.admit":
        return (f"t {ev['t']:10.3f}s  admit x{ev['joiners']} "
                f"({ev['pages']} pages, queue {ev['queue_depth']}, "
                f"{_fmt(ev.get('wall_ms'), 2)} ms)")
    if typ == "serve.macro":
        flag = "  ** straggler" if ev.get("straggler") else ""
        return (f"t {ev['t']:10.3f}s  macro x{ev['n_steps']}: "
                f"{ev['tokens']} tokens, active {_fmt(ev['active'], 1)}, "
                f"fetched {ev['fetched']}, {_fmt(ev['wall_ms'], 2)} ms{flag}")
    if typ == "serve.retire":
        return (f"t {ev['t']:10.3f}s  retire rid={ev['rid']} "
                f"({ev['tokens']} tokens)")
    if typ == "ft.straggler":
        return (f"t {ev['t']:10.3f}s  STRAGGLER [{ev['timer']}] step "
                f"{ev['step']}: {_fmt(ev['dt_s'])}s vs EMA "
                f"{_fmt(ev['ema_s'])}s")
    if typ == "serve.stream":
        return (f"t {ev['t']:10.3f}s  stream {ev['phase']} "
                f"({ev.get('tokens')} tokens)")
    return None


def decision_trace(events: List[dict], include_all: bool = False
                   ) -> List[str]:
    """Render the event stream as decision-trace lines (tuner-only by
    default; ``include_all`` interleaves serving/tiering lines)."""
    lines = []
    for ev in events:
        typ = ev.get("type", "")
        if typ == "metrics.summary":
            continue
        line = _line_tuner(ev) if typ.startswith("tuner.") else (
            _line_other(ev) if include_all else None)
        if line:
            lines.append(line)
    return lines


def metrics_table(summary: dict) -> List[str]:
    lines = ["", "== metrics =="]
    if summary.get("counters"):
        lines.append("counters:")
        for k, v in summary["counters"].items():
            lines.append(f"  {k:<34} {_fmt(v)}")
    if summary.get("gauges"):
        lines.append("gauges:")
        for k, v in summary["gauges"].items():
            lines.append(f"  {k:<34} {_fmt(v)}")
    if summary.get("hists"):
        lines.append(f"{'histogram':<34} {'count':>8} {'mean':>10} "
                     f"{'p50':>10} {'p95':>10} {'max':>10}")
        for k, h in summary["hists"].items():
            if not h.get("count"):
                continue
            lines.append(f"  {k:<32} {h['count']:>8} {_fmt(h['mean']):>10} "
                         f"{_fmt(h['p50']):>10} {_fmt(h['p95']):>10} "
                         f"{_fmt(h['max']):>10}")
    if "events_dropped" in summary and summary["events_dropped"]:
        lines.append(f"  (ring dropped {summary['events_dropped']} oldest "
                     "events)")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="replay a flight-recorder JSONL log")
    ap.add_argument("log", help="JSONL event log (obs.export.write_jsonl)")
    ap.add_argument("--perfetto", metavar="OUT.json",
                    help="also write a Perfetto trace_event file")
    ap.add_argument("--all", action="store_true",
                    help="interleave serving/tiering lines with the tuner "
                         "decision trace")
    ap.add_argument("--limit", type=int, default=None,
                    help="print only the last N trace lines")
    args = ap.parse_args(argv)

    events = export.read_jsonl(args.log)
    lines = decision_trace(events, include_all=args.all)
    if args.limit is not None:
        lines = lines[-args.limit:]
    print(f"== decision trace ({len(lines)} lines) ==")
    for line in lines:
        print(line)

    summary: Dict = next((e for e in events
                          if e.get("type") == "metrics.summary"), {})
    for line in metrics_table(summary):
        print(line)

    if args.perfetto:
        p = export.write_perfetto(args.perfetto, events)
        print(f"\nperfetto trace -> {p} (open at https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
