"""Launch-layer units: HLO collective parser, mesh builders, input specs."""
import jax
import numpy as np
import pytest

import repro.configs as C


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = f32[16,1152]{1,0} all-gather(%x), replica_groups=...
  %ar.1 = bf16[8,256,4608]{2,1,0} all-reduce(%y), to_apply=%add
  %rs = (f32[4,4]{1,0}, f32[2,2]{1,0}) reduce-scatter(%a, %b), dims={0}
  %ag2 = f32[32]{0} all-gather-start(%z), dims={0}
  %done = f32[32]{0} all-gather-done(%ag2)
  %cp = u8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %a2a = s32[64,2]{1,0} all-to-all(%v), dimensions={0}
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 16 * 1152 * 4 + 32 * 4
    assert got["all-reduce"] == 8 * 256 * 4608 * 2
    assert got["reduce-scatter"] == 16 * 4 + 4 * 4
    assert got["collective-permute"] == 128
    assert got["all-to-all"] == 64 * 2 * 4


def test_input_specs_all_cells():
    """batch_specs/decode_specs build for every assignment cell without
    allocation and with assignment-correct shapes."""
    from repro.launch import specs as SP
    for arch, shape in C.cells():
        c = SP.cell(arch, shape)
        if c.step_kind in ("train", "prefill"):
            b = SP.batch_specs(c)
            tot = b["tokens"].shape[1] + (c.cfg.prefix_len or 0)
            assert b["tokens"].shape[0] == c.global_batch
            assert tot == c.seq_len
        else:
            d = SP.decode_specs(c)
            assert d["tokens"].shape == (c.global_batch, 1)
            # cache capacity == seq_len for full-attention slots
            leaves = jax.tree.leaves(d["cache"])
            assert all(x.shape[1] == c.global_batch for x in leaves)


def test_cell_table_is_the_assignment():
    cells = C.cells(include_skipped=True)
    assert len(cells) == len(C.ARCHS) * len(C.SHAPES)
    skipped = {(a, s) for a, s, sk in cells if sk}
    assert all(s == "long_500k" for _, s in skipped)
    # one skipped long_500k cell per arch lacking long-context support
    assert len(skipped) == sum(not C.get(a).supports_long_context
                               for a in C.ARCHS)


def test_host_mesh_shapes():
    from repro.launch.mesh import make_host_mesh
    m = make_host_mesh(data=1, model=1)
    assert m.axis_names == ("data", "model")


def test_train_overrides_cover_heavy_archs():
    from repro.launch.dryrun import TRAIN_OVERRIDES
    assert TRAIN_OVERRIDES["nemotron-4-340b"]["state_dtype"] == "bfloat16"
    assert TRAIN_OVERRIDES["deepseek-v3-671b"]["accum"] >= 4
