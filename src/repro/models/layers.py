"""Core layers: norms, rotary, MLPs, GQA / local / cross attention, MLA.

Every ``*_init`` returns ``(params, specs)`` where ``specs`` mirrors the
param tree with tuples of *logical* axis names (resolved to mesh axes by
``repro.distributed.sharding``).  Every ``*_apply`` is a pure function.

The attention reference implementation chunks over queries (``lax.map``)
so the score matrix never materialises at [S, S] -- the memory profile the
dry-run reports is the deployable one.  The Pallas flash kernel
(``repro.kernels.flash_attention``) is the TPU fast path; numerics match.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import MLAConfig, ModelConfig

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, axes, scale=None, dtype=jnp.float32):
    """He-style init; returns (param, spec)."""
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype) * scale, axes)


def split_tree(tree):
    """Split a tree of (param, spec) leaves into (params, specs)."""
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and hasattr(
        x[0], "shape")
    params = jax.tree.map(lambda x: x[0], tree, is_leaf=is_leaf)
    specs = jax.tree.map(lambda x: x[1], tree, is_leaf=is_leaf)
    return params, specs


# ---------------------------------------------------------------------------
# norms / rotary
# ---------------------------------------------------------------------------


def rms_norm_init(d):
    return (jnp.ones((d,), jnp.float32), ("embed",))


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def rope(x, positions, theta: float):
    """Apply rotary embedding.  x: [..., S, H, D], positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        tree = {
            "wi_gate": _dense_init(ks[0], (d, ff), ("embed", "mlp")),
            "wi_up": _dense_init(ks[1], (d, ff), ("embed", "mlp")),
            "wo": _dense_init(ks[2], (ff, d), ("mlp", "embed")),
        }
    else:  # squared_relu | gelu
        tree = {
            "wi": _dense_init(ks[0], (d, ff), ("embed", "mlp")),
            "wo": _dense_init(ks[1], (ff, d), ("mlp", "embed")),
        }
    return split_tree(tree)


def mlp_apply(p: Params, cfg: ModelConfig, x):
    if cfg.mlp_kind == "swiglu":
        h = (jax.nn.silu(x @ p["wi_gate"].astype(x.dtype))
             * (x @ p["wi_up"].astype(x.dtype)))
        return h @ p["wo"].astype(x.dtype)
    # NB: weights must be cast to the activation dtype -- bf16 @ f32
    # silently promotes the whole residual stream to f32 (2x activation
    # memory + 2x collective volume; EXPERIMENTS.md SPerf iteration 5).
    h = x @ p["wi"].astype(x.dtype)
    if cfg.mlp_kind == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# attention masks
# ---------------------------------------------------------------------------


def causal_mask(q_pos, k_pos, window: int = 0, prefix_len: int = 0):
    """Boolean [.., Q, K] mask.  window>0 -> sliding window; prefix_len>0 ->
    bidirectional prefix (PaliGemma image tokens)."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    if prefix_len > 0:
        m |= (k_pos[..., None, :] < prefix_len)
    return m


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ModelConfig, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kd = cfg.cond_dim or d if cross else d
    ks = jax.random.split(key, 6)
    tree = {
        "wq": _dense_init(ks[0], (d, h, hd), ("embed", "heads", "head_dim")),
        "wk": _dense_init(ks[1], (kd, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": _dense_init(ks[2], (kd, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": _dense_init(ks[3], (h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        tree["q_norm"] = (jnp.ones((hd,), jnp.float32), ("head_dim",))
        tree["k_norm"] = (jnp.ones((hd,), jnp.float32), ("head_dim",))
    return split_tree(tree)


def _sdpa_chunked(q, k, v, mask, softcap: float, q_chunk: int = 512):
    """Softmax attention, chunked over queries.  q: [B,S,H,D], k/v:
    [B,T,KV,D], mask: [B,S,T] or [S,T] broadcastable bool."""
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = 1.0 / np.sqrt(d)
    kr = jnp.repeat(k, rep, axis=2)  # [B,T,H,D]
    vr = jnp.repeat(v, rep, axis=2)

    n_chunks = max(1, s // q_chunk) if s % q_chunk == 0 else 1
    if s % q_chunk != 0 or s <= q_chunk:
        n_chunks, q_chunk_eff = 1, s
    else:
        q_chunk_eff = q_chunk

    def one_chunk(args):
        qc, mc = args  # [B,C,H,D], [B,C,T]
        logits = jnp.einsum("bchd,bthd->bhct", qc, kr,
                            preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        logits = jnp.where(mc[:, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(qc.dtype)
        return jnp.einsum("bhct,bthd->bchd", w, vr)

    if n_chunks == 1:
        m = jnp.broadcast_to(mask, (b, s, t))
        return one_chunk((q, m))
    qs = q.reshape(b, n_chunks, q_chunk_eff, h, d).transpose(1, 0, 2, 3, 4)
    ms = jnp.broadcast_to(mask, (b, s, t)).reshape(
        b, n_chunks, q_chunk_eff, t).transpose(1, 0, 2, 3)
    out = jax.lax.map(one_chunk, (qs, ms))
    # NB: output head dim is v's, not q's -- MLA has d_v != d_qk.
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, v.shape[-1])


def attention_apply(p: Params, cfg: ModelConfig, x, kv_x, positions, mask,
                    *, kv_positions=None, use_rope=True, past=None):
    """Full attention (training/prefill).  Returns (out, (k, v)).

    ``past`` -- optional ``(past_k, past_v)`` of already-processed prefix
    tokens (post-qk-norm, post-rope: exactly the cache entries a previous
    chunk returned), each [B, P, KV, D].  The chunk attends over
    ``past ++ own`` keys; ``mask`` must then cover [.., S, P+S] (build it
    from the concatenated key positions).  The returned cache entries are
    the OWN chunk's only -- the caller threads the accumulation."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if use_rope:
        kv_pos = positions if kv_positions is None else kv_positions
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    k_all, v_all = k, v
    if past is not None:
        past_k, past_v = past
        k_all = jnp.concatenate([past_k.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([past_v.astype(v.dtype), v], axis=1)
    out = _sdpa_chunked(q, k_all, v_all, mask, cfg.softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (k, v)


def attention_decode(p: Params, cfg: ModelConfig, x, cache_k, cache_v,
                     cache_pos, cur_pos, *, window: int = 0):
    """One-token decode.  x: [B,1,d]; cache_k/v: [B,T,KV,D]; cache_pos:
    [B,T] absolute positions (-1 == empty); cur_pos: [B] int32.
    Returns (out, new_k_entry, new_v_entry)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k_new = rms_norm(k_new, p["k_norm"])
    q = rope(q, cur_pos[:, None], cfg.rope_theta)
    k_new = rope(k_new, cur_pos[:, None], cfg.rope_theta)

    # attend over cache plus the new entry
    k_all = jnp.concatenate([cache_k, k_new], axis=1).astype(x.dtype)
    v_all = jnp.concatenate([cache_v, v_new], axis=1).astype(x.dtype)
    pos_all = jnp.concatenate([cache_pos, cur_pos[:, None]], axis=1)
    valid = pos_all >= 0
    m = (pos_all <= cur_pos[:, None]) & valid
    if window > 0:
        m &= pos_all > (cur_pos[:, None] - window)
    out = _sdpa_chunked(q, k_all, v_all, m[:, None, :], cfg.softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, k_new, v_new


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 Multi-head Latent Attention)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    m: MLAConfig = cfg.mla
    ks = jax.random.split(key, 8)
    qk = m.qk_nope_dim
    tree = {
        "w_dq": _dense_init(ks[0], (d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": (jnp.ones((m.q_lora_rank,), jnp.float32), ("q_lora",)),
        "w_uq": _dense_init(ks[1], (m.q_lora_rank, h, qk + m.qk_rope_dim),
                            ("q_lora", "heads", "head_dim")),
        "w_dkv": _dense_init(ks[2], (d, m.kv_lora_rank), ("embed", "kv_lora")),
        "kv_norm": (jnp.ones((m.kv_lora_rank,), jnp.float32), ("kv_lora",)),
        "w_kr": _dense_init(ks[3], (d, m.qk_rope_dim), ("embed", None)),
        "w_uk": _dense_init(ks[4], (m.kv_lora_rank, h, qk),
                            ("kv_lora", "heads", "head_dim")),
        "w_uv": _dense_init(ks[5], (m.kv_lora_rank, h, m.v_head_dim),
                            ("kv_lora", "heads", "head_dim")),
        "wo": _dense_init(ks[6], (h, m.v_head_dim, d),
                          ("heads", "head_dim", "embed")),
    }
    return split_tree(tree)


def mla_apply(p: Params, cfg: ModelConfig, x, positions, mask, *, past=None):
    """Training/prefill MLA: materialise per-head K/V.  Returns
    (out, (c_kv, k_rope)) -- the *compressed* cache entries.

    ``past`` -- optional ``(past_ckv, past_krope)`` compressed cache rows
    of a previous chunk ([B, P, kv_lora], [B, P, rope]); the chunk attends
    over ``past ++ own`` (``mask``: [.., S, P+S]) and still returns only
    its OWN chunk's cache entries."""
    m: MLAConfig = cfg.mla
    cq = rms_norm(x @ p["w_dq"].astype(x.dtype), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(x.dtype))
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c_kv = rms_norm(x @ p["w_dkv"].astype(x.dtype), p["kv_norm"])
    k_rope = rope((x @ p["w_kr"].astype(x.dtype))[:, :, None, :], positions,
                  cfg.rope_theta)  # [B,S,1,rope] shared across heads
    c_all, kr_all = c_kv, k_rope[:, :, 0, :]
    if past is not None:
        past_ckv, past_krope = past
        c_all = jnp.concatenate([past_ckv.astype(c_kv.dtype), c_kv], axis=1)
        kr_all = jnp.concatenate([past_krope.astype(c_kv.dtype), kr_all],
                                 axis=1)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_all, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_all, p["w_uv"].astype(x.dtype))

    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                  k_nope.shape[:3] + (m.qk_rope_dim,))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _sdpa_chunked(q_full, k, v, mask, cfg.softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(p: Params, cfg: ModelConfig, x, cache_ckv, cache_krope,
               cache_pos, cur_pos):
    """Absorbed-matrix MLA decode over the compressed cache.
    cache_ckv: [B,T,kv_lora]; cache_krope: [B,T,rope]."""
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    cq = rms_norm(x @ p["w_dq"].astype(x.dtype), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(x.dtype))
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope, cur_pos[:, None], cfg.rope_theta)

    c_new = rms_norm(x @ p["w_dkv"].astype(x.dtype), p["kv_norm"])  # [B,1,r]
    kr_new = rope((x @ p["w_kr"].astype(x.dtype))[:, :, None, :],
                  cur_pos[:, None], cfg.rope_theta)[:, :, 0, :]

    ckv = jnp.concatenate([cache_ckv, c_new], axis=1).astype(x.dtype)
    krope = jnp.concatenate([cache_krope, kr_new], axis=1).astype(x.dtype)
    pos_all = jnp.concatenate([cache_pos, cur_pos[:, None]], axis=1)

    # absorb W_uk into q:  q_abs[b,h,r] = sum_k q_nope[b,h,k] W_uk[r,h,k]
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(x.dtype))
    logits = (jnp.einsum("bshr,btr->bhst", q_abs, ckv)
              + jnp.einsum("bshk,btk->bhst", q_rope, krope))
    # multiply by the precomputed scale (not divide by sqrt) so the paged
    # MLA kernel, which takes `scale` as a static operand, stays
    # bit-identical to this dense path
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    logits = logits.astype(jnp.float32) * scale
    mask = (pos_all <= cur_pos[:, None]) & (pos_all >= 0)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", w, ckv)
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, c_new, kr_new


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def embedding_init(key, cfg: ModelConfig):
    # scale 1/sqrt(d): embed() re-scales by sqrt(d) (unit-std activations)
    # and the tied unembedding then produces unit-scale logits at init.
    tree = {"tok": _dense_init(key, (cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"),
                               scale=1.0 / np.sqrt(cfg.d_model))}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        tree["unembed"] = _dense_init(k2, (cfg.d_model, cfg.vocab_size),
                                      ("embed", "vocab"))
    return split_tree(tree)


def embed(p: Params, cfg: ModelConfig, tokens):
    e = p["tok"].astype(jnp.dtype(cfg.dtype))[tokens]
    # NB: np.sqrt returns a strong np.float64 scalar which promotes the
    # residual stream to f32.  A weak python float keeps it bf16 -- correct
    # for TPU -- but the CPU SPMD partitioner regresses badly on the bf16
    # graph (nemotron train temp 74->93 GB, bytes 31->127 TB), so the CPU
    # dry-run keeps the f32 stream and documents the ~2x activation-traffic
    # headroom (EXPERIMENTS.md SPerf iteration 5: confirmed root cause,
    # fix deferred to the TPU target via RESID_DTYPE).
    scale = (float(np.sqrt(cfg.d_model)) if RESID_WEAK_SCALE
             else np.sqrt(cfg.d_model))
    return e * scale


# Toggle for the TPU deployment: weak-typed scale => bf16 residual stream.
RESID_WEAK_SCALE = False


def unembed(p: Params, cfg: ModelConfig, x):
    w = (p["tok"].T if cfg.tie_embeddings else p["unembed"]).astype(x.dtype)
    return x @ w
