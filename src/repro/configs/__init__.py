"""Architecture registry: ``get(name)`` for full configs (dry-run scale),
``reduced(name)`` for CPU smoke-test configs of the same family shape.

Also defines the four assigned input shapes (train_4k / prefill_32k /
decode_32k / long_500k) and which (arch x shape) cells are lowerable --
long_500k is skipped for pure full-attention archs per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

from repro.configs import (deepseek_v3_671b, gemma3_12b, musicgen_large,
                           nemotron_4_340b, olmoe_1b_7b, paligemma_3b,
                           qwen3_14b, recurrentgemma_2b, stablelm_12b,
                           xlstm_1_3b)

_MODULES = {
    "nemotron-4-340b": nemotron_4_340b,
    "stablelm-12b": stablelm_12b,
    "qwen3-14b": qwen3_14b,
    "gemma3-12b": gemma3_12b,
    "paligemma-3b": paligemma_3b,
    "xlstm-1.3b": xlstm_1_3b,
    "musicgen-large": musicgen_large,
    "deepseek-v3-671b": deepseek_v3_671b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "recurrentgemma-2b": recurrentgemma_2b,
}

ARCHS: List[str] = list(_MODULES)

# ---------------------------------------------------------------------------
# shapes (assignment brief)
# ---------------------------------------------------------------------------

SHAPES: Dict[str, dict] = {
    "train_4k": dict(seq_len=4096, global_batch=256, step="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, step="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, step="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, step="decode"),
}


def get(name: str) -> ModelConfig:
    try:
        return _MODULES[name].config()
    except KeyError as e:
        raise ValueError(f"unknown arch {name!r}; one of {ARCHS}") from e


def cells(include_skipped: bool = False):
    """All (arch, shape) assignment cells; long_500k only where lowerable."""
    out = []
    for a in ARCHS:
        cfg = get(a)
        for s in SHAPES:
            skipped = (s == "long_500k" and not cfg.supports_long_context)
            if include_skipped or not skipped:
                out.append((a, s) if not include_skipped
                           else (a, s, skipped))
    return out


# ---------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduced(name: str) -> ModelConfig:
    """Same family/pattern, tiny dims: one repeat per segment, small width,
    tiny vocab/experts.  Runs a forward/train step on CPU in seconds."""
    cfg = get(name)
    d, heads, kv = 64, 4, min(4, max(1, cfg.num_kv_heads))
    if cfg.num_heads == cfg.num_kv_heads:   # MHA-style archs keep kv == heads
        kv = heads
    hd = 16
    segs = tuple((pat, 1) for pat, _ in cfg.segments)
    kw = dict(
        d_model=d, num_heads=heads, num_kv_heads=kv, head_dim=hd,
        d_ff=(128 if cfg.d_ff else 0), vocab_size=256, segments=segs,
        window_size=min(cfg.window_size, 8) if cfg.window_size else 0,
        lru_width=(64 if cfg.lru_width else 0),
        prefix_len=(8 if cfg.prefix_len else 0),
        cond_len=(4 if cfg.cond_len else 0),
        cond_dim=(d if cfg.cond_dim else 0),
        max_seq_len=64, remat=False, moe_impl="dense",
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(num_experts=8, top_k=2, d_expert=32,
                              num_shared=cfg.moe.num_shared,
                              d_shared=32 if cfg.moe.d_shared else 0,
                              capacity_factor=2.0)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    return dataclasses.replace(cfg, **kw)
