"""Synthetic page-access workloads for the tiering runtime.

Decode-time KV access patterns from the serving literature, in the same
spirit as ``core.traces`` but in the (decode-step x page) domain:

  * ``attention_sink``  -- heavy mass on the first pages (sink tokens) +
                           a sliding recent window: the canonical decode
                           pattern; strong short reuse on sinks.
  * ``periodic_context``-- the model repeatedly re-reads a document span
                           every ~K steps (RAG/agent loops): reuse
                           distance == K, the Cori sweet spot.
  * ``random_lookup``   -- zipf random page touches (retrieval-ish).
"""
from __future__ import annotations

import numpy as np

__all__ = ["attention_sink", "periodic_context", "random_lookup"]


def attention_sink(steps: int, n_pages: int, sink_pages: int = 2,
                   window_pages: int = 4, seed: int = 0,
                   drift_every: int = 2) -> np.ndarray:
    """``drift_every`` = decode steps between moves of the recent window; at
    1 the hot set moves every step, so the best tiering period is
    unambiguously the shortest (no aliasing between tier cadence and
    drift)."""
    rng = np.random.default_rng(seed)
    m = np.zeros((steps, n_pages), np.float32)
    for t in range(steps):
        m[t, :sink_pages] = 0.3 + 0.1 * rng.random(sink_pages)
        cur = min(n_pages - 1, (t // drift_every) % n_pages)
        lo = max(0, cur - window_pages)
        m[t, lo:cur + 1] = 0.2 + 0.1 * rng.random(cur + 1 - lo)
    return m


def periodic_context(steps: int, n_pages: int, span_pages: int = 8,
                     period: int = 16, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    m = np.zeros((steps, n_pages), np.float32)
    span0 = n_pages // 4
    for t in range(steps):
        m[t, :1] = 0.3                      # sink
        if (t % period) < span_pages:       # re-read the span, one page/step
            m[t, span0 + (t % period)] = 0.5
        m[t, min(n_pages - 1, t % n_pages)] += 0.2   # recent window
    return m


def random_lookup(steps: int, n_pages: int, touches: int = 3,
                  zipf_a: float = 1.5, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    m = np.zeros((steps, n_pages), np.float32)
    ranks = np.arange(1, n_pages + 1, dtype=np.float64)
    p = ranks ** (-zipf_a)
    p /= p.sum()
    for t in range(steps):
        pages = rng.choice(n_pages, size=touches, p=p)
        m[t, pages] = 0.2 + 0.3 * rng.random(touches)
    return m
