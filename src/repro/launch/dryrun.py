import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with zero device allocation:
  * proof the distribution config is coherent (SPMD partitioning succeeds),
  * memory_analysis()  -> per-device bytes (fits-in-HBM evidence),
  * cost_analysis()    -> HLO FLOPs / bytes for the roofline terms,
  * collective op bytes parsed from the post-partitioning HLO.

Results are cached as JSON under ``benchmarks/out/dryrun/`` and consumed by
``benchmarks/roofline.py`` and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.distributed import sharding as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import model as mdl
from repro.train import optim, step as tstep

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "out" / "dryrun"

# Per-arch training knobs (documented in EXPERIMENTS.md SDry-run): the
# 100B+ configs need bf16 optimizer state + gradient accumulation to fit
# 16 GB/chip HBM.
TRAIN_OVERRIDES = {
    "nemotron-4-340b": dict(state_dtype="bfloat16", accum=8),
    "deepseek-v3-671b": dict(state_dtype="bfloat16", accum=8),
    "qwen3-14b": dict(accum=2),
    "stablelm-12b": dict(accum=2),
    "gemma3-12b": dict(accum=4),
    "paligemma-3b": dict(accum=2),
    "musicgen-large": dict(accum=2),
    "olmoe-1b-7b": dict(accum=4),
    "xlstm-1.3b": dict(accum=4),
    "recurrentgemma-2b": dict(accum=2),
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u32|s16|u16|s8|u8|pred|c64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}


def collective_bytes(hlo_text: str):
    """Sum output bytes of every collective op in post-SPMD HLO, by kind."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        n = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dims = sm.group(2)
            cnt = int(np.prod([int(d) for d in dims.split(",") if d])) if dims \
                else 1
            n += cnt * _BYTES[sm.group(1)]
        out[kind] += n
    return out


def _batch_shardings(bspecs, mesh, step_kind="train"):
    rules = SH.act_rules_for(step_kind)

    def one(name, sds):
        names = {"tokens": ("batch", "seq"), "targets": ("batch", "seq"),
                 "extra_embeds": ("batch", "seq", "embed"),
                 "cond": ("batch", "seq", "embed"),
                 "cur_pos": ("batch",)}[name]
        return NamedSharding(mesh, SH._resolve(names, sds.shape, rules, mesh))
    return {k: one(k, v) for k, v in bspecs.items()}


def lower_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
               cost_variant: bool = False):
    """Lower+compile one cell.  cost_variant=True unrolls the layer and
    grad-accum loops so HLO cost analysis (which counts while-loop bodies
    once) reports trip-count-correct FLOPs and collective bytes; the deploy
    variant (scan+accum) is what memory analysis and the shardability proof
    use."""
    import dataclasses as _dc
    c = SP.cell(arch, shape)
    if cost_variant:
        c = _dc.replace(c, cfg=_dc.replace(c.cfg, unroll_layers=True))
    mesh = make_production_mesh(multi_pod=multi_pod)
    shard = SH.make_shard_fn(mesh, rules=SH.act_rules_for(c.step_kind))
    ov = dict(TRAIN_OVERRIDES.get(arch, {}))
    if cost_variant:
        ov["accum"] = 1
    t0 = time.time()

    if c.step_kind == "train":
        ocfg = optim.OptConfig(state_dtype=ov.get("state_dtype", "float32"))
        shapes, sspecs = SP.state_specs_shapes(c.cfg, ocfg)
        state_sh = SH.tree_shardings(sspecs, shapes, mesh)
        bspecs = SP.batch_specs(c)
        batch_sh = _batch_shardings(bspecs, mesh)
        pspecs_model = mdl.init_specs_only(c.cfg)
        step = tstep.make_train_step(c.cfg, ocfg, mesh=mesh, shard=shard,
                                     accum_steps=ov.get("accum", 1),
                                     param_specs=pspecs_model,
                                     cast_params=ov.get("cast_params", True))
        metric_sh = {"loss": NamedSharding(mesh, P()),
                     "grad_norm": NamedSharding(mesh, P()),
                     "lr": NamedSharding(mesh, P())}
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metric_sh))
        lowered = fn.lower(shapes, bspecs)
    elif c.step_kind == "prefill":
        pspecs = mdl.init_specs_only(c.cfg)
        pshapes = jax.eval_shape(
            lambda: mdl.init(jax.random.PRNGKey(0), c.cfg)[0])
        param_sh = SH.tree_shardings(pspecs, pshapes, mesh)
        bspecs = SP.batch_specs(c)
        batch_sh = _batch_shardings(bspecs, mesh)

        pshard = SH.make_param_shard_fn(mesh)

        def prefill_fn(params, batch):
            params = tstep.cast_params_tree(params)
            return mdl.prefill(params, c.cfg, batch["tokens"],
                               extra_embeds=batch.get("extra_embeds"),
                               cond=batch.get("cond"), mesh=mesh, shard=shard,
                               param_specs=pspecs, pshard=pshard)

        fn = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh))
        lowered = fn.lower(pshapes, bspecs)
    else:  # decode
        pspecs = mdl.init_specs_only(c.cfg)
        pshapes = jax.eval_shape(
            lambda: mdl.init(jax.random.PRNGKey(0), c.cfg)[0])
        param_sh = SH.tree_shardings(pspecs, pshapes, mesh)
        dsp = SP.decode_specs(c)
        cache_sh = SH.tree_shardings(mdl.cache_specs(c.cfg), dsp["cache"],
                                     mesh, rules=SH.ACT_RULES)
        tok_sh = NamedSharding(mesh, SH._resolve(("batch", "seq"),
                                                 dsp["tokens"].shape,
                                                 SH.ACT_RULES, mesh))
        pos_sh = NamedSharding(mesh, SH._resolve(("batch",),
                                                 dsp["cur_pos"].shape,
                                                 SH.ACT_RULES, mesh))
        cond_spec = dsp.get("cond")

        def decode_fn(params, cache, tokens, cur_pos, cond=None):
            params = tstep.cast_params_tree(params)
            return mdl.decode_step(params, c.cfg, cache, tokens, cur_pos,
                                   cond=cond, mesh=mesh, shard=shard)

        in_sh = [param_sh, cache_sh, tok_sh, pos_sh]
        args = [pshapes, dsp["cache"], dsp["tokens"], dsp["cur_pos"]]
        if cond_spec is not None:
            in_sh.append(NamedSharding(mesh, SH._resolve(
                ("batch", "seq", "embed"), cond_spec.shape, SH.ACT_RULES,
                mesh)))
            args.append(cond_spec)
        fn = jax.jit(decode_fn, in_shardings=tuple(in_sh),
                     out_shardings=(None, cache_sh))
        lowered = fn.lower(*args)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch, "shape": shape, "variant":
            "cost" if cost_variant else "deploy",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "step_kind": c.step_kind,
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     + mem.output_size_in_bytes
                                     - mem.alias_size_in_bytes),
        "collective_bytes": coll,
        "collective_bytes_total": int(sum(coll.values())),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "model_params": int(SP.cell(arch, shape).cfg.param_count()),
        "active_params": int(SP.cell(arch, shape).cfg.active_param_count()),
        "tokens_per_step": (c.global_batch * c.seq_len
                            if c.step_kind != "decode" else c.global_batch),
    }
    if verbose:
        print(f"[{arch} x {shape} x {rec['mesh']}] "
              f"flops={rec['flops']:.3e} temp={rec['temp_bytes']/1e9:.2f}GB "
              f"args={rec['argument_bytes']/1e9:.2f}GB "
              f"coll={rec['collective_bytes_total']/1e9:.2f}GB "
              f"compile={t_compile:.0f}s")
        print("  memory_analysis:", mem)
    return rec


def run_cell(arch, shape, mesh_mode, force=False):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    tag = "multi" if mesh_mode == "multi" else "single"
    path = OUT_DIR / f"{arch}__{shape}__{tag}.json"
    if path.exists() and not force:
        print(f"[skip cached] {path.name}")
        return json.loads(path.read_text())
    rec = lower_cell(arch, shape, multi_pod=(mesh_mode == "multi"))
    if mesh_mode == "single":
        # trip-count-correct FLOPs/collectives for the roofline table
        crec = lower_cell(arch, shape, multi_pod=False, cost_variant=True)
        rec["cost_variant"] = {k: crec[k] for k in
                               ("flops", "bytes_accessed", "collective_bytes",
                                "collective_bytes_total", "compile_s")}
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = (C.cells() if args.all
             else [(args.arch, args.shape)])
    failures = []
    for arch, shape in cells:
        for m in meshes:
            try:
                run_cell(arch, shape, m, force=args.force)
            except Exception as e:  # noqa: BLE001 - report all failures
                traceback.print_exc()
                failures.append((arch, shape, m, str(e)[:200]))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll requested dry-run cells compiled.")


if __name__ == "__main__":
    main()
