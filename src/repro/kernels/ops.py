"""Jitted public wrappers for the Pallas kernels.

``impl`` selects the execution path:
  * "pallas"    -- compiled TPU kernel (the deploy target)
  * "interpret" -- Pallas interpret mode (CPU-validatable, same kernel body)
  * "reference" -- pure-jnp oracle (autodiff-friendly)

On this CPU container the default is "interpret" for tests and "reference"
inside jitted model code.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import page_hist as _ph
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref


@functools.partial(jax.jit, static_argnames=("alpha", "threshold", "impl"))
def page_hist(ids, hotness, *, alpha: float = 0.5, threshold: float = 1.0,
              impl: str = "interpret"):
    if impl == "reference":
        return _ref.page_hist_ref(ids, hotness, alpha=alpha,
                                  threshold=threshold)
    return _ph.page_hist(ids, hotness, alpha=alpha, threshold=threshold,
                         interpret=(impl == "interpret"))


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bkv", "impl"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = _fa.DEFAULT_BQ, bkv: int = _fa.DEFAULT_BKV,
                    impl: str = "interpret"):
    if impl == "reference":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                               bkv=bkv, interpret=(impl == "interpret"))


@functools.partial(jax.jit,
                   static_argnames=("window", "softcap", "return_mass",
                                    "impl"))
def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    window: int = 0, softcap: float = 0.0,
                    return_mass: bool = False, impl: str = "interpret"):
    # Ragged multi-request tables pad short rows with -1; those entries are
    # already masked out by `lengths`, so clamp them to a valid physical
    # page before the gather (the Pallas index_map would otherwise DMA out
    # of bounds, and the reference gather would wrap).  Precondition: a -1
    # *inside* the `lengths` range means a non-resident page (slot_of ==
    # -1) leaked into the table -- callers must ensure_resident first; the
    # clamp cannot distinguish that from padding on traced values.
    page_table = jnp.maximum(page_table, 0)
    if impl == "reference":
        return _ref.paged_attention_ref(q, k_pages, v_pages, page_table,
                                        lengths, window=window,
                                        softcap=softcap,
                                        return_mass=return_mass)
    # The kernel carries a per-page exp-sum alongside its online-softmax
    # accumulators and emits the head-normalised page mass as a second
    # output -- telemetry is fused in-kernel; the reference oracle above is
    # retained only as the allclose target (tests/test_kernels.py).
    out, mass = _pa.paged_attention(q, k_pages, v_pages, page_table, lengths,
                                    window=window, softcap=softcap,
                                    interpret=(impl == "interpret"))
    if not return_mass:
        return out
    return out, mass


@functools.partial(jax.jit,
                   static_argnames=("scale", "return_mass", "impl"))
def paged_attention_mla(q_abs, q_rope, ckv_pages, krope_pages, page_table,
                        lengths, *, scale: float, return_mass: bool = False,
                        impl: str = "interpret"):
    """MLA absorbed-matrix decode over compressed paged rows (ckv shared
    across heads + roped krope).  Same ragged-table clamp contract as
    ``paged_attention``; ``scale`` = 1/sqrt(qk_nope_dim + qk_rope_dim).
    Returns the compressed-space context [B, H, R] (callers up-project
    with W_uv) and, with ``return_mass``, the per-page mass f32[B, n]."""
    page_table = jnp.maximum(page_table, 0)
    if impl == "reference":
        return _ref.paged_attention_mla_ref(q_abs, q_rope, ckv_pages,
                                            krope_pages, page_table, lengths,
                                            scale=scale,
                                            return_mass=return_mass)
    out, mass = _pa.paged_attention_mla(q_abs, q_rope, ckv_pages,
                                        krope_pages, page_table, lengths,
                                        scale=scale,
                                        interpret=(impl == "interpret"))
    if not return_mass:
        return out
    return out, mass
