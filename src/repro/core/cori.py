"""Cori: Frequency Generator + Tuner (paper §IV-B, §IV-C).

Dominant reuse (Eq. 1), with reuses sorted ascending so that the extra
``(N - i)`` weight favours shorter reuse distances:

            sum_i (N - i) * repeat_i * reuse_i
    DR  =  ------------------------------------        i = 1..N
            sum_i (N - i) * repeat_i

Candidate periods (Eq. 2):  [DR, 2*DR, 3*DR, ..., Runtime/2], emitted
shortest period first (highest frequency first) -- this priority ordering is
essential to Cori's trial efficiency (§IV-B).

The Tuner (§IV-C) trials candidates in order against the actual system (here:
the hybrid-memory simulator, or any callable ``period -> runtime``) and stops
either when a trial budget is hit or when performance stops improving
("performance ... shows no significant variation from the last trial",
§IV-D).

Invariants of the online state machine (pinned by tests/test_online.py and
tests/test_sched.py):

  * **Trial-window alignment.**  Every cost window (TRIAL and HOLD) is
    rounded up to a whole multiple of the period being measured, so each
    window contains the same number of tiering events.  Without this, a
    window boundary aliasing against the period makes per-step costs
    oscillate and fakes drift on a perfectly stable workload.  Trials rank
    by the window's *tail* half only -- the head absorbs the residency
    transient inherited from whatever period ran before.
  * **Page-ID recycling contract.**  ``forget_pages`` must be called when
    the serving scheduler frees a logical page ID, *before* the allocator
    may recycle it; a recycled ID's first access by its new owner must
    never pair with the old owner's last access into a bogus reuse gap.
  * **Mass-domain stability.**  The collector thresholds page masses into
    accessed sets.  The fully-paged serving path feeds masses aggregated
    over ALL attention layers (head-normalised, layer-averaged);
    ``rel_threshold`` switches the cut to a fraction of the step's peak
    mass so the accessed-set size does not drift with batch occupancy.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.reuse import ReuseHistogram, StreamingReuseCollector

__all__ = [
    "dominant_reuse",
    "candidate_periods",
    "TuneResult",
    "Tuner",
    "OnlineTuner",
    "trials_to_best",
]


def dominant_reuse(hist: ReuseHistogram) -> float:
    """Eq. 1: weighted average of reuses, biased towards short ones."""
    if hist.num_bins == 0:
        raise ValueError("empty reuse histogram: nothing to tune from")
    order = np.argsort(hist.values)
    reuse = hist.values[order].astype(np.float64)
    repeat = hist.counts[order].astype(np.float64)
    n = reuse.shape[0]
    if n == 1:
        return float(reuse[0])
    w = (n - np.arange(1, n + 1, dtype=np.float64)) * repeat  # (N - i) * repeat_i
    denom = w.sum()
    if denom <= 0:  # degenerate: all weight on the longest reuse
        return float(reuse[0])
    return float((w * reuse).sum() / denom)


def candidate_periods(dr: float, runtime: float, max_candidates: int = 64,
                      min_period: float = 1.0) -> np.ndarray:
    """Eq. 2: multiples of DR up to Runtime/2, shortest first.

    `runtime` and the returned periods are in whatever domain DR is measured
    in (requests for the simulator, seconds / decode-steps on a system).
    """
    dr = max(float(dr), float(min_period))
    hi = runtime / 2.0
    if dr > hi:
        return np.array([hi], dtype=np.float64)
    n = int(hi // dr)
    ks = np.arange(1, n + 1, dtype=np.float64)
    if n > max_candidates:
        # Keep the ladder's head exact (the critical low-multiples region),
        # thin the tail geometrically -- same endpoints as Eq. 2.
        head = ks[: max_candidates // 2]
        tail = np.unique(np.geomspace(head[-1] + 1, n,
                                      max_candidates - head.shape[0]).round())
        ks = np.concatenate([head, tail])
    return ks * dr


@dataclasses.dataclass(frozen=True)
class TuneResult:
    chosen_period: float
    chosen_runtime: float
    trials: int                      # trials actually executed
    tried_periods: np.ndarray
    tried_runtimes: np.ndarray
    candidates: np.ndarray           # full candidate ladder

    @property
    def best_runtime_tried(self) -> float:
        return float(np.min(self.tried_runtimes))


class Tuner:
    """Cori's Tuner: trial candidates in order, stop on no-improvement.

    Args:
      evaluate: callable(period) -> runtime (lower is better).  For the
        simulator this wraps `core.sim.simulate`; for the serving runtime it
        wraps a measured window of decode steps.
      patience: stop after this many consecutive non-improving trials
        (the flexible stopping policy of §IV-D).
      rel_tol: a trial must beat the best-so-far by this fraction to count
        as an improvement.
      max_trials: hard trial budget (None = whole ladder).
    """

    def __init__(self, evaluate: Callable[[float], float], patience: int = 2,
                 rel_tol: float = 0.01, max_trials: Optional[int] = None):
        self.evaluate = evaluate
        self.patience = patience
        self.rel_tol = rel_tol
        self.max_trials = max_trials

    def run(self, candidates: Sequence[float]) -> TuneResult:
        candidates = np.asarray(list(candidates), dtype=np.float64)
        if candidates.size == 0:
            raise ValueError(
                "empty candidate ladder: nothing to trial (Eq. 2 produced no "
                "periods -- check the reuse histogram / runtime horizon)")
        best_rt = np.inf
        best_p = float(candidates[0])
        tried_p: List[float] = []
        tried_rt: List[float] = []
        stale = 0
        for p in candidates:
            rt = float(self.evaluate(float(p)))
            tried_p.append(float(p))
            tried_rt.append(rt)
            if rt < best_rt * (1.0 - self.rel_tol):
                best_rt, best_p, stale = rt, float(p), 0
            else:
                stale += 1
                if stale >= self.patience:
                    break
            if self.max_trials is not None and len(tried_p) >= self.max_trials:
                break
        if not np.isfinite(best_rt):
            best_rt, best_p = tried_rt[0], tried_p[0]
        return TuneResult(best_p, best_rt, len(tried_p),
                          np.asarray(tried_p), np.asarray(tried_rt), candidates)


class OnlineTuner:
    """Closed-loop Cori: profile -> trial -> hold, re-entered on drift.

    The offline ``Tuner`` needs an oracle ``evaluate(period)`` it can call at
    will (the simulator).  Inside a running system there is no oracle -- each
    candidate must be *lived through* for a window of decode steps while the
    system serves traffic.  The OnlineTuner is that state machine:

      PROFILE  feed a ``StreamingReuseCollector`` for ``profile_steps`` steps,
               then derive DR (Eq. 1) and the candidate ladder (Eq. 2) over
               the ``horizon_steps`` trial horizon.  Decode steps are already
               coarse, so reuse gaps bin at width 1 by default -- a wider
               bin floors DR (and hence the shortest candidate) at the bin
               centre, hiding period-1 ladders.
      TRIAL    live each candidate period for a window of decode steps, but
               rank candidates by the per-step cost of the window's *tail*
               half only: the head absorbs the residency transient the
               trial inherits from whatever ran before it (charging that
               transient to the candidate biases the ranking against
               whichever period is trialed first).  The offline Tuner's
               stopping rule (``rel_tol`` improvement, ``patience`` stale
               trials, ``max_trials`` budget) decides when to stop.
      HOLD     run at the winning period.  Every measurement window the
               per-step cost is compared against the post-tune baseline; a
               regression beyond ``drift_ratio`` sustained for
               ``drift_patience`` consecutive windows means the workload
               changed phase -> reset the collector and re-enter PROFILE.
               The detector is symmetric: a *sustained improvement* beyond
               ``improve_ratio`` (cost below baseline/improve_ratio for
               ``improve_patience`` windows) also re-profiles -- a cheaper
               phase may admit an even better period than the one tuned
               for the old, more expensive mix.  Set ``improve_ratio`` to
               ``None`` to restore the regression-only detector.

    Cost windows (TRIAL and HOLD) are rounded up to a whole multiple of the
    period being measured, so every window contains the same number of
    tiering events -- otherwise a window boundary that aliases against the
    period makes per-step costs oscillate and fakes drift on a perfectly
    stable workload.

    Drive it one decode step at a time with ``on_step``; it returns the
    period the tiering runtime should use *now*.
    """

    PROFILE, TRIAL, HOLD = "profile", "trial", "hold"

    def __init__(self, n_pages: int, default_period: int = 8,
                 profile_steps: int = 64, trial_steps: int = 32,
                 horizon_steps: Optional[int] = None,
                 window: Optional[int] = None,
                 patience: int = 2, rel_tol: float = 0.01,
                 max_trials: Optional[int] = None,
                 drift_ratio: float = 1.3, drift_patience: int = 2,
                 improve_ratio: Optional[float] = 2.0,
                 improve_patience: Optional[int] = None,
                 bin_width: int = 1,
                 min_period: float = 1.0, access_threshold: float = 0.05,
                 rel_threshold: bool = False,
                 max_candidates: int = 16, cost_log_len: int = 4096):
        self.collector = StreamingReuseCollector(
            n_pages, window=window or 4 * profile_steps, bin_width=bin_width)
        self.profile_steps = profile_steps
        self.trial_steps = trial_steps
        self.horizon_steps = horizon_steps or 2 * trial_steps
        self.patience = patience
        self.rel_tol = rel_tol
        self.max_trials = max_trials
        self.drift_ratio = drift_ratio
        self.drift_patience = drift_patience
        self.improve_ratio = improve_ratio
        self.improve_patience = (improve_patience if improve_patience
                                 is not None else drift_patience)
        self.min_period = min_period
        self.access_threshold = access_threshold
        self.rel_threshold = rel_threshold
        self.max_candidates = max_candidates

        self.state = self.PROFILE
        self.period = int(default_period)
        self.step = 0
        self.dominant_reuse: Optional[float] = None
        self.candidates: np.ndarray = np.empty(0)
        self.tried: List[Tuple[float, float]] = []   # (period, cost/step)
        self.baseline_cost: Optional[float] = None
        self.retunes = 0          # completed PROFILE->TRIAL->HOLD cycles
        self.history: List[Tuple[int, int]] = []     # (step, period) changes
        self.converged_at: Optional[int] = None      # step of last HOLD entry
        # recent per-step costs (bounded: this object lives in a serving loop)
        self.cost_log: "collections.deque[float]" = collections.deque(
            maxlen=cost_log_len)
        self._drift_strikes = 0
        self._improve_strikes = 0
        self._trial_idx = 0
        self._best_cost = np.inf
        self._best_period = self.period
        self._stale = 0
        self._win_cost = 0.0
        self._win_steps = 0
        self._tail_cost = 0.0
        self._tail_steps = 0

    # -- per-step entry point ------------------------------------------------
    def on_step(self, page_mass: Optional[np.ndarray] = None,
                cost: float = 0.0,
                accessed_ids: Optional[np.ndarray] = None,
                dt: int = 1) -> int:
        """Feed one observation (attention masses or accessed page ids, plus
        the measured cost); returns the period to tier at.

        ``dt`` is the number of token-steps the observation spans (the
        macro length when the serving loop samples once per movement
        period).  The tuner's clock, reuse gaps, and profile/trial
        windows all advance by ``dt``, so the derived period stays in
        the same token-step units it is actuated in -- ``cost`` must
        then be the total for those ``dt`` steps (window means stay
        per-step)."""
        dt = max(1, int(dt))
        if accessed_ids is not None:
            self.collector.observe(accessed_ids, dt=dt)
        elif page_mass is not None:
            self.collector.observe_mass(page_mass, self.access_threshold,
                                        relative=self.rel_threshold, dt=dt)
        self._win_cost += float(cost)
        self._win_steps += dt
        self.cost_log.append(float(cost))
        self.step += dt
        if self.state == self.PROFILE:
            if self._win_steps >= self.profile_steps:
                self._begin_trials()
        elif self.state == self.TRIAL:
            if self._win_steps > self._cost_window() - self._tail_window():
                self._tail_cost += float(cost)
                self._tail_steps += dt
            if self._win_steps >= self._cost_window():
                self._finish_trial()
        else:  # HOLD
            if self._win_steps >= self._cost_window():
                self._check_drift()
        return self.period

    def _cost_window(self) -> int:
        """Measurement window: >= trial_steps, rounded up to a whole multiple
        of the current period so every window sees the same number of
        tiering events (no aliasing between window and period)."""
        p = max(1, self.period)
        return -(-self.trial_steps // p) * p

    def _tail_window(self) -> int:
        """Measured tail of a trial window: ~half of it, still a whole
        multiple of the period (the head is warmup for the residency
        transient)."""
        p = max(1, self.period)
        return max(1, (self._cost_window() // (2 * p))) * p

    # -- state transitions ---------------------------------------------------
    def _set_period(self, period: float) -> None:
        p = max(1, int(round(period)))
        if p != self.period:
            self.history.append((self.step, p))
        self.period = p

    def _reset_window(self) -> None:
        self._win_cost = 0.0
        self._win_steps = 0
        self._tail_cost = 0.0
        self._tail_steps = 0

    def _begin_trials(self) -> None:
        hist = self.collector.histogram()
        if hist.num_bins == 0:
            # nothing re-accessed yet: keep the default period, try again
            # after another profile window
            self._reset_window()
            return
        self.dominant_reuse = dominant_reuse(hist)
        ladder = candidate_periods(self.dominant_reuse,
                                   float(self.horizon_steps),
                                   max_candidates=self.max_candidates,
                                   min_period=self.min_period)
        # a candidate longer than the trial window cannot be observed even
        # once per window -- clip the ladder (keep at least the head)
        feasible = ladder[ladder <= self.trial_steps]
        self.candidates = feasible if feasible.size else ladder[:1]
        self.tried = []
        self._trial_idx = 0
        self._best_cost = np.inf
        self._best_period = self.period
        self._stale = 0
        self.state = self.TRIAL
        self._set_period(self.candidates[0])
        self._reset_window()

    def _finish_trial(self) -> None:
        cost = self._tail_cost / max(1, self._tail_steps)
        self.tried.append((float(self.period), cost))
        if cost < self._best_cost * (1.0 - self.rel_tol):
            self._best_cost, self._best_period = cost, self.period
            self._stale = 0
        else:
            self._stale += 1
        self._trial_idx += 1
        done = (self._stale >= self.patience
                or self._trial_idx >= len(self.candidates)
                or (self.max_trials is not None
                    and self._trial_idx >= self.max_trials))
        if done:
            self.state = self.HOLD
            self.baseline_cost = None
            self._drift_strikes = 0
            self._improve_strikes = 0
            self.retunes += 1
            self.converged_at = self.step
            self._set_period(self._best_period)
        else:
            self._set_period(self.candidates[self._trial_idx])
        self._reset_window()

    def _check_drift(self) -> None:
        cost = self._win_cost / max(1, self._win_steps)
        if self.baseline_cost is None:
            self.baseline_cost = cost
        elif cost > self.drift_ratio * max(self.baseline_cost, 1e-12):
            self._drift_strikes += 1
            self._improve_strikes = 0
            if self._drift_strikes >= self.drift_patience:
                # sustained regression == workload phase change: stale
                # reuse info is worse than none
                self._reprofile()
        elif (self.improve_ratio is not None
              and cost * self.improve_ratio < self.baseline_cost):
            self._improve_strikes += 1
            self._drift_strikes = 0
            if self._improve_strikes >= self.improve_patience:
                # sustained *improvement* is a phase change too: the new,
                # cheaper mix may admit an even better period than the one
                # tuned against the old mix
                self._reprofile()
        else:
            self._drift_strikes = 0
            self._improve_strikes = 0
        self._reset_window()

    def _reprofile(self) -> None:
        self.collector.reset()
        self.state = self.PROFILE
        self._drift_strikes = 0
        self._improve_strikes = 0

    # -- multi-request traffic hooks -----------------------------------------
    def forget_pages(self, ids: np.ndarray) -> None:
        """Invalidate freed logical page IDs in the reuse collector (see
        ``StreamingReuseCollector.forget``): called by the serving scheduler
        when a request retires, so a recycled global page ID does not pair
        the new owner's first access with the old owner's last one."""
        self.collector.forget(ids)


def trials_to_best(runtimes_in_order: Sequence[float], tol: float = 0.005
                   ) -> int:
    """Number of trials until a candidate within `tol` of the sequence's own
    best has been tried (the Fig. 5a metric)."""
    rts = np.asarray(list(runtimes_in_order), dtype=np.float64)
    if rts.size == 0:
        return 0
    target = rts.min() * (1.0 + tol)
    return int(np.argmax(rts <= target)) + 1
