"""Overload-safe serving: the chaos matrix and the degradation ladder.

Covers the robustness tentpole end to end: FaultPlan determinism (a
fault schedule is a pure function of (seed, kind, occurrence)), the
pool-level degraded ladder (migrate retry-with-backoff, pinned-to-host
fallback, apply_plan rollback), pressure-driven preemption with
bit-identical reactivation, the DecisionWorker watchdog (hang + crash
recovery, degraded-permanent sync mode), typed terminal statuses for
every submitted request, and the headline acceptance bar: a seeded
chaos run firing EVERY injection point drains without a hang and its
completed token streams are bit-identical to the fault-free run.
"""
import numpy as np
import pytest

from repro.core import OnlineTuner
from repro.core.traffic import RequestSpec
from repro.ft.inject import (FAULT_KINDS, FaultPlan, FaultPoint,
                             MigrationError, NULL_PLAN)
from repro.memtier import SharedPagedPools, TierConfig, TieringManager
from repro.obs import telemetry as _obs
from repro.serve.sched import TrafficMonitor, TrafficScheduler


# ---------------------------------------------------------------------------
# FaultPlan: determinism, windows, bookkeeping
# ---------------------------------------------------------------------------


def test_fault_plan_is_deterministic():
    def run(seed):
        plan = FaultPlan([FaultPoint("pool.migrate_fail", prob=0.5)],
                         seed=seed)
        plan.tick()
        return [plan.fires("pool.migrate_fail") is not None
                for _ in range(64)]

    a, b = run(3), run(3)
    assert a == b, "same seed must replay the same fault schedule"
    assert any(a) and not all(a), "prob=0.5 must mix hits and misses"
    assert run(4) != a, "the schedule is seed-keyed"
    plan = FaultPlan([FaultPoint("pool.migrate_fail", prob=0.5)], seed=3)
    plan.tick()
    hits = sum(plan.fires("pool.migrate_fail") is not None
               for _ in range(64))
    assert plan.fired["pool.migrate_fail"] == hits == sum(a)


def test_fault_plan_windows_and_registry():
    plan = FaultPlan([FaultPoint("pool.squeeze", start=2, stop=4, value=8)])
    hits = []
    for _ in range(6):
        plan.tick()
        hits.append(plan.fires("pool.squeeze") is not None)
    assert hits == [False, True, True, False, False, False], \
        "a point fires only inside its [start, stop) clock window"
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPoint("bogus.kind")
    assert not NULL_PLAN.enabled
    assert all(NULL_PLAN.fires(k) is None for k in FAULT_KINDS), \
        "the shared inert plan never fires"
    assert NULL_PLAN.fired == {}


# ---------------------------------------------------------------------------
# pool-level degraded ladder (no model)
# ---------------------------------------------------------------------------


def _tiny_pools():
    return SharedPagedPools.create(8, 4, page_size=2, kv_heads=1, head_dim=2)


def test_migrate_retry_exhaustion_pins_to_host():
    """An always-failing transport exhausts the retry budget, falls back
    to the degraded synchronous copy (the bytes still move -- the pages
    end up resident), pins the pages to host for a cooldown and counts
    the fetch so the serving loop can re-price it."""
    rec = _obs.install(_obs.Recorder(enabled=True))
    try:
        pools = _tiny_pools()
        pools.migrate_retries = 1
        pools.retry_backoff_s = 0.0
        pools.fault_plan = FaultPlan([FaultPoint("pool.migrate_fail")])
        gids = pools.alloc(2, owner=0)
        fetched = pools.ensure_resident(gids)
        assert fetched == 2
        assert (pools.slot_of[gids] >= 0).all(), \
            "the degraded path still makes the pages resident"
        assert pools.degraded_fetches == 2
        assert pools.host_pinned(gids).all(), \
            "retry-exhausted pages pin to host for the cooldown"
        assert pools.fault_plan.fired["pool.migrate_fail"] == 2, \
            "initial attempt + 1 retry"
        assert rec.summary()["counters"]["pool.degraded_fetches"] == 2
    finally:
        _obs.install(_obs.Recorder())


def test_apply_plan_rolls_back_on_migration_failure():
    """A failed promotion batch rolls the slot bookkeeping back (the
    pages stay host-resident, prior residents keep their slots) and
    emits ``tier.move_failed`` instead of corrupting the tables."""
    rec = _obs.install(_obs.Recorder(enabled=True))
    try:
        pools = _tiny_pools()
        pools.alloc(4, owner=0)
        pools.ensure_resident(np.asarray([0, 1]))
        before_slots = pools.slot_of.copy()
        pools.fault_plan = FaultPlan([FaultPoint("pool.migrate_fail")])
        mgr = TieringManager(8, TierConfig(page_size=2, hbm_pages=4,
                                           period_steps=1))
        mgr.apply_plan(pools, bring=np.asarray([2, 3]),
                       evict=np.asarray([], np.int64))
        np.testing.assert_array_equal(pools.slot_of, before_slots)
        assert pools.hbm_occupied == 2
        assert rec.summary()["counters"]["tier.moves_failed"] == 1
        (ev,) = rec.events("tier.move_failed")
        assert ev["pages"] == 2
    finally:
        _obs.install(_obs.Recorder())


def test_apply_plan_skips_host_pinned_pages():
    pools = _tiny_pools()
    pools.migrate_retries = 0
    pools.retry_backoff_s = 0.0
    pools.alloc(4, owner=0)
    pools.fault_plan = FaultPlan([FaultPoint("pool.migrate_fail")])
    pools.ensure_resident(np.asarray([0]))          # pins page 0
    pools.fault_plan = NULL_PLAN
    assert pools.host_pinned(np.asarray([0, 1])).tolist() == [True, False]
    pools.demote(np.asarray([0]))
    mgr = TieringManager(8, TierConfig(page_size=2, hbm_pages=4,
                                       period_steps=1))
    mgr.apply_plan(pools, bring=np.asarray([0, 1]),
                   evict=np.asarray([], np.int64))
    assert pools.slot_of[0] < 0, "pinned pages sit out the promotion plan"
    assert pools.slot_of[1] >= 0


# ---------------------------------------------------------------------------
# model-free admission TTL (TrafficScheduler)
# ---------------------------------------------------------------------------


def test_traffic_scheduler_sheds_expired_queue():
    pools = SharedPagedPools.create(64, 16)
    mgr = TieringManager(64, TierConfig(page_size=16, hbm_pages=16,
                                        period_steps=4))
    specs = [RequestSpec(i, 0, 17, 30, "sink", i) for i in range(6)]
    sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr),
                             page_size=16, max_active=2, ttl_steps=5)
    sched.run(steps=400)
    assert sched.shed > 0, "queued requests past their TTL must shed"
    assert sched.completed + sched.shed == 6, \
        "every arrival terminates: served or typed-shed, never lost"
    assert sched.rejected == sched.shed


# ---------------------------------------------------------------------------
# model-backed: chaos matrix, preemption parity, watchdog
# ---------------------------------------------------------------------------


def _stack(cfg, *, n_logical=48, hbm=16, page=4):
    pools = SharedPagedPools.create(n_logical, hbm, page_size=page,
                                    kv_heads=cfg.num_kv_heads,
                                    head_dim=cfg.head_dim)
    mgr = TieringManager(n_logical, TierConfig(page_size=page,
                                               hbm_pages=hbm,
                                               period_steps=2))
    tuner = OnlineTuner(n_logical, default_period=2, profile_steps=8,
                        trial_steps=4)
    return TrafficMonitor(pools, mgr, tuner)


def _submissions(cfg, *, sacrificial=False):
    """(arrival_step, Request-kwargs) pairs; ``ttl_steps`` entries are
    honored by the faulted run and stripped by the baseline."""
    import jax

    rng = np.random.default_rng(0)
    plens = (6, 9, 5, 8)
    steps = (12, 10, 14, 12)
    subs = [(0,
             dict(rid=i, max_new_tokens=steps[i],
                  prompt=rng.integers(0, cfg.vocab_size,
                                      size=plens[i]).astype(np.int32),
                  temperature=0.0 if i % 2 == 0 else 0.7,
                  key=jax.random.PRNGKey(10 + i)))
            for i in range(4)]
    if sacrificial:
        # rid 100 arrives inside the admit-flood window with a 1-step
        # TTL: floods past the queue bound, then expires while queued.
        # rids 101/102 arrive after the flood window with the queue
        # already over its bound: shed at submit.
        for rid, at, ttl in ((100, 0, 1), (101, 4, None), (102, 4, None)):
            subs.append((at, dict(
                rid=rid, max_new_tokens=4, ttl_steps=ttl,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=5).astype(np.int32),
                temperature=0.0, key=jax.random.PRNGKey(10 + rid))))
    return subs


def _drive(params, cfg, subs, *, plan=None, max_queue=None,
           watchdog_s=None, max_worker_restarts=3, baseline=False,
           max_steps=200):
    from repro.serve.sched import ContinuousBatcher, Request

    mon = _stack(cfg)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32,
                          page_size=4, monitor=mon, pipeline=True,
                          fault_plan=plan, max_queue=max_queue,
                          watchdog_s=watchdog_s,
                          max_worker_restarts=max_worker_restarts)
    last = max(at for at, _ in subs)
    try:
        for t in range(max_steps):
            for at, kw in subs:
                if at == t:
                    if baseline:
                        kw = {k: v for k, v in kw.items()
                              if k != "ttl_steps"}
                    b.submit(Request(**kw))
            b.step()
            if t >= last and b.idle:
                break
        assert b.idle, "no-hang: the batcher must drain under chaos"
        assert mon.pools.free_pages == mon.pools.n_logical, \
            "every page must come back to the pool"
    finally:
        b.close()
    return b, mon


@pytest.fixture(scope="module")
def served():
    """Model params + the fault-free baseline token streams (one run
    serving every submission, sacrificial rids included)."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    b, _ = _drive(params, cfg, _submissions(cfg, sacrificial=True),
                  baseline=True)
    baseline = {r.rid: list(r.tokens) for r in b.completed}
    assert len(baseline) == 7 and all(baseline.values())
    return params, cfg, baseline


def _chaos_plan():
    return FaultPlan([
        FaultPoint("pool.squeeze", start=4, stop=8, value=8),
        FaultPoint("pool.migrate_fail", start=2, stop=20, prob=0.4),
        FaultPoint("pool.migrate_slow", start=2, stop=20, prob=0.3,
                   value=0.002),
        FaultPoint("worker.delay", start=3, stop=6, prob=1.0, value=0.08),
        FaultPoint("worker.crash", start=8, stop=10, prob=1.0),
        FaultPoint("mass.nonfinite", start=2, stop=30, prob=0.5),
        FaultPoint("admit.flood", start=0, stop=2, prob=1.0),
    ], seed=7)


def test_chaos_matrix_no_hang_typed_statuses_token_parity(served):
    """The acceptance bar: a seeded plan firing EVERY injection point --
    capacity squeeze, failing + slow migrations, a hung then crashing
    decision worker (restarts exhausted into degraded-permanent sync
    mode), corrupted mass telemetry, an admission flood -- and the run
    still drains in bounded steps, every submitted request terminates
    with a typed status, and every completed stream is bit-identical to
    the fault-free run."""
    params, cfg, baseline = served
    rec = _obs.install(_obs.Recorder(enabled=True))
    try:
        b, _ = _drive(params, cfg, _submissions(cfg, sacrificial=True),
                      plan=_chaos_plan(), max_queue=1, watchdog_s=0.02,
                      max_worker_restarts=3)
    finally:
        _obs.install(_obs.Recorder())

    assert set(b.fault_plan.fired) == set(FAULT_KINDS), \
        f"chaos coverage: every injection point must fire " \
        f"(fired: {b.fault_plan.fired})"

    # every submission terminated, with a typed status
    statuses = {r.rid: r.status for r in b.completed}
    assert set(statuses) == {0, 1, 2, 3, 100, 101, 102}
    assert set(statuses.values()) <= {"completed", "shed", "expired"}
    assert all(statuses[i] == "completed" for i in range(4)), \
        "admitted requests always run to completion"
    assert b.shed >= 1 and b.expired >= 1, \
        "overload must exercise both shed-at-submit and queue expiry"
    for r in b.completed:
        if r.status == "completed":
            assert list(r.tokens) == baseline[r.rid], \
                f"request {r.rid} diverged under chaos"
        else:
            assert not r.tokens

    # the watchdog saw both failure modes and exhausted its restarts
    reasons = [e["reason"] for e in rec.events("serve.worker_restart")]
    assert {"hang", "crash"} <= set(reasons), reasons
    assert b._worker_restarts > b.max_worker_restarts
    assert b._worker_degraded, \
        "restart exhaustion must park the loop in degraded sync mode"
    assert rec.summary()["counters"]["serve.worker_restarts"] == \
        b._worker_restarts


def test_preempt_then_reactivate_is_bit_identical(served):
    """A mid-flight HBM capacity squeeze preempts the coldest active
    request (pure slot drop -- the write-through host copy IS the
    state), freezes it, thaws it when pressure lifts, and the thawed
    request resumes WITHOUT re-prefill, emitting the exact token stream
    of the fault-free run."""
    params, cfg, baseline = served
    plan = FaultPlan([FaultPoint("pool.squeeze", start=4, stop=10,
                                 value=8)], seed=0)
    rec = _obs.install(_obs.Recorder(enabled=True))
    try:
        b, _ = _drive(params, cfg, _submissions(cfg), plan=plan)
    finally:
        _obs.install(_obs.Recorder())
    assert b.preemptions >= 1, "the squeeze must force a preemption"
    counters = rec.summary()["counters"]
    assert counters["serve.preempted"] == b.preemptions
    assert counters["serve.thawed"] == b.preemptions, \
        "every frozen request must reactivate"
    assert counters["serve.admitted"] == 4, \
        "reactivation is a thaw, never a second admission/prefill"
    for ev in rec.events("serve.preempt"):
        assert ev["hbm_cap"] == 8
    got = {r.rid: list(r.tokens) for r in b.completed}
    assert got == {i: baseline[i] for i in range(4)}, \
        "preempted-then-reactivated streams must be bit-identical"


def test_worker_crash_without_watchdog_fails_loud_and_closes_clean(served):
    """Satellite regression: with no watchdog configured the injected
    decision-worker crash surfaces as the worker's exception on the
    dispatch thread (fail-loud, not fail-silent), and ``close()`` still
    shuts the batcher down cleanly mid-macro."""
    from repro.serve.sched import ContinuousBatcher, Request

    params, cfg, _ = served
    plan = FaultPlan([FaultPoint("worker.crash", start=1)])
    mon = _stack(cfg)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32,
                          page_size=4, monitor=mon, pipeline=True,
                          fault_plan=plan)
    rng = np.random.default_rng(2)
    b.submit(Request(rid=0, max_new_tokens=12,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=6).astype(np.int32)))
    with pytest.raises(RuntimeError, match="injected decision-worker"):
        for _ in range(50):
            b.step()
    b.close()                  # mid-macro close after the error: clean
    assert b._decision_worker is None or not b._decision_worker.alive
