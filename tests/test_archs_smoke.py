"""Per-architecture smoke tests on reduced configs (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the reduced config of
the same family, run one forward + one train step on CPU, assert output
shapes, finite loss in the ln(vocab) ballpark, and nonzero finite grads.
Decode consistency: prefill + token-by-token decode reproduces the full
forward logits (KV caches, ring buffers, MLA absorbed decode, recurrent
states all exercised).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as mdl
from repro.train import optim as O
from repro.train import step as S

OCFG = O.OptConfig(lr=1e-3, warmup_steps=2, decay_steps=10)


def _batch(cfg, key, b=2, t=16):
    tokens = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
    batch = {"tokens": tokens, "targets": targets}
    if cfg.prefix_len:
        batch["extra_embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.prefix_len, cfg.d_model),
            jnp.bfloat16)
        batch["targets"] = jnp.concatenate(
            [jnp.full((b, cfg.prefix_len), -1, tokens.dtype), targets], axis=1)
    if cfg.cond_len:
        batch["cond"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.cond_len, cfg.d_model),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", C.ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = C.reduced(name)
    params, specs = mdl.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = mdl.forward(params, cfg, batch["tokens"],
                              extra_embeds=batch.get("extra_embeds"),
                              cond=batch.get("cond"))
    s_exp = 16 + (cfg.prefix_len or 0)
    assert logits.shape == (2, s_exp, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # spec tree mirrors param tree
    assert (jax.tree.structure(jax.tree.map(lambda _: 0, params))
            == jax.tree.structure(jax.tree.map(
                lambda _: 0, specs,
                is_leaf=lambda x: x is None or isinstance(x, tuple))))


@pytest.mark.parametrize("name", C.ARCHS)
def test_train_step(name):
    cfg = C.reduced(name)
    state, _ = S.init_state(jax.random.PRNGKey(0), cfg, OCFG)
    batch = _batch(cfg, jax.random.PRNGKey(1), b=4)
    ts = jax.jit(S.make_train_step(cfg, OCFG))
    state2, m = ts(state, batch)
    loss = float(m["loss"])
    assert np.isfinite(loss) and 2.0 < loss < 12.0, loss
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # one more step must reduce the loss on the same batch
    _, m2 = ts(state2, batch)
    assert float(m2["loss"]) < loss


@pytest.mark.parametrize("name", C.ARCHS)
def test_grad_accumulation_matches(name):
    """accum_steps=2 must match the single-shot gradient step numerics."""
    cfg = C.reduced(name)
    state, _ = S.init_state(jax.random.PRNGKey(0), cfg, OCFG)
    batch = _batch(cfg, jax.random.PRNGKey(1), b=4)
    m1 = jax.jit(S.make_train_step(cfg, OCFG, accum_steps=1))(state, batch)[1]
    m2 = jax.jit(S.make_train_step(cfg, OCFG, accum_steps=2))(state, batch)[1]
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)


@pytest.mark.parametrize("name", C.ARCHS)
def test_decode_matches_forward(name):
    cfg = C.reduced(name)
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    b, s, pre = 2, 12, 8
    batch = _batch(cfg, jax.random.PRNGKey(1), b=b, t=s)
    tokens = batch["tokens"]
    extra, cond = batch.get("extra_embeds"), batch.get("cond")
    p = cfg.prefix_len or 0
    logits_full, _ = mdl.forward(params, cfg, tokens, extra_embeds=extra,
                                 cond=cond)
    lp, cache = mdl.prefill(params, cfg, tokens[:, :pre], extra_embeds=extra,
                            cond=cond)
    cache = mdl.pad_cache(cache, cfg, max_len=p + s)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0], np.float32),
        np.asarray(logits_full[:, p + pre - 1], np.float32), atol=4e-2)
    pos = jnp.full((b,), p + pre, jnp.int32)
    step = jax.jit(lambda c, t_, pp: mdl.decode_step(params, cfg, c, t_, pp,
                                                     cond=cond))
    for t in range(pre, s):
        lt, cache = step(cache, tokens[:, t:t + 1], pos)
        np.testing.assert_allclose(
            np.asarray(lt[:, 0], np.float32),
            np.asarray(logits_full[:, p + t], np.float32), atol=4e-2)
        pos = pos + 1


@pytest.mark.parametrize("name", ["deepseek-v3-671b", "olmoe-1b-7b"])
def test_param_count_formula(name):
    """Config param_count() within 10% of the actual reduced-init count
    (sanity for the 6ND roofline math)."""
    cfg = C.reduced(name)
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    predicted = cfg.param_count()
    assert abs(actual - predicted) / actual < 0.35, (actual, predicted)
    assert cfg.active_param_count() < cfg.param_count()


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "deepseek-v3-671b": (61, 7168, 128, 128, None, 129280),
        "olmoe-1b-7b": (16, 2048, 16, 16, 0, 50304),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for name, (nl, d, h, kv, ff, v) in spec.items():
        cfg = C.get(name)
        assert cfg.num_layers == nl, (name, cfg.num_layers)
        assert cfg.d_model == d and cfg.num_heads == h
        assert cfg.num_kv_heads == kv and cfg.vocab_size == v
        if ff is not None and ff > 0:
            assert cfg.d_ff == ff
    # MoE specifics
    ds = C.get("deepseek-v3-671b")
    assert ds.moe.num_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.d_expert == 2048 and ds.moe.num_shared == 1
    ol = C.get("olmoe-1b-7b")
    assert ol.moe.num_experts == 64 and ol.moe.top_k == 8
    # gemma3 local:global 5:1
    g = C.get("gemma3-12b")
    pat = g.segments[0][0]
    assert pat.count("local") == 5 and pat.count("attn") == 1
    # recurrentgemma 2:1 recurrent:attention
    r = C.get("recurrentgemma-2b")
    kinds = [k.base for k in r.layer_kinds()]
    assert kinds.count("rglru") == 18 and kinds.count("local") == 8


def test_long_context_support_flags():
    """long_500k runs for SSM/hybrid/local-heavy archs only (DESIGN.md)."""
    runnable = {a for a, s in C.cells() if s == "long_500k"}
    assert runnable == {"xlstm-1.3b", "recurrentgemma-2b", "gemma3-12b"}
    # full matrix = every arch x every shape, derived from the registry
    assert len(C.cells(include_skipped=True)) == len(C.ARCHS) * len(C.SHAPES)


def test_mla_chunked_attention_dv_neq_dqk():
    """_sdpa_chunked must handle d_v != d_qk (MLA) when query chunking
    engages (seq > chunk); regression for the deepseek prefill_32k cell."""
    from repro.models.layers import _sdpa_chunked
    b, s, h, dq, dv = 1, 1024, 2, 24, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dq))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dq))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, dv))
    mask = jnp.tril(jnp.ones((s, s), bool))
    out_chunked = _sdpa_chunked(q, k, v, mask, 0.0, q_chunk=256)
    out_single = _sdpa_chunked(q, k, v, mask, 0.0, q_chunk=s)
    assert out_chunked.shape == (b, s, h, dv)
    np.testing.assert_allclose(np.asarray(out_chunked),
                               np.asarray(out_single), atol=1e-5)
