"""Flight recorder: structured tracing + metrics for the whole stack.

``repro.obs`` is the observability substrate the tuner, tiering manager,
paged pools and serving scheduler report into.  See
``docs/observability.md`` for the event taxonomy and exporter formats.

Hot-path idiom (what every instrumented module does)::

    from repro import obs
    ...
    if (r := obs.RECORDER).enabled:
        r.emit("tuner.transition", tuner=self._obs_id, step=step, ...)

Reading ``RECORDER`` through the module attribute (never ``from repro.obs
import RECORDER``) is load-bearing: ``install()`` rebinds the attribute,
so a fresh recorder takes effect everywhere at once.
"""
from repro.obs import telemetry as telemetry
from repro.obs.events import EVENTS, Event, RESERVED_FIELDS
from repro.obs.export import (SCHEMA, perfetto_trace, read_jsonl,
                              write_jsonl, write_perfetto)
from repro.obs.telemetry import Histogram, Recorder, get, install

__all__ = [
    "EVENTS", "Event", "RESERVED_FIELDS",
    "Histogram", "Recorder", "RECORDER", "install", "get",
    "SCHEMA", "write_jsonl", "read_jsonl", "perfetto_trace",
    "write_perfetto",
]


def __getattr__(name):
    # RECORDER must stay live across install(): delegate to telemetry's
    # module attribute instead of snapshotting it at import time.
    if name == "RECORDER":
        return telemetry.RECORDER
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
