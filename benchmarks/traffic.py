"""Traffic benchmark: the scheduler-fed online tuner vs a brute-force
fixed-period sweep, on a Poisson arrival stream whose mix shifts mid-run.

Phase A serves zipf random-retrieval requests (long-period friendly),
phase B drifting attention-sink requests (short-period friendly); all
requests share one HBM slot pool through ``serve.sched``.  Reports:

  * end-state (final-window) modeled cost of the online run vs every
    fixed period -- the acceptance bar is online <= 1.05x the best fixed;
  * peak cache memory of the bucket-rounded paged rows vs the dense
    packed-cache provisioning (``max_active`` rows of the longest
    request's footprint, held for the whole run) -- the fully-paged
    acceptance bar is >= 25% reduction on this mixed-length stream;
  * the token-parity check: a multi-request ``ContinuousBatcher`` running
    the FULLY-PAGED decode (every attention layer gathered from
    ``SharedPagedPools`` by ``kernels.paged_attention``) must emit
    token-identical output to per-request ``generate`` for the same
    prompts/keys, and the paged kernel's gather from the shared HBM pool
    must match the host-leaf reference;
  * wall-clock serving throughput (``serving_perf``): the macro-step
    decode loop (one device launch per movement period) vs the per-token
    paged loop -- tokens/sec (== decode token-steps/sec) and per-
    scheduler-step p50/p95 latency -- with the four-way bit-parity bar
    (dense == per-token paged == macro-step == per-request generate).
    Written to ``BENCH_serving.json`` so the serving perf trajectory is
    tracked across PRs.
  * the hostile-traffic replay (``hostile``): the online tuner rides a
    four-phase adversarial stream (plain Poisson, then flash crowds,
    correlated bursts and a diurnal swing -- ``repro.core.traffic``) and
    its per-phase regret vs the best fixed period must stay <= 1.15x in
    EVERY phase, plus a deterministic poisoned-TRIAL demo asserting the
    cost-spike guardrail reverts to the last attested period.  Written to
    ``BENCH_hostile.json``; both bars are asserted under ``--smoke``.

    PYTHONPATH=src python -m benchmarks.traffic [--quick | --smoke]
"""
from __future__ import annotations

import gc
import os
import time
from typing import Dict, Optional

import numpy as np

from benchmarks.common import out_dir, save_json
from repro import obs
from repro.core import OnlineTuner, shifting_mix_stream
from repro.memtier import SharedPagedPools, TierConfig, TieringManager
from repro.serve.sched import TrafficMonitor, TrafficScheduler

N_LOGICAL, HBM_PAGES, PAGE = 256, 32, 16
MAX_ACTIVE = 8
FIXED = (1, 2, 4, 8, 16, 32, 64, 200)
STEADY_WINDOW = 150

# Heavy-tailed mixed-length traffic (the serving shape bucketing is for):
# most requests are short (2..6 pages), an occasional long one spans up
# to the 16-page row cap.  A dense packed cache must provision EVERY row
# for the worst case; bucket-rounded paged rows pay their own
# power-of-two class.
SHORT = dict(rate=0.09, prompt_len=(8, 40), new_tokens=(24, 56))
LONG = dict(rate=0.015, prompt_len=(48, 104), new_tokens=(112, 152))


def _stream(phase_steps: int, seed: int = 0):
    import dataclasses

    def phases(rate, prompt_len, new_tokens, s):
        return shifting_mix_stream(
            [(phase_steps, rate, {"random": 1.0}),
             (phase_steps, rate, {"sink": 1.0})],
            prompt_len=prompt_len, new_tokens=new_tokens, seed=s)

    merged = sorted(phases(s=seed, **SHORT) + phases(s=seed + 1, **LONG),
                    key=lambda r: (r.arrival, r.rid))
    return [dataclasses.replace(r, rid=i) for i, r in enumerate(merged)]


def _run(specs, steps: int, *, period: int = 8,
         tuner: Optional[OnlineTuner] = None, probe_at: Optional[int] = None):
    """Replay one stream; returns (scheduler, manager, tuner,
    modeled_time at ``probe_at``) -- the probe turns one run into an exact
    final-window cost, the replays being deterministic."""
    pools = SharedPagedPools.create(N_LOGICAL, HBM_PAGES)
    mgr = TieringManager(N_LOGICAL, TierConfig(
        page_size=PAGE, hbm_pages=HBM_PAGES, period_steps=period))
    sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr, tuner),
                             page_size=PAGE, max_active=MAX_ACTIVE)
    probe = 0.0
    for t in range(steps):
        if t == probe_at:
            probe = mgr.modeled_time
        sched.step()
    return sched, mgr, tuner, probe


def run(quick: bool = False) -> Dict:
    phase = 400 if quick else 700
    steps = 2 * phase
    lo = steps - STEADY_WINDOW
    specs = _stream(phase)

    # heavy-tailed traffic makes short cost windows noisy (a trial's cost
    # depends on which requests happen to be in flight): 96-step trials
    # average over several request lifetimes so the ladder ranks stably
    tuner = OnlineTuner(N_LOGICAL, default_period=8,
                        drift_ratio=1.5, drift_patience=3, trial_steps=96)
    sched, mgr, tuner, probe = _run(specs, steps, tuner=tuner, probe_at=lo)
    online_steady = (mgr.modeled_time - probe) / STEADY_WINDOW

    fixed = {}
    for p in FIXED:
        _, m, _, pr = _run(specs, steps, period=p, probe_at=lo)
        fixed[str(p)] = {"total": m.modeled_time,
                         "steady": (m.modeled_time - pr) / STEADY_WINDOW}
    best_steady = min(v["steady"] for v in fixed.values())
    best_total = min(v["total"] for v in fixed.values())

    out = {
        "steps": steps,
        "requests": {"submitted": len(specs), "admitted": sched.admitted,
                     "completed": sched.completed},
        "cache_memory": {
            "peak_paged_pages": sched.peak_cache_pages,
            "dense_pages": sched.dense_cache_pages,
            "row_pages": sched.row_pages,
            "reduction": 1.0 - sched.peak_cache_pages
            / max(1, sched.dense_cache_pages),
        },
        "online": {
            "total": mgr.modeled_time,
            "steady": online_steady,
            "final_period": tuner.period,
            "state": tuner.state,
            "tune_cycles": tuner.retunes,
            "period_history": tuner.history,
        },
        "fixed": fixed,
        "online_vs_best_fixed_steady": online_steady / best_steady,
        "online_vs_best_fixed_total": mgr.modeled_time / best_total,
        "token_parity": _token_parity(quick),
    }
    save_json("traffic", out)
    return out


HOSTILE_MIX = {"random": 0.7, "sink": 0.3}
HOSTILE_FIXED = (1, 2, 4, 8, 16, 64)


def _hostile_stream(phase_steps: int, seed: int = 0):
    """Four phases of identical mix and mean rate, escalating hostility:
    plain Poisson, flash crowds, correlated bursts, a diurnal swing.  The
    optimum barely moves across phases, so any per-phase regret the online
    run shows is the hostile *shape* shaking the tuner -- exactly what the
    guardrail/variance/warm-retune defenses exist to prevent."""
    rate = 0.09
    return shifting_mix_stream(
        [(phase_steps, rate, HOSTILE_MIX),
         (phase_steps, rate, HOSTILE_MIX,
          {"gen": "flash_crowd", "spike_factor": 6.0, "spike_every": 120,
           "spike_len": 10}),
         (phase_steps, rate, HOSTILE_MIX, {"gen": "burst", "burst_size": 5}),
         (phase_steps, rate, HOSTILE_MIX,
          # swing period deliberately NOT scaled with phase length: a
          # 300-step cycle is what a drift detector with ~35-step windows
          # and patience 3 must ride out -- much slower swings are
          # indistinguishable from genuine regime changes and SHOULD
          # re-tune
          {"gen": "diurnal", "swing_period": 300, "amplitude": 0.6})],
        prompt_len=(16, 48), new_tokens=(40, 100), seed=seed)


def _trajectory(specs, steps: int, *, period: int = 8,
                tuner: Optional[OnlineTuner] = None):
    """Replay one stream recording the full modeled-time trajectory, so one
    deterministic run yields the exact cost of every phase window."""
    pools = SharedPagedPools.create(N_LOGICAL, HBM_PAGES)
    mgr = TieringManager(N_LOGICAL, TierConfig(
        page_size=PAGE, hbm_pages=HBM_PAGES, period_steps=period))
    sched = TrafficScheduler(specs, TrafficMonitor(pools, mgr, tuner),
                             page_size=PAGE, max_active=MAX_ACTIVE)
    traj = np.zeros(steps + 1)
    for t in range(steps):
        sched.step()
        traj[t + 1] = mgr.modeled_time
    return sched, tuner, traj


def _poisoned_trial_revert() -> Dict:
    """Deterministic guardrail demo: converge a tuner on a clean synthetic
    workload (attesting period 8 at cost ~1), force a re-tune sweep, then
    poison the TRIAL windows with a spiky cost (whole period-buckets
    alternating 300x/clean).  The cost-spike guardrail must abort the
    sweep and revert to the attested period instead of crowning whichever
    candidate the spikes happened to spare."""
    tuner = OnlineTuner(64, default_period=2, profile_steps=32,
                        trial_steps=32, horizon_steps=64, bin_width=1,
                        patience=3)
    ids = lambda t: np.array([t % 4])        # every reuse gap is exactly 4
    for t in range(600):
        tuner.on_step(accessed_ids=ids(t), cost=abs(tuner.period - 8) + 1.0)
    attested = tuner.last_good_period
    tuner._reprofile()                       # force the re-tune sweep
    poisoned_steps = 0
    while tuner.state == OnlineTuner.TRIAL and poisoned_steps < 200:
        c = 300.0 if (poisoned_steps // 8) % 2 == 0 else 1.0
        tuner.on_step(accessed_ids=ids(poisoned_steps), cost=c)
        poisoned_steps += 1
    return {
        "attested_period": attested,
        "final_period": tuner.period,
        "state": tuner.state,
        "guard_trips": tuner.guard_trips,
        "steps_to_abort": poisoned_steps,
        "reverted": (tuner.state == OnlineTuner.HOLD
                     and tuner.period == attested
                     and tuner.guard_trips >= 1),
    }


def hostile(quick: bool = False) -> Dict:
    phase = 350 if quick else 600
    window = 120 if quick else 150
    steps = 4 * phase
    specs = _hostile_stream(phase)

    # a fresh flight recorder isolates the online run's event stream: the
    # JSONL written below is the full tuner decision timeline of exactly
    # this trajectory (fixed-period replays never pollute it)
    rec = obs.install(obs.Recorder())
    # shorter profile/trial windows than run(): the tuner must be settled
    # well before the first phase window closes, and the variance-scaled
    # extension recovers the averaging when a phase is genuinely noisy
    tuner = OnlineTuner(N_LOGICAL, default_period=8, profile_steps=48,
                        trial_steps=24, drift_ratio=1.5, drift_patience=3)
    sched, tuner, online_traj = _trajectory(specs, steps, tuner=tuner)
    events_jsonl = obs.write_jsonl(out_dir() / "hostile_events.jsonl", rec)
    metrics = {"schema": obs.SCHEMA, **rec.summary()}
    fixed_traj = {p: _trajectory(specs, steps, period=p)[2]
                  for p in HOSTILE_FIXED}

    names = ("poisson", "flash_crowd", "burst", "diurnal")
    phases = []
    for i, name in enumerate(names):
        e = (i + 1) * phase
        s = e - window
        online_cost = (online_traj[e] - online_traj[s]) / window
        fixed = {str(p): (tr[e] - tr[s]) / window
                 for p, tr in fixed_traj.items()}
        best = min(fixed.values())
        phases.append({"phase": name, "online_steady": online_cost,
                       "fixed_steady": fixed, "best_fixed": best,
                       "regret": online_cost / best})

    out = {
        "steps": steps,
        "requests": {"submitted": len(specs), "admitted": sched.admitted,
                     "completed": sched.completed},
        "phases": phases,
        "max_regret": max(p["regret"] for p in phases),
        "tuner": {"final_period": tuner.period, "state": tuner.state,
                  "tune_cycles": tuner.retunes,
                  "guard_trips": tuner.guard_trips,
                  "window_extensions": tuner.window_extensions,
                  "period_history": tuner.history},
        "poisoned_trial": _poisoned_trial_revert(),
        # the flight-recorder view of the same online run (see
        # docs/observability.md for the schema): replay the JSONL with
        # ``python -m repro.obs.report`` for the decision trace
        "metrics": metrics,
        "events_jsonl": str(events_jsonl),
    }
    save_json("BENCH_hostile", out)
    return out


def _print_hostile(ho: Dict) -> None:
    for p in ho["phases"]:
        print(f"hostile[{p['phase']:>11s}]: online {p['online_steady']:8.2f}"
              f"/step vs best fixed {p['best_fixed']:8.2f} "
              f"(regret {p['regret']:.3f}x)")
    t = ho["tuner"]
    print(f"hostile tuner: period={t['final_period']} ({t['state']}), "
          f"{t['tune_cycles']} tune cycles, {t['guard_trips']} guard trips, "
          f"{t['window_extensions']} window extensions")
    pt = ho["poisoned_trial"]
    print(f"poisoned trial: reverted={pt['reverted']} "
          f"(period {pt['final_period']} == attested "
          f"{pt['attested_period']}, {pt['guard_trips']} guard trips, "
          f"abort after {pt['steps_to_abort']} poisoned steps)")


def _token_parity(quick: bool) -> Dict:
    """Fully-paged multi-request decode over SharedPagedPools (every
    attention layer through ``kernels.paged_attention``) == per-request
    generate, and the paged kernel's shared-HBM gather == the host-leaf
    reference."""
    import jax
    import jax.numpy as jnp

    import repro.configs as C
    from repro.kernels import ops
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 3 if quick else 4
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 10)))
               .astype(np.int32) for _ in range(n_req)]
    new_tokens = [int(rng.integers(4, 8)) for _ in range(n_req)]
    keys = [jax.random.PRNGKey(100 + i) for i in range(n_req)]

    page = 4
    pools = SharedPagedPools.create(48, 16)
    mgr = TieringManager(48, TierConfig(page_size=page, hbm_pages=16,
                                        period_steps=2))
    mon = TrafficMonitor(pools, mgr,
                         OnlineTuner(48, default_period=2, profile_steps=8,
                                     trial_steps=4))
    batcher = ContinuousBatcher(params, cfg, max_active=2, max_len=32,
                                page_size=page, monitor=mon)
    assert batcher.paged, "gemma3 must take the fully-paged decode path"
    for i in range(n_req):
        batcher.submit(Request(rid=i, prompt=prompts[i],
                               max_new_tokens=new_tokens[i], key=keys[i],
                               temperature=0.7 if i % 2 else 0.0))
    # after a few steps, validate the shared-pool paged gather path
    for _ in range(3):
        batcher.step()
    kernel_diff = 0.0
    if batcher.active:
        req = next(iter(batcher.active.values()))
        q = jax.random.normal(jax.random.PRNGKey(7),
                              (1, cfg.num_heads, cfg.head_dim))
        out, _ = batcher.paged_context(req.rid, q)
        length = int(np.asarray(batcher.pos)[req.row])
        n = -(-length // page)
        tbl = jnp.asarray(req.gids[:n], jnp.int32)[None]
        li = mdl.attn_slot_index(cfg, batcher._si, batcher._sj)
        ref = ops.paged_attention(q, pools.kv_layers["k_host"][li][-1],
                                  pools.kv_layers["v_host"][li][-1], tbl,
                                  jnp.asarray([length], jnp.int32),
                                  impl="reference")
        kernel_diff = float(jnp.abs(out - ref).max())
    got = batcher.run()

    matches = []
    for i in range(n_req):
        ref = np.asarray(generate(
            params, cfg, jnp.asarray(prompts[i])[None],
            steps=new_tokens[i], temperature=0.7 if i % 2 else 0.0,
            key=keys[i]))[0].tolist()
        matches.append(ref == got[i])
    return {"requests": n_req, "decode_mode": "fully-paged",
            "token_identical": all(matches),
            "paged_kernel_max_diff": kernel_diff,
            "pages_all_released": pools.free_pages == pools.n_logical}


def mla(quick: bool = False) -> Dict:
    """Paged MLA admission on the shared slot pool (deepseek-v3): requests
    hold bucket-rounded compressed ``ckv``/``krope`` pages instead of a
    dense ``max_active x max_len`` row cache, so peak provisioning drops
    by the mixed-length slack — the tentpole bar is >= 1.5x fewer pages
    than dense provisioning, token streams bit-identical to per-request
    ``generate``.  Written to ``traffic_mla.json``."""
    import jax
    import jax.numpy as jnp

    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("deepseek-v3-671b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 4 if quick else 8
    page, max_len, max_active = 4, 64, 4
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(6, 13))).astype(np.int32)
               for _ in range(n_req)]
    budgets = [int(rng.integers(8, 17)) for _ in range(n_req)]
    temps = [0.0 if i % 2 == 0 else 0.7 for i in range(n_req)]
    keys = [jax.random.PRNGKey(200 + i) for i in range(n_req)]

    n_logical, hbm = 96, 48
    pools = SharedPagedPools.create(n_logical, hbm)
    mgr = TieringManager(n_logical, TierConfig(page_size=page,
                                               hbm_pages=hbm,
                                               period_steps=2))
    mon = TrafficMonitor(pools, mgr,
                         OnlineTuner(n_logical, default_period=2,
                                     profile_steps=8, trial_steps=4))
    b = ContinuousBatcher(params, cfg, max_active=max_active,
                          max_len=max_len, page_size=page, monitor=mon,
                          macro=True, macro_steps=4)
    assert b.paged and b.macro, \
        "deepseek-v3 (MLA) must take the paged macro path"
    for i in range(n_req):
        b.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i],
                         key=keys[i], temperature=temps[i]))
    got = b.run()

    matches = []
    for i in range(n_req):
        ref = np.asarray(generate(params, cfg, jnp.asarray(prompts[i])[None],
                                  steps=budgets[i], temperature=temps[i],
                                  key=keys[i]))[0].tolist()
        matches.append(ref == got[i])

    # dense provisioning: every row carries max_len tokens of cache for
    # the whole run; paged provisioning peaks at the worst co-resident
    # sum of bucket-rounded compressed rows
    dense_pages = max_active * (max_len // page)
    peak_paged = int(pools.peak_allocated)
    out = {
        "arch": "deepseek-v3-671b",
        "decode_mode": "paged-macro",
        "requests": n_req,
        "token_identical": all(matches),
        "dense_pages": dense_pages,
        "peak_paged_pages": peak_paged,
        "page_reduction_x": dense_pages / max(1, peak_paged),
        "pages_all_released": pools.free_pages == pools.n_logical,
    }
    save_json("traffic_mla", out)
    return out


def _print_mla(m: Dict) -> None:
    print(f"mla[deepseek-v3]: peak paged {m['peak_paged_pages']} pages vs "
          f"dense {m['dense_pages']} ({m['page_reduction_x']:.2f}x "
          f"reduction); token-identical: {m['token_identical']}; "
          f"pages released: {m['pages_all_released']}")


def serving_perf(quick: bool = False) -> Dict:
    """Wall-clock serving throughput: macro-step vs per-token paged decode.

    Each mode serves two identical request waves over one batcher: wave 1
    warms the jit caches, wave 2 is timed.  ``tokens_per_sec`` counts
    decode token-steps served per wall second (the throughput the macro
    loop exists to raise); latency percentiles are per ``step()`` call
    (one token for the per-token path, one movement period for macro).
    The parity field pins the tentpole bar: every mode's wave-2 streams
    bit-identical to per-request ``generate``.

    Also measures the flight recorder's cost on the macro hot loop:
    alternating telemetry-enabled/disabled waves over one warmed batcher;
    the ``telemetry_overhead.ratio`` is the median of pairwise per-rep
    ratios (adjacent measurements cancel machine drift).  The CI bar is
    enabled throughput within 3% of disabled on hosts with >= 2 cores;
    single-core hosts cannot resolve 3% and the smoke floor widens to
    0.90 (see ``overlap_parallel_substrate``)."""
    import jax
    import jax.numpy as jnp

    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rec = obs.install(obs.Recorder())
    rng = np.random.default_rng(0)
    n_req = 4 if quick else 8
    page, max_len, max_active = 4, 64, 4
    macro_len = 8
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(5, 12))).astype(np.int32)
               for _ in range(n_req)]
    budgets = [int(rng.integers(10, 16)) for _ in range(n_req)]
    temps = [0.0 if i % 2 == 0 else 0.7 for i in range(n_req)]
    keys = [jax.random.PRNGKey(50 + i) for i in range(n_req)]

    def build(mode):
        pools = SharedPagedPools.create(192, 64)
        mgr = TieringManager(192, TierConfig(page_size=page, hbm_pages=64,
                                             period_steps=macro_len))
        mon = TrafficMonitor(pools, mgr,
                             OnlineTuner(192, default_period=macro_len,
                                         profile_steps=16, trial_steps=8))
        macro = mode in ("macro", "pipelined")
        return ContinuousBatcher(params, cfg, max_active=max_active,
                                 max_len=max_len, page_size=page,
                                 monitor=mon, paged=(mode != "dense"),
                                 macro=macro,
                                 macro_steps=(macro_len if macro else None),
                                 pipeline=(mode == "pipelined"))

    def submit_wave(b, wave):
        for i in range(n_req):
            b.submit(Request(rid=wave * n_req + i, prompt=prompts[i],
                             max_new_tokens=budgets[i], key=keys[i],
                             temperature=temps[i]))

    def drive(b):
        tokens, lats = 0, []
        while not b.idle:       # pipelined tail: in-flight macro, pendings
            t0 = time.perf_counter()
            out = b.step()
            lats.append(time.perf_counter() - t0)
            tokens += len(out)
        return tokens, lats

    refs = [np.asarray(generate(params, cfg, jnp.asarray(prompts[i])[None],
                                steps=budgets[i], temperature=temps[i],
                                key=keys[i]))[0].tolist()
            for i in range(n_req)]

    modes = ("paged", "macro", "pipelined", "dense")
    results: Dict[str, Dict] = {}
    parity: Dict[str, bool] = {}
    for mode in modes:
        b = build(mode)
        submit_wave(b, 0)                    # warm the jit caches
        drive(b)
        n_admits = len(rec.events("serve.admit"))
        submit_wave(b, 1)                    # timed wave
        t0 = time.perf_counter()
        tokens, lats = drive(b)
        wall = time.perf_counter() - t0
        b.close()
        lat_ms = np.asarray(lats) * 1e3
        results[mode] = {
            "tokens": tokens,
            "wall_s": wall,
            # decode token-steps/sec == tokens/sec: every emitted token
            # is one request-token-step (the satellite's "steps/sec")
            "tokens_per_sec": tokens / wall,
            "sched_steps": len(lats),
            "latency_ms_p50": float(np.percentile(lat_ms, 50)),
            "latency_ms_p95": float(np.percentile(lat_ms, 95)),
        }
        # p95 admission stall over the timed wave, from the flight
        # recorder's serve.admit walls: reservation-to-activation for
        # the pipelined loop (stall_ms), prefill dispatch wall for the
        # synchronous paths (admission is inline there)
        admits = rec.events("serve.admit")[n_admits:]
        stalls = [e.get("stall_ms", e["wall_ms"]) for e in admits]
        if stalls:
            results[mode]["admission_stall_ms_p95"] = float(
                np.percentile(np.asarray(stalls), 95))
        got = {r.rid: list(r.tokens) for r in b.completed}
        parity[mode] = all(got.get(n_req + i) == refs[i]
                           for i in range(n_req))

    # the overlap A-B: one warmed batcher per mode serves an identical
    # DOUBLE wave (2 x n_req over max_active rows, so joiners keep
    # prefilling while earlier rows decode -- the admission pressure the
    # overlap window exists to hide), interleaved best-of-3 so machine
    # drift hits both modes alike.  This is the assertable bar; the
    # single-wave rows above are per-mode latency reporting.
    ab = {m: build(m) for m in ("macro", "pipelined")}
    for b in ab.values():
        submit_wave(b, 0)                    # warm the jit caches
        drive(b)
    # machine noise here is low-frequency drift (whole phases speed up
    # and slow down), so the assertable ratio is the MEDIAN of pairwise
    # per-rep ratios -- adjacent measurements see the same machine state
    # and the drift cancels -- not a ratio of two independent bests
    ab_best = {m: 0.0 for m in ab}
    ab_ratios = []
    ab_wave = 1
    for rep in range(7):
        order = list(ab.items())
        if rep % 2:                      # alternate so order bias cancels
            order.reverse()
        per = {}
        for m, b in order:
            submit_wave(b, ab_wave)
            submit_wave(b, ab_wave + 1)
            ab_wave += 2
            gc.collect()                 # no GC pause inside the window
            t0 = time.perf_counter()
            tokens, _ = drive(b)
            per[m] = tokens / (time.perf_counter() - t0)
            ab_best[m] = max(ab_best[m], per[m])
        ab_ratios.append(per["pipelined"] / per["macro"])
    for b in ab.values():
        b.close()

    # telemetry overhead on the macro hot loop: one warmed batcher serves
    # alternating enabled/disabled DOUBLE waves (interleaved so machine
    # drift hits both modes alike; doubled so each timed window is long
    # enough that a GC pause or scheduler blip cannot masquerade as
    # recorder overhead), best-of-3 per mode
    b = build("macro")
    submit_wave(b, 0)
    drive(b)
    best = {True: 0.0, False: 0.0}
    oh_ratios = []
    wave = 1
    for rep in range(9):
        order = (True, False) if rep % 2 == 0 else (False, True)
        per = {}
        for enabled in order:
            rec.enabled = enabled
            submit_wave(b, wave)
            submit_wave(b, wave + 1)
            wave += 2
            gc.collect()                 # no GC pause inside the window
            t0 = time.perf_counter()
            tokens, _ = drive(b)
            per[enabled] = tokens / (time.perf_counter() - t0)
            best[enabled] = max(best[enabled], per[enabled])
        oh_ratios.append(per[True] / per[False])
    rec.enabled = True
    # same drift-robust estimator as the overlap A-B: median of pairwise
    # per-rep ratios, not a ratio of independent bests
    overhead = {"enabled_tok_s": best[True], "disabled_tok_s": best[False],
                "ratio": float(np.median(oh_ratios))}

    out = {
        "n_requests": n_req,
        "max_active": max_active,
        "macro_len": macro_len,
        "modes": results,
        "speedup_macro_vs_per_token": (results["macro"]["tokens_per_sec"]
                                       / results["paged"]["tokens_per_sec"]),
        # the overlap A-B: the pipelined loop vs the synchronous macro
        # loop under sustained admission -- overlap may only move work,
        # so any throughput delta is boundary host time (decision,
        # prefill, prefetch, tables) hidden behind the in-flight scan
        "overlap_ab": {"sync_tok_s": ab_best["macro"],
                       "pipelined_tok_s": ab_best["pipelined"],
                       "per_rep_ratios": ab_ratios},
        "speedup_overlap_vs_sync": float(np.median(ab_ratios)),
        # overlap needs somewhere to overlap INTO: on a single-core host
        # the in-flight scan and the boundary work time-slice the same
        # core, so wall time is conserved and the honest ceiling for the
        # A-B ratio is 1.0 (the smoke bar degrades to no-regression)
        "overlap_parallel_substrate": (os.cpu_count() or 1) >= 2,
        "parity_vs_generate": parity,
        "token_identical_all_modes": all(parity.values()),
        "telemetry_overhead": overhead,
        # the flight-recorder metrics of this whole benchmark run (see
        # docs/observability.md for the schema)
        "metrics": {"schema": obs.SCHEMA, **rec.summary()},
    }
    save_json("BENCH_serving", out)
    return out


def _print_serving(sp: Dict) -> None:
    for mode, r in sp["modes"].items():
        stall = r.get("admission_stall_ms_p95")
        print(f"serving[{mode:>9s}]: {r['tokens_per_sec']:8.1f} tok/s  "
              f"step p50 {r['latency_ms_p50']:7.2f} ms  "
              f"p95 {r['latency_ms_p95']:7.2f} ms  "
              f"({r['tokens']} tokens / {r['sched_steps']} sched steps"
              + (f"; admit stall p95 {stall:.1f} ms" if stall is not None
                 else "") + ")")
    print(f"macro-step speedup vs per-token paged: "
          f"{sp['speedup_macro_vs_per_token']:.2f}x; "
          f"overlap (pipelined vs sync macro): "
          f"{sp['speedup_overlap_vs_sync']:.2f}x; "
          f"token-identical (all modes vs generate): "
          f"{sp['token_identical_all_modes']}")
    ov = sp["telemetry_overhead"]
    print(f"telemetry overhead: enabled {ov['enabled_tok_s']:.0f} tok/s vs "
          f"disabled {ov['disabled_tok_s']:.0f} "
          f"(ratio {ov['ratio']:.3f})")


def overload(quick: bool = False) -> Dict:
    """Overload serving A-B: FIFO-forever vs graceful degradation.

    One heavy-tailed request stream arrives ~4x faster than the pool
    drains it.  The *baseline* batcher serves strict FIFO forever --
    every request is eventually served, including ones whose deadline
    passed long ago.  The *degraded* batcher turns on the overload
    ladder (docs/robustness.md): per-request admission TTLs (queued
    requests past their deadline shed with a typed status), a bounded
    submit queue (floods shed at submit instead of queueing without
    bound), and a deterministic mid-run HBM capacity squeeze exercising
    pressure preemption.  Both runs are scored by the SAME external
    rule -- tokens of requests that completed within ``ttl`` steps of
    arrival, per wall second (goodput) -- so shedding is only rewarded
    when the work it abandons was already worthless.  The degradation
    never trades fidelity: every stream the degraded run completes must
    be bit-identical to per-request ``generate``.  Written to
    ``BENCH_overload.json``."""
    import jax
    import jax.numpy as jnp

    import repro.configs as C
    from repro.ft.inject import FaultPlan, FaultPoint
    from repro.models import model as mdl
    from repro.serve.engine import generate
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 32 if quick else 48
    ttl = 8
    page, max_len, max_active = 4, 64, 4
    n_logical, hbm = 96, 24
    # heavy-tailed: 3 in 4 short, 1 in 4 long; 8 arrivals per scheduler
    # step -- far past what max_active rows can drain inside a TTL, so
    # roughly half the offered work is doomed at arrival and a FIFO
    # server burns its wall clock on it anyway
    specs = []
    for i in range(n_req):
        long_req = i % 4 == 3
        specs.append(dict(
            arrival=i // 8,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=9 if long_req else 5).astype(np.int32),
            budget=24 if long_req else 8,
            temp=0.7 if i % 2 else 0.0))

    def build(degrade: bool):
        pools = SharedPagedPools.create(n_logical, hbm)
        mgr = TieringManager(n_logical, TierConfig(page_size=page,
                                                   hbm_pages=hbm,
                                                   period_steps=4))
        mon = TrafficMonitor(pools, mgr,
                             OnlineTuner(n_logical, default_period=4,
                                         profile_steps=16, trial_steps=8))
        # BOTH runs face the identical deterministic mid-stream capacity
        # squeeze (the preemption ladder fires inside the measured
        # window; parity still holds -- preemption is a freeze, never a
        # token change).  Only the overload *policy* differs between the
        # modes: TTL shedding + the bounded queue.
        plan = FaultPlan([FaultPoint("pool.squeeze", start=6, stop=10,
                                     value=hbm // 2)], seed=0)
        return ContinuousBatcher(params, cfg, max_active=max_active,
                                 max_len=max_len, page_size=page,
                                 monitor=mon, macro=True, macro_steps=4,
                                 fault_plan=plan,
                                 max_queue=4 if degrade else None)

    def drive(b, *, base: int, degrade: bool):
        done_step: Dict[int, int] = {}
        lats = []
        t = 0
        pending = list(enumerate(specs))
        seen = len(b.completed)
        t0 = time.perf_counter()
        while pending or not b.idle:
            while pending and pending[0][1]["arrival"] <= t:
                i, s = pending.pop(0)
                b.submit(Request(rid=base + i, prompt=s["prompt"],
                                 max_new_tokens=s["budget"],
                                 temperature=s["temp"],
                                 key=jax.random.PRNGKey(300 + i),
                                 ttl_steps=ttl if degrade else None))
            s0 = time.perf_counter()
            b.step()
            lats.append(time.perf_counter() - s0)
            for r in b.completed[seen:]:
                done_step[r.rid - base] = t
            seen = len(b.completed)
            t += 1
            assert t < 3000, "overload drive must drain"
        return done_step, lats, time.perf_counter() - t0

    results: Dict[str, Dict] = {}
    parity = True
    for mode in ("baseline", "degraded"):
        degrade = mode == "degraded"
        b = build(degrade)
        # warm wave: the identical stream once over, so both prefill
        # shape buckets and the macro bodies are jitted before timing
        drive(b, base=10_000, degrade=degrade)
        # the warm wave consumed the squeeze window's clock span; rewind
        # the plan clock so the squeeze hits the timed wave
        b.fault_plan.clock = 0
        n0 = len(b.completed)
        pre_preempt = b.preemptions
        done_step, lats, wall = drive(b, base=0, degrade=degrade)
        timed = b.completed[n0:]
        status = {"completed": 0, "shed": 0, "expired": 0}
        good = total = 0
        for r in timed:
            status[r.status or "completed"] += 1
            total += len(r.tokens)
            if (r.status == "completed"
                    and done_step[r.rid] <= specs[r.rid]["arrival"] + ttl):
                good += len(r.tokens)
        lat_ms = np.asarray(lats) * 1e3
        results[mode] = {
            "wall_s": wall,
            "goodput_tok_s": good / wall,
            "in_deadline_tokens": good,
            "total_tokens": total,
            "statuses": status,
            "shed_rate": (status["shed"] + status["expired"]) / n_req,
            "p95_step_ms": float(np.percentile(lat_ms, 95)),
            "preemptions": b.preemptions - pre_preempt,
        }
        if degrade:
            for r in timed:
                if r.status != "completed":
                    continue
                s = specs[r.rid]
                ref = np.asarray(generate(
                    params, cfg, jnp.asarray(s["prompt"])[None],
                    steps=s["budget"], temperature=s["temp"],
                    key=jax.random.PRNGKey(300 + r.rid)))[0].tolist()
                parity = parity and list(r.tokens) == ref
        b.close()

    ratio = (results["degraded"]["goodput_tok_s"]
             / max(1e-9, results["baseline"]["goodput_tok_s"]))
    out = {
        "n_requests": n_req,
        "ttl_steps": ttl,
        "arrivals_per_step": 8,
        "modes": results,
        "goodput_ratio_degraded_vs_baseline": ratio,
        "degraded_completed_token_parity": parity,
    }
    save_json("BENCH_overload", out)
    return out


def _print_overload(ov: Dict) -> None:
    for mode, r in ov["modes"].items():
        st = r["statuses"]
        print(f"overload[{mode:>8s}]: goodput {r['goodput_tok_s']:8.1f} "
              f"tok/s  shed rate {r['shed_rate']:.2f}  "
              f"step p95 {r['p95_step_ms']:7.2f} ms  "
              f"preemptions {r['preemptions']}  "
              f"({st['completed']} completed / {st['shed']} shed / "
              f"{st['expired']} expired; wall {r['wall_s']:.2f}s)")
    print(f"goodput with degradation vs FIFO baseline: "
          f"{ov['goodput_ratio_degraded_vs_baseline']:.2f}x; "
          f"completed-stream parity vs generate: "
          f"{ov['degraded_completed_token_parity']}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="serving-throughput comparison only (the "
                         "macro-step acceptance bar)")
    args = ap.parse_args()
    if args.smoke:
        sp = serving_perf(quick=True)
        _print_serving(sp)
        assert sp["token_identical_all_modes"], \
            "macro/paged/dense decode diverged from per-request generate"
        assert sp["speedup_macro_vs_per_token"] >= 1.3, \
            "macro-step decode must beat the per-token paged path by " \
            f">= 1.3x (got {sp['speedup_macro_vs_per_token']:.2f}x)"
        # the overlap bar binds wherever overlap is physically possible
        # (>= 2 cores: the boundary host work runs while the scan holds
        # other cores).  A single-core host time-slices the two, so wall
        # time is conserved by construction and the bar degrades to
        # no-material-regression: the pipeline machinery (worker thread,
        # lazy admission, window bookkeeping) must stay within 10%.
        ov_floor = 1.0 if sp["overlap_parallel_substrate"] else 0.90
        assert sp["speedup_overlap_vs_sync"] >= ov_floor, \
            "the pipelined loop must not serve slower than the " \
            "synchronous macro loop " \
            f"(got {sp['speedup_overlap_vs_sync']:.2f}x, " \
            f"floor {ov_floor:.2f}x)"
        assert sp["parity_vs_generate"]["pipelined"], \
            "the pipelined loop diverged from per-request generate"
        # same substrate gate as the overlap bar: on a single-core host
        # the GIL, the recorder lock and XLA compute time-slice one core,
        # so paired wall measurements cannot resolve 3% (observed pair
        # spread ~0.6-1.3x with a median at 1.0) and the floor widens
        oh_floor = 0.97 if sp["overlap_parallel_substrate"] else 0.90
        assert sp["telemetry_overhead"]["ratio"] >= oh_floor, \
            "telemetry-enabled macro throughput regressed vs disabled " \
            f"(got {sp['telemetry_overhead']['ratio']:.3f}, " \
            f"floor {oh_floor:.2f})"
        ho = hostile(quick=True)
        _print_hostile(ho)
        assert ho["max_regret"] <= 1.15, \
            "hostile traffic shook the tuner: per-phase regret must stay " \
            f"<= 1.15x best fixed (got {ho['max_regret']:.3f}x)"
        assert ho["poisoned_trial"]["reverted"], \
            "poisoned TRIAL sweep must abort and revert to the last " \
            f"attested period (got {ho['poisoned_trial']})"
        m = mla(quick=True)
        _print_mla(m)
        assert m["token_identical"], \
            "paged MLA decode diverged from per-request generate"
        assert m["page_reduction_x"] >= 1.5, \
            "paged MLA admission must provision >= 1.5x fewer pages than " \
            f"dense rows (got {m['page_reduction_x']:.2f}x)"
        ovl = overload(quick=True)
        _print_overload(ovl)
        assert ovl["degraded_completed_token_parity"], \
            "graceful degradation must never trade token fidelity"
        assert ovl["goodput_ratio_degraded_vs_baseline"] >= 1.2, \
            "degradation must raise in-deadline goodput >= 1.2x over the " \
            "FIFO-forever baseline under overload " \
            f"(got {ovl['goodput_ratio_degraded_vs_baseline']:.2f}x)"
        raise SystemExit(0)
    r = run(args.quick)
    o = r["online"]
    print(f"traffic: {r['requests']['completed']}/{r['requests']['submitted']}"
          f" requests completed over {r['steps']} steps")
    cm = r["cache_memory"]
    print(f"cache memory: peak paged {cm['peak_paged_pages']} pages vs dense "
          f"{cm['dense_pages']} ({cm['reduction']:.1%} reduction)")
    print(f"online: period={o['final_period']} ({o['state']}) after "
          f"{o['tune_cycles']} tune cycles; steady {o['steady']:.2f}/step")
    for p, v in r["fixed"].items():
        print(f"    fixed {p:>3s}: steady {v['steady']:8.2f} total "
              f"{v['total']:10.0f}")
    print(f"online vs best fixed (steady): "
          f"{r['online_vs_best_fixed_steady']:.3f}x "
          f"(total {r['online_vs_best_fixed_total']:.3f}x)")
    tp = r["token_parity"]
    print(f"token parity: {tp['token_identical']} over {tp['requests']} "
          f"requests; paged kernel max diff {tp['paged_kernel_max_diff']:.1e};"
          f" pages released: {tp['pages_all_released']}")
    _print_hostile(hostile(args.quick))
    _print_serving(serving_perf(args.quick))
    _print_mla(mla(args.quick))
    _print_overload(overload(args.quick))
