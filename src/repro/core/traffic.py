"""Serving-traffic request streams (the aggregate-workload generator).

The paper tunes the movement period against one application's reuse; a
serving system sees a *mix* of requests arriving over time, each with its
own prompt length, output budget and KV access pattern.  This module
generates those streams: Poisson arrivals per decode step, mixed
prompt/output lengths, and a per-request workload ``kind`` naming the
access pattern (resolved by the consumer -- ``repro.serve.sched`` maps
kinds onto ``repro.memtier.workload`` mass generators).

``poisson_request_stream`` generates one stationary phase; concatenate
calls with different rates/mixes (``shifting_mix_stream``) to model the
traffic-mix shifts the online tuner must survive.

The **hostile suite** generates the adversarial shapes a permanently-on
tuner has to survive (ARMS / Hybrid Adaptive Tuning, PAPERS.md), all
built on one modulated-Poisson kernel and all phase-composable through
``shifting_mix_stream``:

  * ``flash_crowd_stream``   -- the arrival rate spikes x ``spike_factor``
    for short bursts (a viral prompt, a retry storm);
  * ``diurnal_stream``       -- a smooth sinusoidal rate swing (the
    day/night cycle compressed to decode steps);
  * ``correlated_burst_stream`` -- arrivals come in correlated clumps of
    ``burst_size`` (webhook fan-out, batch clients): the mean rate is
    preserved but the variance is ``burst_size`` x Poisson;
  * ``mix_inversion_stream`` -- the kind-mix abruptly inverts every
    ``invert_every`` steps (``invert_kinds``), so the dominant access
    pattern flips without the rate changing at all.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = ["RequestSpec", "poisson_request_stream",
           "modulated_request_stream", "flash_crowd_stream",
           "diurnal_stream", "correlated_burst_stream",
           "mix_inversion_stream", "invert_kinds", "shifting_mix_stream"]


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One request of a traffic stream (all lengths in tokens/steps)."""

    rid: int
    arrival: int                  # decode step the request arrives at
    prompt_len: int
    new_tokens: int               # output budget (retire on length)
    kind: str                     # access-pattern name (consumer-resolved)
    seed: int

    def total_tokens(self, prefix_len: int = 0) -> int:
        return prefix_len + self.prompt_len + self.new_tokens

    def n_pages(self, page_size: int, prefix_len: int = 0) -> int:
        """KV pages the request occupies (page-aligned allocation)."""
        return -(-self.total_tokens(prefix_len) // page_size)


def modulated_request_stream(steps: int,
                             rate: Union[float, Callable[[int], float]],
                             kinds: Union[Dict[str, float],
                                          Callable[[int], Dict[str, float]]],
                             *, burst_size: int = 1,
                             prompt_len: Tuple[int, int] = (16, 64),
                             new_tokens: Tuple[int, int] = (32, 128),
                             start: int = 0, rid0: int = 0,
                             seed: int = 0) -> List[RequestSpec]:
    """The kernel every stream generator is built on: per decode step,
    ``Poisson(rate(t) / burst_size)`` arrival *events* fire, each bringing
    ``burst_size`` requests at once (``burst_size=1`` is plain Poisson;
    larger values keep the mean rate but clump arrivals into correlated
    bursts).  ``rate`` and ``kinds`` may be constants or per-step
    callables of the phase-local step index.  Arrivals are offset by
    ``start`` and request ids by ``rid0`` so phases concatenate cleanly;
    the draw sequence is deterministic given ``seed``."""
    rng = np.random.default_rng(seed)
    rate_fn = rate if callable(rate) else (lambda t, _r=float(rate): _r)
    kinds_fn = kinds if callable(kinds) else (lambda t, _k=dict(kinds): _k)
    burst_size = max(1, int(burst_size))
    specs: List[RequestSpec] = []
    rid = rid0
    for t in range(steps):
        k = kinds_fn(t)
        names = sorted(k)
        w = np.asarray([k[n] for n in names], np.float64)
        w = w / w.sum()
        lam = max(0.0, float(rate_fn(t))) / burst_size
        for _ in range(int(rng.poisson(lam)) * burst_size):
            specs.append(RequestSpec(
                rid=rid, arrival=start + t,
                prompt_len=int(rng.integers(prompt_len[0],
                                            prompt_len[1] + 1)),
                new_tokens=int(rng.integers(new_tokens[0],
                                            new_tokens[1] + 1)),
                kind=names[int(rng.choice(len(names), p=w))],
                seed=int(rng.integers(0, 2 ** 31 - 1))))
            rid += 1
    return specs


def poisson_request_stream(steps: int, rate: float,
                           kinds: Dict[str, float], *,
                           prompt_len: Tuple[int, int] = (16, 64),
                           new_tokens: Tuple[int, int] = (32, 128),
                           start: int = 0, rid0: int = 0,
                           seed: int = 0) -> List[RequestSpec]:
    """One stationary traffic phase: per decode step, ``Poisson(rate)``
    requests arrive; each draws its kind from the ``kinds`` weight map and
    its prompt/output lengths uniformly from the given inclusive ranges.
    Arrivals are offset by ``start`` and request ids by ``rid0`` so phases
    concatenate cleanly."""
    return modulated_request_stream(steps, rate, kinds,
                                    prompt_len=prompt_len,
                                    new_tokens=new_tokens, start=start,
                                    rid0=rid0, seed=seed)


def flash_crowd_stream(steps: int, rate: float, kinds: Dict[str, float], *,
                       spike_factor: float = 8.0, spike_every: int = 200,
                       spike_len: int = 12, spike_offset: int = 0,
                       prompt_len: Tuple[int, int] = (16, 64),
                       new_tokens: Tuple[int, int] = (32, 128),
                       start: int = 0, rid0: int = 0,
                       seed: int = 0) -> List[RequestSpec]:
    """Flash crowds: the base ``rate`` spikes x ``spike_factor`` for
    ``spike_len`` steps every ``spike_every`` steps (first spike at
    ``spike_offset``) -- the short hostile burst that poisons a TRIAL
    window mid-sweep if the tuner has no guardrail."""
    spike_every = max(1, int(spike_every))

    def rate_fn(t: int) -> float:
        return rate * (spike_factor
                       if (t - spike_offset) % spike_every < spike_len
                       and t >= spike_offset else 1.0)

    return modulated_request_stream(steps, rate_fn, kinds,
                                    prompt_len=prompt_len,
                                    new_tokens=new_tokens, start=start,
                                    rid0=rid0, seed=seed)


def diurnal_stream(steps: int, rate: float, kinds: Dict[str, float], *,
                   swing_period: int = 400, amplitude: float = 0.8,
                   phase: float = 0.0,
                   prompt_len: Tuple[int, int] = (16, 64),
                   new_tokens: Tuple[int, int] = (32, 128),
                   start: int = 0, rid0: int = 0,
                   seed: int = 0) -> List[RequestSpec]:
    """Diurnal swing: the arrival rate follows
    ``rate * (1 + amplitude * sin(2*pi*(t/swing_period + phase)))`` -- a
    smooth but large load oscillation (peak/trough ratio
    ``(1+a)/(1-a)``) that a drift detector tuned for step changes must
    ride out without churning through re-profiles."""
    swing_period = max(1, int(swing_period))

    def rate_fn(t: int) -> float:
        return rate * (1.0 + amplitude
                       * math.sin(2.0 * math.pi * (t / swing_period + phase)))

    return modulated_request_stream(steps, rate_fn, kinds,
                                    prompt_len=prompt_len,
                                    new_tokens=new_tokens, start=start,
                                    rid0=rid0, seed=seed)


def correlated_burst_stream(steps: int, rate: float,
                            kinds: Dict[str, float], *,
                            burst_size: int = 6,
                            prompt_len: Tuple[int, int] = (16, 64),
                            new_tokens: Tuple[int, int] = (32, 128),
                            start: int = 0, rid0: int = 0,
                            seed: int = 0) -> List[RequestSpec]:
    """Correlated bursts: arrivals clump into groups of ``burst_size``
    (Poisson arrival *events* at ``rate / burst_size``), preserving the
    mean rate while multiplying the arrival variance by ``burst_size`` --
    the heavy-tailed load shape that de-noises a fixed-length trial
    window into a wrong ranking."""
    return modulated_request_stream(steps, rate, kinds,
                                    burst_size=burst_size,
                                    prompt_len=prompt_len,
                                    new_tokens=new_tokens, start=start,
                                    rid0=rid0, seed=seed)


def invert_kinds(kinds: Dict[str, float]) -> Dict[str, float]:
    """Invert a kind-weight map: the weight vector is reversed across the
    sorted kind names, so the dominant kind becomes the rarest and vice
    versa (a pure mix inversion -- total weight, and hence the arrival
    rate, is unchanged)."""
    names = sorted(kinds)
    weights = [kinds[n] for n in names]
    return dict(zip(names, reversed(weights)))


def mix_inversion_stream(steps: int, rate: float, kinds: Dict[str, float],
                         *, invert_every: int = 300,
                         prompt_len: Tuple[int, int] = (16, 64),
                         new_tokens: Tuple[int, int] = (32, 128),
                         start: int = 0, rid0: int = 0,
                         seed: int = 0) -> List[RequestSpec]:
    """Abrupt kind-mix inversions: every ``invert_every`` steps the kind
    mix flips between ``kinds`` and ``invert_kinds(kinds)`` with no rate
    change at all -- the access-pattern phase change arrives silently in
    the reuse structure, not in the load level."""
    invert_every = max(1, int(invert_every))
    flipped = invert_kinds(kinds)

    def kinds_fn(t: int) -> Dict[str, float]:
        return flipped if (t // invert_every) % 2 else kinds

    return modulated_request_stream(steps, rate, kinds_fn,
                                    prompt_len=prompt_len,
                                    new_tokens=new_tokens, start=start,
                                    rid0=rid0, seed=seed)


#: Per-phase generators ``shifting_mix_stream`` can dispatch to via the
#: optional 4th phase element ``{"gen": <name>, ...kwargs}``.
PHASE_GENERATORS: Dict[str, Callable[..., List[RequestSpec]]] = {
    "poisson": poisson_request_stream,
    "flash_crowd": flash_crowd_stream,
    "diurnal": diurnal_stream,
    "burst": correlated_burst_stream,
    "inversion": mix_inversion_stream,
}


def shifting_mix_stream(phases: Sequence[Tuple], *,
                        prompt_len: Tuple[int, int] = (16, 64),
                        new_tokens: Tuple[int, int] = (32, 128),
                        seed: int = 0) -> List[RequestSpec]:
    """Concatenate stationary phases ``(steps, rate, kind_weights)`` into
    one stream whose arrival mix shifts at each phase boundary -- the
    workload the scheduler-fed online tuner is benchmarked against.

    A phase may carry an optional 4th element, a dict of generator
    kwargs: ``{"gen": "flash_crowd", "spike_factor": 8.0, ...}`` routes
    the phase through the named hostile generator (``PHASE_GENERATORS``)
    instead of plain Poisson, which is how the hostile suite composes
    with ordinary mix-shift phases in one stream."""
    specs: List[RequestSpec] = []
    startt = 0
    for i, ph in enumerate(phases):
        steps, rate, kinds = ph[0], ph[1], ph[2]
        extra = dict(ph[3]) if len(ph) > 3 else {}
        gen = PHASE_GENERATORS[extra.pop("gen", "poisson")]
        specs.extend(gen(steps, rate, kinds, prompt_len=prompt_len,
                         new_tokens=new_tokens, start=startt,
                         rid0=len(specs), seed=seed + 7919 * i, **extra))
        startt += steps
    return specs
