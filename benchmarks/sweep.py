"""Batched simulator sweep vs the per-candidate loop.

`core.sim.sweep` evaluates a whole candidate ladder one-shot: the block
histogram crosses to the device once, periods aggregate hierarchically on
device, and candidates with equal padded period counts share a single
`jax.vmap`-batched scan.  `sweep_loop` is the old path (host re-aggregation
+ one scan launch per candidate).  Reports warm wall-clock for a
16-candidate Eq.-2 ladder and verifies the runtimes agree exactly.

    PYTHONPATH=src python -m benchmarks.sweep
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save_json
from repro.core import (bin_trace, candidate_periods, dominant_reuse,
                        generate, prune_insignificant,
                        reuse_distance_histogram, sweep, sweep_loop)

REPS = 3


def _ladder(bins, trace, n_cands: int = 16) -> np.ndarray:
    hist = prune_insignificant(
        reuse_distance_histogram(trace.pages, bin_width=bins.block * 10))
    dr = dominant_reuse(hist)
    # Halve DR so Eq. 2 yields a full n_cands-rung ladder on this trace.
    ladder = candidate_periods(dr / 2, float(bins.num_accesses),
                               max_candidates=n_cands,
                               min_period=float(bins.block))
    return ladder[:n_cands]


def run(quick: bool = False):
    apps = ["backprop"] if quick else ["backprop", "lud", "kmeans"]
    out = {}
    for app in apps:
        trace = generate(app)
        bins = bin_trace(trace)
        ladder = _ladder(bins, trace)
        # warm both paths (compile), then time
        a = sweep_loop(bins, ladder)
        b = sweep(bins, ladder)
        max_err = max(abs(a[p].runtime - b[p].runtime) /
                      max(1.0, abs(a[p].runtime)) for p in a)
        t0 = time.monotonic()
        for _ in range(REPS):
            sweep_loop(bins, ladder)
        t1 = time.monotonic()
        for _ in range(REPS):
            sweep(bins, ladder)
        t2 = time.monotonic()
        out[app] = {
            "candidates": int(len(ladder)),
            "loop_s": (t1 - t0) / REPS,
            "batched_s": (t2 - t1) / REPS,
            "speedup": (t1 - t0) / max(1e-9, (t2 - t1)),
            "max_rel_err": max_err,
        }
    save_json("sweep", out)
    return out


if __name__ == "__main__":
    for app, v in run().items():
        print(f"{app:12s} {v['candidates']:3d} cands: loop "
              f"{v['loop_s']:.2f}s batched {v['batched_s']:.2f}s -> "
              f"{v['speedup']:.1f}x (max rel err {v['max_rel_err']:.2e})")
