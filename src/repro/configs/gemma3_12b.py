"""Gemma-3-12B [hf:google]: 5:1 local:global attention, 128k context.

long_500k is lowerable: local layers are sliding-window (sub-quadratic);
global layers at decode are O(L)/step with context-parallel KV.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
        d_ff=15360, vocab_size=262144,
        segments=(((("local",) * 5 + ("attn",)), 8),),
        window_size=1024, mlp_kind="swiglu", qk_norm=True,
        tie_embeddings=True, rope_theta=1_000_000.0, max_seq_len=131072,
        supports_long_context=True)
