"""Config-wide differential parity matrix.

Every architecture in ``repro.configs`` — full attention, windowed,
MLA-compressed, recurrent/xLSTM state, conditioned cross-attention, and
shared-prefix — must emit token-identical streams across four
execution paths:

  1. per-request ``engine.generate`` (the oracle),
  2. the dense ContinuousBatcher (``paged=False``, contiguous cache),
  3. the per-token paged batcher over ``SharedPagedPools``,
  4. the macro-step paged batcher (device-resident multi-token launches).

The workload bakes in the serving edge cases: staggered admission into
a recycled row, temperature sampling with per-request keys, a mid-macro
EOS retirement, and window rings (prompt + steps exceed the reduced
sliding window so rings wrap).
"""

import numpy as np
import pytest

import repro.configs as C


def _stack(cfg, *, n_logical=64, hbm=32, page=4):
    from repro.memtier import cori
    from repro.memtier.tiering import (SharedPagedPools, TierConfig,
                                       TieringManager)
    from repro.serve.sched import TrafficMonitor

    pools = SharedPagedPools.create(n_logical, hbm, page_size=page)
    mgr = TieringManager(n_logical, TierConfig(page_size=page,
                                               hbm_pages=hbm,
                                               period_steps=2))
    tuner = cori.OnlineTuner(n_logical, default_period=2, profile_steps=8,
                             trial_steps=4)
    return TrafficMonitor(pools, mgr, tuner)


def _workload(cfg, seed=0):
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 9, 5)]
    keys = [jax.random.PRNGKey(10 + i) for i in range(3)]
    steps = [6, 4, 7]
    temps = [0.0, 0.7, 0.7]
    cond = None
    ex = None
    if cfg.cond_dim:
        cond = jax.random.normal(jax.random.PRNGKey(2),
                                 (1, cfg.cond_len, cfg.cond_dim),
                                 jnp.float32)
    if cfg.prefix_len:
        ex = jax.random.normal(jax.random.PRNGKey(3),
                               (1, cfg.prefix_len, cfg.d_model),
                               jnp.float32)
    return prompts, keys, steps, temps, cond, ex


def _run_batcher(params, cfg, prompts, keys, steps, temps, cond, ex, *,
                 mode, eos_for=None, eos_id=None):
    """Drive one batcher mode over the staggered workload; returns
    ({rid: tokens}, streamed event list)."""
    from repro.serve.sched import ContinuousBatcher, Request

    mon = None if mode == "dense" else _stack(cfg)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32, page_size=4,
                          monitor=mon, paged=(mode != "dense"),
                          macro=(mode == "macro"),
                          macro_steps=3 if mode == "macro" else None,
                          cond=cond, extra_embeds=ex)
    assert b.paged == (mode != "dense")

    def mk(i):
        return Request(rid=i, prompt=prompts[i], max_new_tokens=steps[i],
                       key=keys[i], temperature=temps[i],
                       eos_id=eos_id if i == eos_for else None)

    b.submit(mk(0))
    b.submit(mk(1))
    events = []
    for t in range(60):
        if t == 2:       # joins mid-flight, lands in a recycled row
            b.submit(mk(2))
        events.extend(b.step())
        if t > 2 and not b.queue and not b.active:
            break
    assert not b.queue and not b.active, "workload did not drain"
    if mon is not None:
        shared = (cfg.prefix_len or 0) // 4
        assert mon.pools.free_pages == mon.pools.n_logical - shared, \
            "retirement must release every owned page (prefix stays mapped)"
    return {r.rid: list(r.tokens) for r in b.completed}, events


@pytest.mark.parametrize("name", C.ARCHS)
def test_four_way_parity(name):
    """generate == dense == per-token paged == macro, token for token,
    for every architecture in the config registry."""
    import jax
    import jax.numpy as jnp
    from repro.models import model as mdl
    from repro.serve.engine import generate

    cfg = C.reduced(name)
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    prompts, keys, steps, temps, cond, ex = _workload(cfg)

    want = []
    for i in range(3):
        ref = np.asarray(generate(params, cfg, jnp.asarray(prompts[i])[None],
                                  steps=steps[i], temperature=temps[i],
                                  key=keys[i], cond=cond,
                                  extra_embeds=ex))[0].tolist()
        want.append(ref)

    for mode in ("dense", "paged", "macro"):
        got, events = _run_batcher(params, cfg, prompts, keys, steps, temps,
                                   cond, ex, mode=mode)
        for i in range(3):
            assert got[i] == want[i], \
                f"{name}/{mode}: request {i} diverged from generate"
            streamed = [tok for rid, tok in events if rid == i]
            assert streamed == want[i], \
                f"{name}/{mode}: stream for request {i} incomplete"

    # mid-macro EOS: a later greedy token becomes EOS, landing inside a
    # 3-token macro launch; the stream must truncate exactly there and
    # release the row's pages (checked by _run_batcher's leak assert).
    eos_idx = next((i for i in range(2, len(want[0]))
                    if want[0][i] not in want[0][:i]), None)
    if eos_idx is not None:
        got, _ = _run_batcher(params, cfg, prompts, keys, steps, temps,
                              cond, ex, mode="macro", eos_for=0,
                              eos_id=want[0][eos_idx])
        assert got[0] == want[0][:eos_idx + 1], \
            f"{name}: mid-macro EOS must truncate at the EOS token"


def test_matrix_covers_every_registered_arch():
    """The parametrization above is the whole registry — adding a config
    without geometry support fails here, not in production."""
    assert len(C.ARCHS) >= 10
    from repro.models import model as mdl
    for name in C.ARCHS:
        cfg = C.reduced(name)
        assert mdl.paged_supported(cfg), name
        specs = mdl.slot_leaf_specs(cfg, 4)
        assert specs, name
        for _, leaves in specs:
            assert set(leaves) in ({"k", "v"}, {"ckv", "krope"}, {"state"}), \
                (name, set(leaves))


def test_window_ring_wraps_in_matrix_workload():
    """The matrix workload genuinely exercises ring wrap-around for the
    windowed architectures (prompt + steps > reduced window)."""
    windowed = [n for n in C.ARCHS
                if any(w for w in _windows(C.reduced(n)))]
    assert windowed, "registry lost all windowed architectures"
    for n in windowed:
        w = min(w for w in _windows(C.reduced(n)) if w)
        assert 9 + 7 > w, f"{n}: workload too short to wrap window={w}"


def _windows(cfg):
    from repro.models import model as mdl
    return [window for _, _, _, window, _ in mdl.state_slot_meta(cfg)]
