"""Deterministic, seedable fault injection for the serving stack.

A :class:`FaultPlan` is a set of typed injection points threaded through
``SharedPagedPools``, ``TieringManager``, ``DecisionWorker`` and
``ContinuousBatcher``.  Each component *asks* the plan whether its fault
fires at the current point (``plan.fires("pool.migrate_fail")``) instead
of the plan reaching into the component -- so production code paths stay
fault-free when no plan is installed (the default is a shared inert plan
whose every query is two attribute loads and a dict miss).

Determinism contract: a fault decision is a pure function of
``(seed, kind, occurrence_counter)`` -- *not* of wall clock or global
RNG state -- so the same plan replays the same fault schedule on every
run.  The plan keeps a logical ``clock`` advanced once per scheduler
step by the component that owns the plan (the batcher), which windows
each point to a ``[start, stop)`` span of steps.

Injection points (the chaos matrix):

=====================  =====================================================
``pool.squeeze``       HBM capacity squeeze: ``effective_hbm`` drops to
                       ``value`` pages while active (pressure, preemption)
``pool.migrate_fail``  ``migrate_slots`` raises :class:`MigrationError`
                       (retry-with-backoff, degraded pinned-to-host mode)
``pool.migrate_slow``  ``migrate_slots`` sleeps ``value`` seconds first
``worker.delay``       the DecisionWorker sleeps ``value`` seconds before
                       planning (watchdog hang detection)
``worker.crash``       the DecisionWorker raises before planning
                       (watchdog crash recovery)
``mass.nonfinite``     the merged page-mass telemetry is corrupted with
                       NaN/inf before it reaches the monitor
``admit.flood``        the submit queue bound is ignored for this request
                       (admission flood; deadline shedding must absorb it)
=====================  =====================================================
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Tuple

from repro.obs import telemetry as _obs

__all__ = ["FAULT_KINDS", "FaultPoint", "FaultPlan", "MigrationError",
           "NULL_PLAN"]

#: The closed registry of injection-point kinds.
FAULT_KINDS = (
    "pool.squeeze",
    "pool.migrate_fail",
    "pool.migrate_slow",
    "worker.delay",
    "worker.crash",
    "mass.nonfinite",
    "admit.flood",
)


class MigrationError(RuntimeError):
    """A slot migration failed (injected or real transport error)."""


@dataclasses.dataclass(frozen=True)
class FaultPoint:
    """One injection point: *kind* fires with *prob* inside ``[start,
    stop)`` of the plan's logical clock; *value* is the kind-specific
    magnitude (squeeze capacity in pages, delay in seconds)."""
    kind: str
    start: int = 0
    stop: int = 2 ** 31
    prob: float = 1.0
    value: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"registered: {FAULT_KINDS}")


class FaultPlan:
    """A deterministic schedule of fault injections.

    ``fires(kind)`` is the single query surface: it checks the clock
    window, then samples a per-occurrence coin from
    ``sha256(seed, kind, counter)`` -- each call advances that kind's
    counter, so the decision sequence is reproducible as long as each
    kind is queried from one code path (true here: every kind has
    exactly one owner site).  Firing emits an ``ft.inject`` event and
    bumps ``fired[kind]`` so chaos tests can assert coverage.
    """

    def __init__(self, points=(), *, seed: int = 0):
        self.points: Tuple[FaultPoint, ...] = tuple(points)
        self.seed = int(seed)
        self.clock = 0
        self._counts: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}
        self._by_kind: Dict[str, Tuple[FaultPoint, ...]] = {}
        for p in self.points:
            self._by_kind.setdefault(p.kind, ())
            self._by_kind[p.kind] += (p,)

    @property
    def enabled(self) -> bool:
        return bool(self.points)

    def tick(self) -> None:
        """Advance the logical clock (once per scheduler step)."""
        self.clock += 1

    def _coin(self, kind: str, count: int) -> float:
        h = hashlib.sha256(
            f"{self.seed}:{kind}:{count}".encode()).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64

    def active(self, kind: str):
        """The first point of *kind* whose window covers the clock, or
        None.  Does NOT advance counters (pure span check -- used for
        level-style faults like the capacity squeeze)."""
        for p in self._by_kind.get(kind, ()):
            if p.start <= self.clock < p.stop:
                return p
        return None

    def fires(self, kind: str):
        """Sample whether *kind* fires now; returns the firing
        :class:`FaultPoint` or None.  Advances the kind's occurrence
        counter on every in-window query (hit or miss) so the schedule
        is independent of earlier outcomes."""
        p = self.active(kind)
        if p is None:
            return None
        count = self._counts.get(kind, 0)
        self._counts[kind] = count + 1
        if p.prob < 1.0 and self._coin(kind, count) >= p.prob:
            return None
        n = self.fired.get(kind, 0) + 1
        self.fired[kind] = n
        if (r := _obs.RECORDER).enabled:
            r.emit("ft.inject", kind=kind, clock=self.clock, count=n,
                   value=float(p.value))
            r.count(f"ft.inject.{kind}")
        return p


#: Shared inert plan: every query is a dict miss.  Components default to
#: this so the unfaulted hot path never branches on plan identity.
NULL_PLAN = FaultPlan()
