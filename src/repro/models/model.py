"""Model assembly: init / train-forward / prefill / decode over segments.

A model is a list of segments ``(pattern, repeats)``; parameters of each
segment are stacked ``[R, ...]`` and executed with ``lax.scan`` over repeats
(pattern slots unrolled in the body), so HLO size scales with the pattern
length, not the layer count.  ``jax.checkpoint`` (remat) wraps the scan body
when ``cfg.remat``.

Serving has two decode data paths: the dense per-row cache
(``init_cache``/``decode_step``) and the fully-paged path
(``decode_step_paged``) where every attention layer reads and writes
shared KV page pools through ``kernels.paged_attention`` -- see
docs/serving.md.  ``prefill_batched`` packs a scheduler step's admissions
into one right-padded forward pass for either path.

All functions are pure; sharding is applied externally (pjit in_shardings
from the spec tree + optional ``shard_fn`` activation constraints).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.config import LayerKind, ModelConfig, parse_kind

Params = Dict[str, Any]
_IDENT = lambda x, names: x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _slot_init(key, cfg: ModelConfig, kind: LayerKind):
    """(params, specs) for one layer slot."""
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["norm1"], s["norm1"] = L.rms_norm_init(cfg.d_model)
    if kind.is_attention:
        init = L.mla_init if kind.mla else L.attention_init
        p["attn"], s["attn"] = init(ks[0], cfg)
    elif kind.base == "mlstm":
        p["cell"], s["cell"] = R.mlstm_init(ks[0], cfg)
    elif kind.base == "slstm":
        p["cell"], s["cell"] = R.slstm_init(ks[0], cfg)
    elif kind.base == "rglru":
        p["cell"], s["cell"] = R.rglru_init(ks[0], cfg)
    if kind.xattn:
        p["norm_x"], s["norm_x"] = L.rms_norm_init(cfg.d_model)
        p["xattn"], s["xattn"] = L.attention_init(ks[1], cfg, cross=True)
    if kind.moe:
        p["norm2"], s["norm2"] = L.rms_norm_init(cfg.d_model)
        p["moe"], s["moe"] = M.moe_init(ks[2], cfg)
    elif cfg.d_ff > 0:
        p["norm2"], s["norm2"] = L.rms_norm_init(cfg.d_model)
        p["mlp"], s["mlp"] = L.mlp_init(ks[2], cfg)
    return p, s


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    """Returns (params, specs).  Segment params stacked [R, ...] with a
    leading "layers" spec axis (always unsharded)."""
    ks = jax.random.split(key, len(cfg.segments) + 2)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = L.embedding_init(ks[0], cfg)
    params["final_norm"], specs["final_norm"] = L.rms_norm_init(cfg.d_model)
    segs_p, segs_s = [], []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        slot_ps, slot_ss = [], []
        for j, kind_s in enumerate(pattern):
            kind = parse_kind(kind_s)
            kseed = jax.random.fold_in(ks[si + 2], j)

            def one(k):
                return _slot_init(k, cfg, kind)[0]

            stacked = jax.vmap(one)(jax.random.split(kseed, repeats))
            _, spec = _slot_init(kseed, cfg, kind)
            spec = jax.tree.map(
                lambda ax: ("layers",) + tuple(ax) if isinstance(ax, tuple)
                else ax, spec,
                is_leaf=lambda x: isinstance(x, tuple) or x is None)
            slot_ps.append(stacked)
            slot_ss.append(spec)
        segs_p.append(slot_ps)
        segs_s.append(slot_ss)
    params["segments"] = segs_p
    specs["segments"] = segs_s
    return params, specs


# ---------------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_block_seq(slot_p, cfg: ModelConfig, kind: LayerKind, x, *,
                     positions, cond, mesh, state=None, past=None,
                     k_positions=None, shard=_IDENT):
    """Sequence-mode block (train/prefill).  Returns (x, cache_entry, aux).

    ``past`` / ``k_positions`` serve chunked prefill: ``past`` is the
    slot's accumulated cache entries from previous chunks (one repeat's
    slice) and ``k_positions`` the concatenated past++own key positions
    the causal mask must range over."""
    aux = jnp.float32(0.0)
    h = L.rms_norm(x, slot_p["norm1"])
    cache_entry = None
    if kind.is_attention:
        window = cfg.window_size if kind.base == "local" else 0
        kpos = positions if k_positions is None else k_positions
        mask = L.causal_mask(positions, kpos, window=window,
                             prefix_len=cfg.prefix_len)
        if kind.mla:
            out, (ckv, krope) = L.mla_apply(
                slot_p["attn"], cfg, h, positions, mask,
                past=(None if past is None
                      else (past["ckv"], past["krope"])))
            cache_entry = {"ckv": ckv, "krope": krope}
        else:
            out, (k, v) = L.attention_apply(
                slot_p["attn"], cfg, h, h, positions, mask,
                past=(None if past is None else (past["k"], past["v"])))
            cache_entry = {"k": k, "v": v}
        x = x + out
    else:
        apply = {"mlstm": R.mlstm_apply, "slstm": R.slstm_apply,
                 "rglru": R.rglru_apply}[kind.base]
        out, new_state = apply(slot_p["cell"], cfg, h, state)
        cache_entry = new_state
        x = x + out
    x = shard(x, ("batch", "seq", "embed"))
    if kind.xattn and cond is not None:
        hx = L.rms_norm(x, slot_p["norm_x"])
        cpos = jnp.arange(cond.shape[1])[None]
        cmask = jnp.ones((1, hx.shape[1], cond.shape[1]), bool)
        out, _ = L.attention_apply(slot_p["xattn"], cfg, hx, cond,
                                   positions, cmask, kv_positions=cpos,
                                   use_rope=False)
        x = x + out
    if kind.moe:
        h2 = L.rms_norm(x, slot_p["norm2"])
        out, aux = M.moe_apply(slot_p["moe"], cfg, h2, mesh)
        x = x + out
    elif cfg.d_ff > 0 and "mlp" in slot_p:
        h2 = L.rms_norm(x, slot_p["norm2"])
        x = x + L.mlp_apply(slot_p["mlp"], cfg, h2)
    x = shard(x, ("batch", "seq", "embed"))
    return x, cache_entry, aux


def _apply_block_decode(slot_p, cfg: ModelConfig, kind: LayerKind, x, cache,
                        *, cur_pos, cond, mesh=None, shard=_IDENT):
    """One-token decode.  cache: this slot's cache for one repeat.
    Returns (x, new_cache)."""
    h = L.rms_norm(x, slot_p["norm1"])
    b = x.shape[0]
    if kind.is_attention:
        window = cfg.window_size if kind.base == "local" else 0
        if kind.mla:
            out, c_new, kr_new = L.mla_decode(
                slot_p["attn"], cfg, h, cache["ckv"], cache["krope"],
                cache["pos"], cur_pos)
            wslot = _write_slot(cache["pos"], cur_pos, window)
            new_cache = {
                "ckv": _scatter(cache["ckv"], wslot, c_new[:, 0]),
                "krope": _scatter(cache["krope"], wslot, kr_new[:, 0]),
                "pos": _scatter(cache["pos"], wslot, cur_pos),
            }
        else:
            out, k_new, v_new = L.attention_decode(
                slot_p["attn"], cfg, h, cache["k"], cache["v"], cache["pos"],
                cur_pos, window=window)
            wslot = _write_slot(cache["pos"], cur_pos, window)
            new_cache = {
                "k": _scatter(cache["k"], wslot, k_new[:, 0]),
                "v": _scatter(cache["v"], wslot, v_new[:, 0]),
                "pos": _scatter(cache["pos"], wslot, cur_pos),
            }
        x = x + out
    else:
        step = {"mlstm": R.mlstm_step, "slstm": R.slstm_step,
                "rglru": R.rglru_step}[kind.base]
        out, new_cache = step(slot_p["cell"], cfg, h, cache)
        x = x + out
    if kind.xattn and cond is not None:
        hx = L.rms_norm(x, slot_p["norm_x"])
        cpos = jnp.arange(cond.shape[1])[None]
        cmask = jnp.ones((1, 1, cond.shape[1]), bool)
        out, _ = L.attention_apply(slot_p["xattn"], cfg, hx, cond,
                                   cur_pos[:, None], cmask, kv_positions=cpos,
                                   use_rope=False)
        x = x + out
    if kind.moe:
        h2 = L.rms_norm(x, slot_p["norm2"])
        out, _ = M.moe_apply(slot_p["moe"], cfg, h2, mesh)
        x = x + out
    elif cfg.d_ff > 0 and "mlp" in slot_p:
        h2 = L.rms_norm(x, slot_p["norm2"])
        x = x + L.mlp_apply(slot_p["mlp"], cfg, h2)
    x = shard(x, ("batch", "seq", "embed"))
    return x, new_cache


def _write_slot(cache_pos, cur_pos, window: int):
    """Cache slot to write: pos for full caches, ring slot for windows."""
    t = cache_pos.shape[1]
    if window > 0 and t == window:
        return cur_pos % window
    return jnp.minimum(cur_pos, t - 1)


def _scatter(cache, slot, entry):
    """cache: [B,T,...]; slot: [B]; entry: [B,...]."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), slot].set(entry.astype(cache.dtype))


# ---------------------------------------------------------------------------
# segment runners
# ---------------------------------------------------------------------------


def _strip_layers(spec_tree):
    is_axes = lambda t: t is None or (isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t))
    return jax.tree.map(
        lambda ax: tuple(ax[1:]) if isinstance(ax, tuple) else ax,
        spec_tree, is_leaf=is_axes)


def _constrain_slots(slot_ps, slot_specs, pshard):
    if pshard is None or slot_specs is None:
        return slot_ps
    is_axes = lambda t: t is None or (isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t))
    out = []
    for ps, sp in zip(slot_ps, slot_specs):
        leaves, treedef = jax.tree.flatten(ps)
        specs = treedef.flatten_up_to(sp)
        out.append(jax.tree.unflatten(
            treedef, [pshard(l, a) if isinstance(a, tuple) else l
                      for l, a in zip(leaves, specs)]))
    return out


def _run_segments_seq(params, cfg: ModelConfig, x, *, positions, cond, mesh,
                      states=None, pasts=None, k_positions=None,
                      shard=_IDENT, collect_cache=False,
                      param_specs=None, pshard=None):
    """Run all segments in sequence mode.  states (optional) mirror the
    segment/slot structure with [R, ...] stacked leaves (recurrent only);
    pasts (optional, chunked prefill) likewise mirror it with previous
    chunks' attention cache entries stacked [R, B, P, ...], attended via
    ``k_positions``.  Returns (x, caches, aux_total)."""
    aux_total = jnp.float32(0.0)
    caches: List[List[Any]] = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        kinds = [parse_kind(s) for s in pattern]
        slot_params = params["segments"][si]
        seg_states = states["segments"][si] if states is not None else None
        seg_pasts = pasts["segments"][si] if pasts is not None else None

        slot_specs = (_strip_layers(param_specs["segments"][si])
                      if param_specs is not None else None)

        if cfg.unroll_layers:
            entries_all = []

            def one_repeat(xx, aux, slot_ps, slot_sts, slot_pst):
                slot_ps = _constrain_slots(slot_ps, slot_specs, pshard)
                entries = []
                for j, kind in enumerate(kinds):
                    st = slot_sts[j] if slot_sts is not None else None
                    pst = slot_pst[j] if slot_pst is not None else None
                    xx, entry, a = _apply_block_seq(
                        slot_ps[j], cfg, kind, xx, positions=positions,
                        cond=cond, mesh=mesh, state=st, past=pst,
                        k_positions=k_positions, shard=shard)
                    entries.append(entry)
                    aux = aux + a
                return xx, aux, entries

            fn = (jax.checkpoint(one_repeat, static_argnums=())
                  if cfg.remat else one_repeat)
            for r in range(repeats):
                slot_ps_r = jax.tree.map(lambda a: a[r], slot_params)
                sts_r = (jax.tree.map(lambda a: a[r], seg_states)
                         if seg_states is not None else None)
                pst_r = (jax.tree.map(lambda a: a[r], seg_pasts)
                         if seg_pasts is not None else None)
                x, aux_total, entries = fn(x, aux_total, slot_ps_r, sts_r,
                                           pst_r)
                entries_all.append(entries)
            if collect_cache:
                stacked = []
                for j in range(len(kinds)):
                    stacked.append(jax.tree.map(
                        lambda *xs: jnp.stack(xs, axis=0),
                        *[e[j] for e in entries_all]))
                caches.append(stacked)
            else:
                caches.append(None)
            continue

        def body(carry, per_repeat):
            xx, aux = carry
            slot_ps, slot_sts, slot_pst = per_repeat
            slot_ps = _constrain_slots(slot_ps, slot_specs, pshard)
            entries = []
            for j, kind in enumerate(kinds):
                st = slot_sts[j] if slot_sts is not None else None
                pst = slot_pst[j] if slot_pst is not None else None
                xx, entry, a = _apply_block_seq(
                    slot_ps[j], cfg, kind, xx, positions=positions, cond=cond,
                    mesh=mesh, state=st, past=pst, k_positions=k_positions,
                    shard=shard)
                entries.append(entry)
            return (xx, aux + a), entries

        body_fn = jax.checkpoint(body) if cfg.remat else body
        has_st, has_pst = seg_states is not None, seg_pasts is not None
        dummy = [jnp.zeros((repeats,))] * len(kinds)

        def body_fn2(carry, pr, _st=has_st, _pst=has_pst):
            slot_ps, sts, pst = pr
            return body_fn(carry, (slot_ps, sts if _st else None,
                                   pst if _pst else None))

        xs = (slot_params,
              seg_states if has_st else dummy,
              seg_pasts if has_pst else dummy)
        (x, aux_total), entries = jax.lax.scan(body_fn2, (x, aux_total), xs)
        caches.append(entries if collect_cache else None)
    return x, caches, aux_total


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None, cond=None,
            mesh=None, shard=_IDENT, param_specs=None, pshard=None):
    """Training forward.  tokens: [B,S_text]; extra_embeds (VLM/audio
    frontend stub): [B,P,d] prepended before the token embeddings.
    Returns (logits [B,S,V], aux_loss)."""
    x = L.embed(params["embed"], cfg, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)[None]
    x = shard(x, ("batch", "seq", "embed"))
    if cond is not None:
        cond = cond.astype(x.dtype)
    x, _, aux = _run_segments_seq(params, cfg, x, positions=positions,
                                  cond=cond, mesh=mesh, shard=shard,
                                  param_specs=param_specs, pshard=pshard)
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(params["embed"], cfg, x)
    logits = shard(logits, ("batch", "seq", "vocab"))
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    """Decode cache pytree mirroring the segment structure."""
    segs = []
    for pattern, repeats in cfg.segments:
        slots = []
        for kind_s in pattern:
            kind = parse_kind(kind_s)
            if kind.is_attention:
                t = (min(cfg.window_size, max_len)
                     if kind.base == "local" else max_len)
                if kind.mla:
                    m = cfg.mla
                    c = {"ckv": jnp.zeros((repeats, batch, t, m.kv_lora_rank),
                                          dtype),
                         "krope": jnp.zeros((repeats, batch, t, m.qk_rope_dim),
                                            dtype),
                         "pos": jnp.full((repeats, batch, t), -1, jnp.int32)}
                else:
                    kv, hd = cfg.num_kv_heads, cfg.head_dim
                    c = {"k": jnp.zeros((repeats, batch, t, kv, hd), dtype),
                         "v": jnp.zeros((repeats, batch, t, kv, hd), dtype),
                         "pos": jnp.full((repeats, batch, t), -1, jnp.int32)}
            else:
                zero = {"mlstm": R.mlstm_zero_state, "slstm": R.slstm_zero_state,
                        "rglru": R.rglru_zero_state}[kind.base]
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (repeats,) + a.shape),
                    zero(cfg, batch))
            slots.append(c)
        segs.append(slots)
    return {"segments": segs}


def cache_specs(cfg: ModelConfig, shape_kind: str = "decode"):
    """Logical-axis spec tree matching ``init_cache`` output."""
    segs = []
    for pattern, repeats in cfg.segments:
        slots = []
        for kind_s in pattern:
            kind = parse_kind(kind_s)
            if kind.is_attention:
                if kind.mla:
                    c = {"ckv": ("layers", "batch", "kv_seq", None),
                         "krope": ("layers", "batch", "kv_seq", None),
                         "pos": ("layers", "batch", "kv_seq")}
                else:
                    c = {"k": ("layers", "batch", "kv_seq", None, None),
                         "v": ("layers", "batch", "kv_seq", None, None),
                         "pos": ("layers", "batch", "kv_seq")}
            else:
                zero = {"mlstm": R.mlstm_zero_state, "slstm": R.slstm_zero_state,
                        "rglru": R.rglru_zero_state}[kind.base]
                proto = zero(cfg, 1)
                c = jax.tree.map(
                    lambda a: ("layers", "batch") + (None,) * (a.ndim - 1),
                    proto)
            slots.append(c)
        segs.append(slots)
    return {"segments": segs}


def decode_step(params, cfg: ModelConfig, cache, tokens, cur_pos, *,
                cond=None, mesh=None, shard=_IDENT):
    """One decode step.  tokens: [B,1]; cur_pos: [B] int32 (current length).
    Returns (logits [B,1,V], new_cache)."""
    x = L.embed(params["embed"], cfg, tokens)
    x = shard(x, ("batch", "seq", "embed"))
    if cond is not None:
        cond = cond.astype(x.dtype)
    new_segs = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        kinds = [parse_kind(s) for s in pattern]
        slot_params = params["segments"][si]
        slot_caches = cache["segments"][si]

        def body(xx, per_repeat):
            slot_ps, slot_cs = per_repeat
            new_cs = []
            for j, kind in enumerate(kinds):
                xx, nc = _apply_block_decode(
                    slot_ps[j], cfg, kind, xx, slot_cs[j], cur_pos=cur_pos,
                    cond=cond, mesh=mesh, shard=shard)
                new_cs.append(nc)
            return xx, new_cs

        if cfg.unroll_layers:
            reps = []
            for r in range(repeats):
                per = jax.tree.map(lambda a: a[r], (slot_params, slot_caches))
                x, ncs = body(x, per)
                reps.append(ncs)
            new_slot_caches = [
                jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                             *[rep[j] for rep in reps])
                for j in range(len(kinds))]
        else:
            x, new_slot_caches = jax.lax.scan(body, x,
                                              (slot_params, slot_caches))
        new_segs.append(new_slot_caches)
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(params["embed"], cfg, x)
    return logits, {"segments": new_segs}


def prefill(params, cfg: ModelConfig, tokens, *, extra_embeds=None, cond=None,
            mesh=None, shard=_IDENT, param_specs=None, pshard=None):
    """Prefill: forward pass that also returns a populated cache.
    Returns (last_logits [B,1,V], cache)."""
    x = L.embed(params["embed"], cfg, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s)[None]
    x = shard(x, ("batch", "seq", "embed"))
    if cond is not None:
        cond = cond.astype(x.dtype)
    x, caches, _ = _run_segments_seq(params, cfg, x, positions=positions,
                                     cond=cond, mesh=mesh, shard=shard,
                                     collect_cache=True,
                                     param_specs=param_specs, pshard=pshard)
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(params["embed"], cfg, x[:, -1:])

    # Assemble the cache pytree: attention entries -> (k, v, pos); recurrent
    # entries are already final states stacked [R, ...] by the scan.
    segs = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        kinds = [parse_kind(p_) for p_ in pattern]
        slots = []
        for j, kind in enumerate(kinds):
            e = caches[si][j]
            if kind.is_attention:
                window = cfg.window_size if kind.base == "local" else 0
                pos = jnp.broadcast_to(jnp.arange(s)[None, None],
                                       (repeats, b, s)).astype(jnp.int32)
                if window > 0 and s > window:
                    e = jax.tree.map(lambda a: a[:, :, -window:], e)
                    pos = pos[:, :, -window:]
                    # Ring alignment: decode overwrites slot cur_pos %
                    # window, so slot j must hold the position == j (mod
                    # window).  The chronological clip above puts position
                    # s-window+j at slot j; roll by s % window to restore
                    # the ring invariant -- without it, a prompt with
                    # s % window >= 2 had its next decode step overwrite a
                    # position still inside the attention window.
                    shift = s % window
                    if shift:
                        e = jax.tree.map(
                            lambda a: jnp.roll(a, shift, axis=2), e)
                        pos = jnp.roll(pos, shift, axis=2)
                if kind.mla:
                    slots.append({"ckv": e["ckv"], "krope": e["krope"],
                                  "pos": pos})
                else:
                    slots.append({"k": e["k"], "v": e["v"], "pos": pos})
            else:
                slots.append(e)
        segs.append(slots)
    return logits, {"segments": segs}


def pad_cache(cache, cfg: ModelConfig, max_len: int):
    """Pad prefill-produced attention caches out to `max_len` capacity
    (pos entries -1 == empty).  Recurrent states pass through."""
    segs = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        slots = []
        for j, kind_s in enumerate(pattern):
            kind = parse_kind(kind_s)
            c = cache["segments"][si][j]
            if kind.is_attention:
                window = cfg.window_size if kind.base == "local" else 0
                cap = min(window, max_len) if window > 0 else max_len
                cur = c["pos"].shape[2]
                if cur < cap:
                    pad = cap - cur

                    def padk(a, fill=0):
                        w = [(0, 0)] * a.ndim
                        w[2] = (0, pad)
                        return jnp.pad(a, w, constant_values=fill)

                    c = {k_: (padk(v, -1) if k_ == "pos" else padk(v))
                         for k_, v in c.items()}
            slots.append(c)
        segs.append(slots)
    return {"segments": segs}


# ---------------------------------------------------------------------------
# fully-paged serving path: batched prefill + decode over shared page pools
# ---------------------------------------------------------------------------


def state_slot_meta(cfg: ModelConfig):
    """EVERY state-bearing slot in execution order: (si, j, repeats,
    window, kind) -- plain/local attention, MLA and recurrent cells alike.

    This is the layer enumeration the shared page pools mirror: one set of
    geometry leaves per (segment, slot), stacked ``[repeats, ...]``
    exactly like the parameter tree, so the paged decode scan can slice
    pools and params with the same index."""
    out = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        for j, kind_s in enumerate(pattern):
            kind = parse_kind(kind_s)
            window = (cfg.window_size
                      if kind.is_attention and kind.base == "local" else 0)
            out.append((si, j, repeats, window, kind))
    return out


def attn_slot_meta(cfg: ModelConfig):
    """The attention subset of ``state_slot_meta`` (same tuple layout)."""
    return [m for m in state_slot_meta(cfg) if m[4].is_attention]


def attn_slot_index(cfg: ModelConfig, si: int, j: int) -> int:
    """Index of segment ``si`` slot ``j`` in the ``state_slot_meta`` order
    (== its leaf index in the shared pools' layered storage).  The slot
    must be an attention slot (its leaves are k/v or ckv/krope)."""
    for i, (si_, j_, _, _, kind) in enumerate(state_slot_meta(cfg)):
        if (si_, j_) == (si, j):
            if not kind.is_attention:
                break
            return i
    raise ValueError(f"({si}, {j}) is not an attention slot of {cfg.name}")


def _zero_state(cfg: ModelConfig, kind: LayerKind, batch: int):
    zero = {"mlstm": R.mlstm_zero_state, "slstm": R.slstm_zero_state,
            "rglru": R.rglru_zero_state}[kind.base]
    return zero(cfg, batch)


def state_dim(cfg: ModelConfig, kind: LayerKind) -> int:
    """Flattened per-row float count of one recurrent cell's state -- the
    trailing dim of its pool leaf (one logical "page" per request)."""
    proto = _zero_state(cfg, kind, 1)
    return sum(int(np.prod(a.shape[1:])) for a in jax.tree.leaves(proto))


def pack_state(state) -> jnp.ndarray:
    """Flatten a recurrent state pytree to f32[B, state_dim] (canonical
    tree-leaf order).  Pure reshape/concat -- bit-exact round trip."""
    leaves = jax.tree.leaves(state)
    b = leaves[0].shape[0]
    return jnp.concatenate(
        [a.reshape(b, -1).astype(jnp.float32) for a in leaves], axis=1)


def unpack_state(flat: jnp.ndarray, proto):
    """Inverse of ``pack_state`` against a same-structure prototype (e.g.
    the cell's zero state at the right batch)."""
    leaves, treedef = jax.tree.flatten(proto)
    b = flat.shape[0]
    out, o = [], 0
    for a in leaves:
        n = int(np.prod(a.shape[1:]))
        out.append(flat[:, o:o + n].reshape((b,) + a.shape[1:])
                   .astype(a.dtype))
        o += n
    return jax.tree.unflatten(treedef, out)


def slot_leaf_specs(cfg: ModelConfig, page_size: int):
    """Per-geometry leaf specs for ``SharedPagedPools.attach_layered``:
    one ``(repeats, {leaf_name: trailing_shape})`` entry per
    ``state_slot_meta`` slot.  Plain attention pages hold (k, v) token
    rows; MLA pages hold compressed (ckv, krope) rows shared across
    heads; recurrent cells hold one fixed-size state vector per request
    (a single logical page, tiered like any other)."""
    specs = []
    for (_, _, repeats, _, kind) in state_slot_meta(cfg):
        if kind.is_attention and kind.mla:
            m = cfg.mla
            leaves = {"ckv": (page_size, m.kv_lora_rank),
                      "krope": (page_size, m.qk_rope_dim)}
        elif kind.is_attention:
            leaves = {"k": (page_size, cfg.num_kv_heads, cfg.head_dim),
                      "v": (page_size, cfg.num_kv_heads, cfg.head_dim)}
        else:
            leaves = {"state": (state_dim(cfg, kind),)}
        specs.append((repeats, leaves))
    return specs


def has_state_pages(cfg: ModelConfig) -> bool:
    """Whether any slot is a recurrent cell (the request then carries one
    extra logical "state page" after its KV pages)."""
    return any(not k.is_attention for (_, _, _, _, k) in state_slot_meta(cfg))


def has_attention(cfg: ModelConfig) -> bool:
    return any(k.is_attention for (_, _, _, _, k) in state_slot_meta(cfg))


def paged_supported(cfg: ModelConfig) -> bool:
    """Every registered geometry now runs fully paged: plain/local
    attention (k, v) pages, MLA compressed (ckv, krope) pages, recurrent
    state slots, shared read-only prefix pages and cross-attention
    conditioning are all expressible on the shared slot pool
    (``slot_leaf_specs``).  Kept as an API point for callers that gate on
    it; always True for the config registry."""
    return True


def batched_prefill_supported(cfg: ModelConfig) -> bool:
    """Right-padded batched prefill is exact iff no layer carries
    sequential state across positions (recurrent cells would consume the
    padding tokens of short rows).  Attention rows are causal, so a row's
    valid prefix never sees the padding."""
    for pattern, _ in cfg.segments:
        for kind_s in pattern:
            if not parse_kind(kind_s).is_attention:
                return False
    return True


def prefill_batched(params, cfg: ModelConfig, tokens, lengths, *, cond=None,
                    extra_embeds=None, mesh=None, shard=_IDENT):
    """Batched-admission prefill: one packed forward over right-padded
    prompts.  tokens: [B, Smax] int32 (rows padded with any id); lengths:
    int32[B] true row length *including* any prepended prefix.

    ``extra_embeds`` ([B, P, d], the shared VLM/audio prefix) is
    prepended before the token embeddings exactly as in ``prefill``; the
    cache timeline then starts at the prefix, so page writers slice it by
    absolute position.

    Returns (last_logits [B,1,V], cache) where ``last_logits[b]`` is the
    logits at position ``lengths[b] - 1`` and the cache keeps the FULL
    padded timeline (no window clipping -- per-request extraction happens
    in ``row_cache_from_batched`` / the paged page-writer, which know each
    row's true length).  ``pos`` is per-row masked: slot t holds t for
    t < lengths[b], else -1.  Causality makes each row's valid prefix
    independent of its padding, so row b's logits and cache match a
    per-request prefill of its own prompt.
    """
    if not batched_prefill_supported(cfg):
        raise ValueError(f"{cfg.name}: batched prefill needs all-attention "
                         "layers (recurrent state would fold in padding)")
    x = L.embed(params["embed"], cfg, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s)[None]
    x = shard(x, ("batch", "seq", "embed"))
    if cond is not None:
        cond = cond.astype(x.dtype)
    x, caches, _ = _run_segments_seq(params, cfg, x, positions=positions,
                                     cond=cond, mesh=mesh, shard=shard,
                                     collect_cache=True)
    x = L.rms_norm(x, params["final_norm"])
    last = x[jnp.arange(b), jnp.asarray(lengths) - 1][:, None]
    logits = L.unembed(params["embed"], cfg, last)

    pos_row = jnp.where(jnp.arange(s)[None] < jnp.asarray(lengths)[:, None],
                        jnp.arange(s)[None], -1).astype(jnp.int32)
    segs = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        slots = []
        for j, kind_s in enumerate(pattern):
            kind = parse_kind(kind_s)
            e = caches[si][j]
            pos = jnp.broadcast_to(pos_row[None], (repeats, b, s))
            if kind.mla:
                slots.append({"ckv": e["ckv"], "krope": e["krope"],
                              "pos": pos})
            else:
                slots.append({"k": e["k"], "v": e["v"], "pos": pos})
        segs.append(slots)
    return logits, {"segments": segs}


def prefill_chunk(params, cfg: ModelConfig, tokens, lengths, past=None, *,
                  start: int = 0, cond=None, mesh=None, shard=_IDENT):
    """One width-bounded chunk of a batched-admission prefill.

    Splits ``prefill_batched``'s packed forward into chunks over absolute
    positions so long-prompt admission can interleave with macro launches
    (docs/serving.md, "Pipelined macro loop").  ``tokens``: [B, C], the
    slice of the right-padded prompt batch covering absolute positions
    ``[start, start+C)``; ``lengths``: int32[B] full true row lengths;
    ``past``: the accumulated cache of every previous chunk (leaves
    stacked [R, B, start, ...]; build it with ``chunk_past_extend`` from
    this function's own returns).  ``start`` is static per jit
    specialisation -- it fixes the past's time extent.

    The past is kept at its exact length (no padding): each chunk's keys
    are ``past ++ own`` at the same key indices the packed pass uses, so
    every valid lane reduces over the identical value set.  Reduction
    *widths* still differ from the packed pass (t grows chunk by chunk),
    so logits agree to reduction-order ULP noise -- the same tolerance
    class as dense-vs-paged attention, and token-identical through the
    sampler (the chunked-prefill parity test pins this).

    Returns (logits [B,1,V], cache_chunk): ``logits[b]`` is taken at the
    row's final position clamped into this chunk, meaningful only when
    ``start <= lengths[b]-1 < start+C`` (the caller keeps that chunk's
    row); ``cache_chunk`` matches the corresponding position range of a
    ``prefill_batched`` cache (``pos`` masked per row, -1 beyond its
    length).  No ``extra_embeds``: admissions with a VLM/audio prefix
    keep the packed path.
    """
    if not batched_prefill_supported(cfg):
        raise ValueError(f"{cfg.name}: chunked prefill needs all-attention "
                         "layers (recurrent state would fold in padding)")
    x = L.embed(params["embed"], cfg, tokens)
    b, c = x.shape[0], x.shape[1]
    start = int(start)
    positions = start + jnp.arange(c)[None]
    k_positions = jnp.arange(start + c)[None]
    x = shard(x, ("batch", "seq", "embed"))
    if cond is not None:
        cond = cond.astype(x.dtype)
    x, caches, _ = _run_segments_seq(params, cfg, x, positions=positions,
                                     cond=cond, mesh=mesh, shard=shard,
                                     pasts=past, k_positions=k_positions,
                                     collect_cache=True)
    x = L.rms_norm(x, params["final_norm"])
    take = jnp.clip(jnp.asarray(lengths) - 1 - start, 0, c - 1)
    last = x[jnp.arange(b), take][:, None]
    logits = L.unembed(params["embed"], cfg, last)

    pos_row = jnp.where(positions < jnp.asarray(lengths)[:, None],
                        positions, -1).astype(jnp.int32)
    segs = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        slots = []
        for j, kind_s in enumerate(pattern):
            kind = parse_kind(kind_s)
            e = caches[si][j]
            pos = jnp.broadcast_to(pos_row[None], (repeats, b, c))
            if kind.mla:
                slots.append({"ckv": e["ckv"], "krope": e["krope"],
                              "pos": pos})
            else:
                slots.append({"k": e["k"], "v": e["v"], "pos": pos})
        segs.append(slots)
    return logits, {"segments": segs}


def chunk_past_extend(past, cache_chunk):
    """Accumulate chunked-prefill past: append ``cache_chunk`` (a
    ``prefill_chunk`` second return) onto ``past`` along the time axis,
    dropping the per-row ``pos`` (the next chunk rebuilds key positions
    as the contiguous ``arange(start+C)``).  ``past=None`` starts the
    accumulation.  Eager concatenation on (possibly lazy) device arrays:
    it dispatches without blocking, so the scheduler can extend the past
    behind an in-flight macro scan."""
    segs = []
    for si, slots in enumerate(cache_chunk["segments"]):
        new_slots = []
        for j, e in enumerate(slots):
            ent = {k_: v_ for k_, v_ in e.items() if k_ != "pos"}
            if past is not None:
                old = past["segments"][si][j]
                ent = {k_: jnp.concatenate([old[k_], v_], axis=2)
                       for k_, v_ in ent.items()}
            new_slots.append(ent)
        segs.append(new_slots)
    return {"segments": segs}


def row_cache_from_batched(cache, cfg: ModelConfig, bi: int, length: int,
                           max_len: int):
    """Extract request ``bi`` from a ``prefill_batched`` cache as the row
    pytree a packed dense cache expects at one batch row: attention
    entries [R, cap, ...] with ring-consistent window layout (slot ==
    pos % window) and pos == -1 beyond ``length`` -- exactly what
    per-request ``prefill`` + ``pad_cache`` would have produced, modulo
    values at masked slots (which attention zeroes out)."""
    segs = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        slots = []
        for j, kind_s in enumerate(pattern):
            kind = parse_kind(kind_s)
            e = cache["segments"][si][j]
            window = cfg.window_size if kind.base == "local" else 0
            cap = min(window, max_len) if window > 0 else max_len
            s = e["pos"].shape[2]
            if length > cap:
                # window ring: slot i holds the unique in-window position
                # with pos % cap == i (the invariant decode's ring
                # overwrite preserves)
                lo = length - cap
                idx = lo + (np.arange(cap) - lo) % cap
                pos_np = idx
            else:
                idx = np.minimum(np.arange(cap), s - 1)
                pos_np = np.where(np.arange(cap) < length,
                                  np.arange(cap), -1)
            src = jnp.asarray(idx, jnp.int32)
            pos_row = jnp.broadcast_to(
                jnp.asarray(pos_np, jnp.int32)[None], (repeats, cap))
            row = {key_: (pos_row if key_ == "pos" else a[:, bi, src])
                   for key_, a in e.items()}
            slots.append(row)
        segs.append(slots)
    return {"segments": segs}


def decode_step_paged(params, cfg: ModelConfig, kv, tables, gid_tables,
                      tokens, cur_pos, *, page_size: int,
                      impl: str = "reference", cond=None, state_cols=None,
                      mesh=None, shard=_IDENT):
    """One decode step with EVERY state-bearing layer reading and writing
    the shared paged pools -- the fully-paged serving hot path (no dense
    per-row cache exists).  Plain attention gathers (k, v) pages through
    ``kernels.paged_attention``; MLA gathers compressed (ckv, krope)
    pages through ``kernels.paged_attention_mla``; recurrent cells read
    and write one packed state page per request.

    kv: the layered pool pytree (``SharedPagedPools.kv_view``): one leaf
        set per ``state_slot_meta`` entry, named per geometry
        (``k/v``, ``ckv/krope``, ``state``; absent leaves are None).  HBM
        leaves are the resident slot pools the kernels gather from, host
        leaves the write-through backing copy that survives eviction.
    tables:     int32[B, n_row_pages] physical HBM slot per row page
                (-1 = padding / inactive row; reads are masked by length,
                writes are dropped).
    gid_tables: int32[B, n_row_pages] global logical page id per row page
                (-1 = padding), for the host-copy write-through.
    tokens: [B,1]; cur_pos: int32[B], position of the token being decoded
                (-1 = inactive row).
    cond:       [B, T, d] cross-attention conditioning for xattn slots.
    state_cols: int32[B] column of each request's state page in its row
                tables (-1 = none); required iff the config has recurrent
                slots.

    Returns (logits [B,1,V], new_kv, page_mass f32[B, n_row_pages]) where
    ``page_mass`` is the per-request access mass per row page aggregated
    over ALL state-bearing layers (head-normalised attention mass per
    attention layer, a unit touch on the state page per recurrent layer,
    mean across layers -- each active row sums to ~1): the true aggregate
    traffic signal online Cori tunes from.
    """
    return _paged_decode_core(params, cfg, kv, tables, gid_tables, tokens,
                              cur_pos, page_size=page_size, impl=impl,
                              cond=cond, state_cols=state_cols, mesh=mesh,
                              shard=shard)


def _paged_decode_core(params, cfg: ModelConfig, kv, tables, gid_tables,
                       tokens, cur_pos, *, page_size: int, impl: str,
                       cond=None, state_cols=None, mesh=None, shard=_IDENT):
    """The traced body shared by ``decode_step_paged`` (one launch per
    token) and ``decode_macro_step`` (one launch per movement period)."""
    b = tokens.shape[0]
    n_row_pages = tables.shape[1]
    active = cur_pos >= 0
    lengths = jnp.where(active, cur_pos + 1, 0)
    safe_pos = jnp.maximum(cur_pos, 0)
    pg = safe_pos // page_size
    off = safe_pos % page_size
    wslot = tables[jnp.arange(b), pg]          # -1 when padding/inactive
    wgid = gid_tables[jnp.arange(b), pg]
    big = jnp.int32(2 ** 30)                   # out of bounds => dropped
    wslot = jnp.where(active & (wslot >= 0), wslot, big)
    wgid = jnp.where(active & (wgid >= 0), wgid, big)
    if state_cols is None and has_state_pages(cfg):
        raise ValueError(f"{cfg.name}: paged decode over recurrent slots "
                         "needs state_cols (column of each row's state "
                         "page in `tables`)")
    if state_cols is not None:
        scol = jnp.maximum(jnp.asarray(state_cols, jnp.int32), 0)
        sslot = tables[jnp.arange(b), scol]
        sgid = gid_tables[jnp.arange(b), scol]
        svalid = active & (jnp.asarray(state_cols) >= 0) & (sslot >= 0)
        srd = jnp.maximum(sslot, 0)            # clamped read index
        swslot = jnp.where(svalid, sslot, big)
        swgid = jnp.where(svalid, sgid, big)
        # a recurrent layer touches its state page once per step: a unit
        # of access mass at the state column, same scale as an attention
        # layer's head-normalised row (sums to ~1)
        smass = jnp.where(
            svalid[:, None] & (jnp.arange(n_row_pages)[None]
                               == scol[:, None]), 1.0, 0.0)

    x = L.embed(params["embed"], cfg, tokens)
    x = shard(x, ("batch", "seq", "embed"))
    if cond is not None:
        cond = cond.astype(x.dtype)
    mass_sum = jnp.zeros((b, n_row_pages), jnp.float32)
    n_layers = 0
    new_kv = {k_: list(v_) for k_, v_ in kv.items()}

    def _block_tail(xx, slot_p, kind):
        """Post-core residual stack shared by every geometry: cross-attn
        conditioning, MoE / MLP."""
        if kind.xattn and cond is not None:
            hx = L.rms_norm(xx, slot_p["norm_x"])
            cpos = jnp.arange(cond.shape[1])[None]
            cmask = jnp.ones((1, 1, cond.shape[1]), bool)
            o2, _ = L.attention_apply(slot_p["xattn"], cfg, hx, cond,
                                      cur_pos[:, None], cmask,
                                      kv_positions=cpos, use_rope=False)
            xx = xx + o2
        if kind.moe:
            h2 = L.rms_norm(xx, slot_p["norm2"])
            o2, _ = M.moe_apply(slot_p["moe"], cfg, h2, mesh)
            xx = xx + o2
        elif cfg.d_ff > 0 and "mlp" in slot_p:
            h2 = L.rms_norm(xx, slot_p["norm2"])
            xx = xx + L.mlp_apply(slot_p["mlp"], cfg, h2)
        return shard(xx, ("batch", "seq", "embed"))

    def attn_block(xx, slot_p, leaves, kind):
        """Plain/local attention against its (k, v) pool leaves
        (per-repeat slices: [hbm_pages|n_logical, page, KV, D])."""
        kh, vh, khost, vhost = leaves
        window = cfg.window_size if kind.base == "local" else 0
        h = L.rms_norm(xx, slot_p["norm1"])
        q = jnp.einsum("bsd,dhk->bshk", h,
                       slot_p["attn"]["wq"].astype(h.dtype))
        k_new = jnp.einsum("bsd,dhk->bshk", h,
                           slot_p["attn"]["wk"].astype(h.dtype))
        v_new = jnp.einsum("bsd,dhk->bshk", h,
                           slot_p["attn"]["wv"].astype(h.dtype))
        if cfg.qk_norm:
            q = L.rms_norm(q, slot_p["attn"]["q_norm"])
            k_new = L.rms_norm(k_new, slot_p["attn"]["k_norm"])
        q = L.rope(q, cur_pos[:, None], cfg.rope_theta)
        k_new = L.rope(k_new, cur_pos[:, None], cfg.rope_theta)
        # write-through: the decoding token's KV lands in its HBM slot
        # page AND the host backing page before the gather, so the kernel
        # attends the current token too
        k1 = k_new[:, 0].astype(kh.dtype)
        v1 = v_new[:, 0].astype(vh.dtype)
        kh = kh.at[wslot, off].set(k1, mode="drop")
        vh = vh.at[wslot, off].set(v1, mode="drop")
        khost = khost.at[wgid, off].set(k1, mode="drop")
        vhost = vhost.at[wgid, off].set(v1, mode="drop")
        ctx, mass = ops.paged_attention(
            q[:, 0], kh, vh, tables, lengths, window=window,
            softcap=cfg.softcap, return_mass=True, impl=impl)
        out = jnp.einsum("bshk,hkd->bsd", ctx[:, None],
                         slot_p["attn"]["wo"].astype(xx.dtype))
        xx = _block_tail(xx + out, slot_p, kind)
        return xx, (kh, vh, khost, vhost, mass)

    def mla_block(xx, slot_p, leaves, kind):
        """Absorbed-matrix MLA against its compressed (ckv, krope) pool
        leaves (per-repeat slices: [hbm_pages|n_logical, page, R|K]) --
        the paged analogue of ``layers.mla_decode``."""
        ckvh, krh, ckvhost, krhost = leaves
        m = cfg.mla
        p = slot_p["attn"]
        h = L.rms_norm(xx, slot_p["norm1"])
        cq = L.rms_norm(h @ p["w_dq"].astype(h.dtype), p["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(h.dtype))
        q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
        q_rope = L.rope(q_rope, cur_pos[:, None], cfg.rope_theta)
        c_new = L.rms_norm(h @ p["w_dkv"].astype(h.dtype), p["kv_norm"])
        kr_new = L.rope((h @ p["w_kr"].astype(h.dtype))[:, :, None, :],
                        cur_pos[:, None], cfg.rope_theta)[:, :, 0, :]
        c1 = c_new[:, 0].astype(ckvh.dtype)
        r1 = kr_new[:, 0].astype(krh.dtype)
        ckvh = ckvh.at[wslot, off].set(c1, mode="drop")
        krh = krh.at[wslot, off].set(r1, mode="drop")
        ckvhost = ckvhost.at[wgid, off].set(c1, mode="drop")
        krhost = krhost.at[wgid, off].set(r1, mode="drop")
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope,
                           p["w_uk"].astype(h.dtype))
        scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
        ctx, mass = ops.paged_attention_mla(
            q_abs[:, 0], q_rope[:, 0], ckvh, krh, tables, lengths,
            scale=scale, return_mass=True, impl=impl)
        out = jnp.einsum("bshr,rhk->bshk", ctx[:, None],
                         p["w_uv"].astype(xx.dtype))
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(xx.dtype))
        xx = _block_tail(xx + out, slot_p, kind)
        return xx, (ckvh, krh, ckvhost, krhost, mass)

    def state_block(xx, slot_p, leaves, kind):
        """Recurrent cell against its packed state page (per-repeat
        slices: [hbm_pages|n_logical, state_dim]).  The cell state lives
        in the pool like any page: read from the HBM slot, step, write
        back through both tiers."""
        sth, sthost = leaves
        h = L.rms_norm(xx, slot_p["norm1"])
        proto = _zero_state(cfg, kind, b)
        state = unpack_state(sth[srd], proto)
        step = {"mlstm": R.mlstm_step, "slstm": R.slstm_step,
                "rglru": R.rglru_step}[kind.base]
        out, new_state = step(slot_p["cell"], cfg, h, state)
        flat = pack_state(new_state).astype(sth.dtype)
        sth = sth.at[swslot].set(flat, mode="drop")
        sthost = sthost.at[swgid].set(flat, mode="drop")
        xx = _block_tail(xx + out, slot_p, kind)
        return xx, (sth, sthost, smass)

    def slot_leaves(kind, li):
        if kind.is_attention and kind.mla:
            return (kv["ckv_hbm"][li], kv["krope_hbm"][li],
                    kv["ckv_host"][li], kv["krope_host"][li])
        if kind.is_attention:
            return (kv["k_hbm"][li], kv["v_hbm"][li],
                    kv["k_host"][li], kv["v_host"][li])
        return (kv["state_hbm"][li], kv["state_host"][li])

    def store_leaves(kind, li, upd):
        if kind.is_attention and kind.mla:
            (new_kv["ckv_hbm"][li], new_kv["krope_hbm"][li],
             new_kv["ckv_host"][li], new_kv["krope_host"][li]) = upd[:-1]
        elif kind.is_attention:
            (new_kv["k_hbm"][li], new_kv["v_hbm"][li],
             new_kv["k_host"][li], new_kv["v_host"][li]) = upd[:-1]
        else:
            new_kv["state_hbm"][li], new_kv["state_host"][li] = upd[:-1]
        return upd[-1]

    def one_block(xx, slot_p, leaves, kind):
        if kind.is_attention and kind.mla:
            return mla_block(xx, slot_p, leaves, kind)
        if kind.is_attention:
            return attn_block(xx, slot_p, leaves, kind)
        return state_block(xx, slot_p, leaves, kind)

    li = 0
    for si, (pattern, repeats) in enumerate(cfg.segments):
        kinds = [parse_kind(s_) for s_ in pattern]
        slot_params = params["segments"][si]
        nslots = len(kinds)
        seg_leaves = [slot_leaves(kinds[j], li + j) for j in range(nslots)]

        # execution order matches decode_step: the whole pattern runs per
        # repeat (slots inner, repeats outer)
        def body(xx, per_repeat):
            slot_ps, slot_lvs = per_repeat
            new_lvs = []
            for j, kind in enumerate(kinds):
                xx, upd = one_block(xx, slot_ps[j], slot_lvs[j], kind)
                new_lvs.append(upd)
            return xx, new_lvs

        if cfg.unroll_layers or repeats == 1:
            reps = []
            for r in range(repeats):
                per = jax.tree.map(lambda a: a[r], (slot_params, seg_leaves))
                x, lvs = body(x, per)
                reps.append(lvs)
            stacked = [jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                                    *[rep[j] for rep in reps])
                       for j in range(nslots)]
        else:
            x, stacked = jax.lax.scan(body, x, (slot_params, seg_leaves))
        for j in range(nslots):
            mass = store_leaves(kinds[j], li + j, stacked[j])
            mass_sum = mass_sum + mass.sum(axis=0)
            n_layers += repeats
        li += nslots

    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(params["embed"], cfg, x)
    page_mass = mass_sum / max(1, n_layers)
    page_mass = jnp.where(active[:, None], page_mass, 0.0)
    return logits, new_kv, page_mass


def _sample_row(logits_row, key, temperature):
    """Per-row sampling lane (vmapped): bit-identical to the host path's
    ``engine._sample(logits[row:row+1, 0], key, temperature)``.  The
    categorical draw consumes the same key stream as the per-request call
    (same shape [1, V], so the threefry bits coincide); greedy rows take
    the argmax and discard the draw."""
    greedy = jnp.argmax(logits_row, axis=-1)
    safe_t = jnp.where(temperature > 0.0, temperature, 1.0)
    drawn = jax.random.categorical(key, logits_row / safe_t, axis=-1)
    return jnp.where(temperature > 0.0, drawn, greedy)        # [1]


def decode_macro_step(params, cfg: ModelConfig, kv, tables, gid_tables,
                      tokens, cur_pos, keys, iters, emitted, max_new,
                      eos_ids, temps, *, n_steps: int, page_size: int,
                      impl: str = "reference", cond=None, state_cols=None,
                      mesh=None, shard=_IDENT):
    """Up to ``n_steps`` fully-paged decode steps in ONE device launch.

    A ``jax.lax.scan`` drives ``_paged_decode_core`` with on-device
    sampling (the exact per-request ``fold_in(key, i)`` schedule of
    ``engine.generate``), on-device mass accumulation, and EOS / length
    masking, so the host only uploads page tables once per movement
    period and downloads ``(tokens, summed mass, finished flags)`` once
    -- the serving hot loop never synchronises at token granularity.

    Per-row serving state (all int32[B] / f32[B] unless noted):
      keys     uint32[B, 2] raw PRNG keys (``req._key``)
      iters    decode iterations done (``req._i``: the fold_in schedule)
      emitted  tokens emitted so far incl. the prefill sample
      max_new  the request's token budget (stop when ``emitted`` reaches it)
      eos_ids  per-request EOS token (-1 = none)
      temps    sampling temperature

    A row is *alive* while ``cur_pos >= 0`` and no stop condition has
    fired; dead rows freeze completely -- no KV writes (their ``cur`` is
    -1 so the core masks them), no key folds, no mass, no emission -- so
    the emitted stream is bit-identical to the per-token path, which
    retires a request on the host before the next launch.  The stop mask
    is also evaluated at entry over the *incoming* token and budget: the
    pipelined scheduler admits rows whose prefill-sampled first token is
    still in flight, and such a row freezes before its first decode step
    if that token already hits EOS or ``max_new``.

    Returns ``(tokens_out int32[n_steps, B] (-1 = row not alive), new_kv,
    state)`` with ``state = {mass_sum f32[B, n_row_pages], alive_steps
    int32[B], pos, keys, iters, emitted, stopped bool[B]}`` -- everything
    the scheduler needs to retire finished requests and feed the monitor
    one merged mass per period.
    """
    b = tokens.shape[0]
    n_row_pages = tables.shape[1]

    def run(carry):
        kv, tok, pos, ks, it, em, stopped, mass_sum, alive_steps = carry
        alive = (pos >= 0) & ~stopped
        cur = jnp.where(alive, pos, -1)
        logits, kv, mass = _paged_decode_core(
            params, cfg, kv, tables, gid_tables, tok, cur,
            page_size=page_size, impl=impl, cond=cond,
            state_cols=state_cols, mesh=mesh, shard=shard)
        mass_sum = mass_sum + mass            # core zeroes dead rows
        alive_steps = alive_steps + alive.astype(jnp.int32)
        ks2 = jax.vmap(jax.random.fold_in)(ks, it)
        new_tok = jax.vmap(_sample_row)(logits, ks2, temps)   # [B, 1]
        ks = jnp.where(alive[:, None], ks2, ks)
        it = jnp.where(alive, it + 1, it)
        em = jnp.where(alive, em + 1, em)
        tok = jnp.where(alive[:, None], new_tok.astype(tok.dtype), tok)
        stop_now = alive & ((em >= max_new)
                            | ((eos_ids >= 0) & (tok[:, 0] == eos_ids)))
        stopped = stopped | stop_now
        pos = jnp.where(alive, pos + 1, pos)
        out = jnp.where(alive, tok[:, 0], -1)
        return (kv, tok, pos, ks, it, em, stopped, mass_sum,
                alive_steps), out

    def body(carry, _):
        # all rows done: skip the model entirely (lax.cond executes one
        # branch at runtime, so a macro longer than the remaining work
        # costs nothing past the last live token)
        kv, tok, pos, ks, it, em, stopped, *_ = carry
        any_alive = jnp.any((pos >= 0) & ~stopped)
        return jax.lax.cond(
            any_alive, run,
            lambda c: (c, jnp.full((b,), -1, jnp.int32)), carry)

    # a row may enter with its stop condition already met: the pipelined
    # scheduler admits fresh rows with the prefill-sampled first token
    # still in flight, so the EOS / budget check the synchronous host
    # path runs at activation happens here instead.  Such a row freezes
    # before its first decode step (alive_steps 0, no KV writes, no
    # tokens); for every other caller the incoming token was already
    # host-checked and this predicate is identically False.
    em0 = jnp.asarray(emitted, jnp.int32)
    max_new = jnp.asarray(max_new, jnp.int32)
    stopped0 = ((cur_pos >= 0)
                & ((em0 >= max_new)
                   | ((eos_ids >= 0) & (tokens[:, 0] == eos_ids))))
    init = (kv, tokens, cur_pos, keys, jnp.asarray(iters, jnp.int32),
            em0, stopped0,
            jnp.zeros((b, n_row_pages), jnp.float32),
            jnp.zeros((b,), jnp.int32))
    (kv, tok, pos, ks, it, em, stopped, mass_sum,
     alive_steps), toks_out = jax.lax.scan(body, init, None, length=n_steps)
    state = {"mass_sum": mass_sum, "alive_steps": alive_steps, "pos": pos,
             "keys": ks, "iters": it, "emitted": em, "stopped": stopped,
             "last_tok": tok}
    return toks_out, kv, state


def init_specs_only(cfg: ModelConfig):
    """Logical-axis spec tree without allocating full-size params.

    The spec tree's structure depends only on the segment patterns and
    feature flags, never on dims -- so build it from a tiny structure twin
    of the config (same patterns/flags, toy sizes).
    """
    import dataclasses as _dc

    from repro.models.config import MLAConfig as _MLA
    from repro.models.config import MoEConfig as _MoE

    kv = 4 if cfg.num_heads == cfg.num_kv_heads else min(4, max(
        1, cfg.num_kv_heads))
    twin = _dc.replace(
        cfg,
        d_model=64, num_heads=4, num_kv_heads=kv, head_dim=16,
        d_ff=128 if cfg.d_ff else 0, vocab_size=64,
        segments=tuple((pat, 1) for pat, _ in cfg.segments),
        lru_width=32 if cfg.lru_width else 0,
        cond_dim=64 if cfg.cond_dim else 0,
        window_size=min(cfg.window_size, 8) if cfg.window_size else 0,
        moe=(_MoE(num_experts=8, top_k=2, d_expert=16,
                  num_shared=cfg.moe.num_shared,
                  d_shared=16 if (cfg.moe and cfg.moe.d_shared) else 0)
             if cfg.moe else None),
        mla=(_MLA(q_lora_rank=16, kv_lora_rank=8, qk_nope_dim=8,
                  qk_rope_dim=4, v_head_dim=8) if cfg.mla else None),
        remat=False, moe_impl="dense",
    )
    return init(jax.random.PRNGKey(0), twin)[1]
