"""StableLM-2-12B [hf:stabilityai]: dense GQA, SwiGLU."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", family="dense",
        d_model=5120, num_heads=32, num_kv_heads=8, head_dim=160,
        d_ff=13824, vocab_size=100352,
        segments=((("attn",), 40),),
        mlp_kind="swiglu", tie_embeddings=False,
        rope_theta=10_000.0, max_seq_len=32768)
