"""Sharded checkpointing: atomic, async-capable, elastic across meshes.

Format: one ``.npz`` per checkpoint step holding the flattened state leaves
(key = leaf index) + a manifest of shapes/dtypes.  Writes go to a temp dir
and are atomically renamed, so a crash mid-save never corrupts the latest
checkpoint.  ``restore`` rebuilds the pytree from a template (structure is
code-defined, not serialized) and ``device_put``s each leaf with the
*target* sharding -- loading a 16x16-mesh checkpoint onto a 2x16x16 mesh
(or CPU) is the same code path, which is what elastic rescaling needs.

``AsyncCheckpointer`` overlaps serialization with training (one in-flight
save, joined before the next).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]


def _ckpt_dir(base, step: int) -> pathlib.Path:
    return pathlib.Path(base) / f"step_{step:010d}"


def save(base, step: int, state, keep: int = 3) -> pathlib.Path:
    """Atomic synchronous save.  Gathers sharded leaves to host."""
    base = pathlib.Path(base)
    base.mkdir(parents=True, exist_ok=True)
    final = _ckpt_dir(base, step)
    tmp = base / f".tmp_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves = jax.tree.leaves(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(tmp / "state.npz", **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "time": time.time(),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(base, keep)
    return final


def _gc(base: pathlib.Path, keep: int):
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*"))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(_ckpt_dir(base, s), ignore_errors=True)


def latest_step(base) -> Optional[int]:
    base = pathlib.Path(base)
    if not base.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*")
                   if (p / "manifest.json").exists())
    return steps[-1] if steps else None


def restore(base, step: int, template, shardings=None):
    """Rebuild `template`'s structure from disk; place with `shardings`
    (a matching tree of NamedSharding, or None for default placement)."""
    d = _ckpt_dir(base, step)
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "state.npz") as z:
        leaves = [z[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    treedef = jax.tree.structure(template)
    assert treedef.num_leaves == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, template "
        f"{treedef.num_leaves} -- incompatible config")
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings,
                                    is_leaf=lambda x: hasattr(x, "spec"))
        leaves = [jax.device_put(x, s) for x, s in zip(leaves, sh_leaves)]
    else:
        leaves = [jax.numpy.asarray(x) for x in leaves]
    return jax.tree.unflatten(treedef, leaves)


class AsyncCheckpointer:
    """One-in-flight background saver."""

    def __init__(self, base, keep: int = 3):
        self.base = base
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state):
        self.wait()
        # Gather to host *before* handing to the thread (device buffers may
        # be donated by the next step).
        host_state = jax.tree.map(np.asarray, state)
        # Non-daemon: an enqueued checkpoint survives an orderly crash (an
        # uncaught exception unwinding the trainer) -- interpreter shutdown
        # joins the writer, so restarts resume from the newest enqueued
        # step, not the previous one.
        self._thread = threading.Thread(
            target=save, args=(self.base, step, host_state, self.keep))
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
