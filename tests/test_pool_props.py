"""Property tests for ``SharedPagedPools`` bookkeeping invariants.

The pool is the single allocator behind every cache geometry (attention
k/v, MLA compressed rows, recurrent state pages, shared prefixes), so
its invariants are load-bearing for the whole serving stack:

  * no slot double-assignment: ``slot_of`` / ``page_of_slot`` stay
    mutually-inverse partial maps at all times,
  * alloc/free conservation: free + allocated == n_logical, allocation
    accounting matches the owner mask, freed ids never leak,
  * slot_of agrees with the residency gauges the observability layer
    exports (``resident_mask`` vs occupied slots),
  * per-geometry leaves never cross-contaminate: a scatter into one
    layer's leaf leaves every other layer's storage bit-identical.

When Hypothesis is installed the op sequences are drawn (and shrunk) by
it; otherwise a seeded random-walk fallback runs the same interpreter,
so the properties are exercised on every environment.
"""

import numpy as np
import pytest

from repro.memtier.tiering import SharedPagedPools

N_LOGICAL = 24
HBM = 8


class _Harness:
    """Op-sequence interpreter with invariant checks after every op."""

    def __init__(self, n_logical=N_LOGICAL, hbm=HBM):
        self.pools = SharedPagedPools(n_logical, hbm)
        self.live = {}                      # owner -> gids
        self.next_owner = 0

    # -- invariants ----------------------------------------------------------
    def check(self):
        p = self.pools
        held = np.nonzero(p.slot_of >= 0)[0]
        slots = p.slot_of[held]
        assert len(set(slots.tolist())) == len(slots), \
            "two pages mapped to one HBM slot"
        assert np.all(p.page_of_slot[slots] == held), \
            "slot_of / page_of_slot stopped being inverse"
        occ = np.nonzero(p.page_of_slot >= 0)[0]
        back = p.page_of_slot[occ]
        assert len(set(back.tolist())) == len(back), \
            "one page occupies two slots"
        assert np.all(p.slot_of[back] == occ)
        # conservation
        assert p.free_pages + p.allocated_pages == p.n_logical
        assert int(p.allocated_mask.sum()) == p.allocated_pages
        assert set(p._free_ids).isdisjoint(
            np.nonzero(p.allocated_mask)[0].tolist()), \
            "allocated page still on the free list"
        assert len(set(p._free_ids)) == len(p._free_ids)
        # residency gauge agreement
        assert int(p.resident_mask.sum()) == int((p.page_of_slot >= 0).sum())
        assert int(p.resident_mask.sum()) <= p.hbm_pages
        # the model's view of liveness matches the pool's
        live = (np.concatenate(list(self.live.values()))
                if self.live else np.empty(0, np.int64))
        assert np.array_equal(np.sort(live),
                              np.nonzero(p.allocated_mask)[0])

    # -- ops -----------------------------------------------------------------
    def _live_gids(self):
        if not self.live:
            return np.empty(0, np.int64)
        return np.concatenate(list(self.live.values()))

    def _subset(self, a, k):
        gids = np.unique(self._live_gids())
        if gids.size == 0:
            return gids
        k = max(1, min(k, gids.size, self.pools.hbm_pages))
        start = a % gids.size
        idx = (start + np.arange(k)) % gids.size
        return np.unique(gids[idx])

    def op_alloc(self, k):
        k = max(1, k)
        before = self.pools.free_pages
        gids = self.pools.alloc(k, self.next_owner)
        if k > before:
            assert gids is None, "alloc over-committed the logical space"
        else:
            assert gids is not None, "alloc refused with pages free"
            assert len(set(gids.tolist())) == k
            assert np.all(self.pools.owner_of[gids] == self.next_owner)
            self.live[self.next_owner] = gids
            self.next_owner += 1

    def op_free(self, idx):
        if not self.live:
            return
        owner = sorted(self.live)[idx % len(self.live)]
        gids = self.live.pop(owner)
        self.pools.free(gids)
        assert not self.pools.resident_mask[gids].any(), \
            "freed page still resident"
        assert np.all(self.pools.owner_of[gids] == -1)

    def op_ensure(self, a, k):
        sub = self._subset(a, k)
        if sub.size == 0:
            return
        was = self.pools.table(sub) >= 0
        fetched = self.pools.ensure_resident(sub)
        assert fetched == int((~was).sum()), \
            "fetch count disagrees with prior residency"
        assert np.all(self.pools.table(sub) >= 0), \
            "ensure_resident left a page host-only"

    def op_assign(self, a, k):
        sub = self._subset(a, k)
        if sub.size == 0:
            return
        slots = self.pools.assign_slots(sub)
        assert np.all(slots >= 0)
        assert len(set(slots.tolist())) == len(slots), \
            "assign_slots handed one slot to two pages"
        assert np.array_equal(slots, self.pools.table(sub))

    OPS = ("alloc", "free", "ensure", "assign")

    def run(self, ops):
        for code, a, b in ops:
            name = self.OPS[code % len(self.OPS)]
            if name == "alloc":
                self.op_alloc(a % (HBM + 4))
            elif name == "free":
                self.op_free(a)
            elif name == "ensure":
                self.op_ensure(a, b % HBM + 1)
            else:
                self.op_assign(a, b % HBM + 1)
            self.check()
        # drain: freeing everything restores the empty pool
        for owner in sorted(self.live):
            self.pools.free(self.live[owner])
        self.live.clear()
        self.check()
        assert self.pools.free_pages == self.pools.n_logical


def _random_ops(rng, n=40):
    return [(int(rng.integers(0, 4)), int(rng.integers(0, 64)),
             int(rng.integers(0, 64))) for _ in range(n)]


def test_pool_invariants_seeded_walks():
    """Seeded fallback: the same interpreter Hypothesis drives, over 30
    deterministic random walks — runs everywhere, shrinks nowhere."""
    for seed in range(30):
        rng = np.random.default_rng(seed)
        _Harness().run(_random_ops(rng))


def test_pool_invariants_hypothesis():
    """Property-based run (skipped when Hypothesis is unavailable)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 63),
                                  st.integers(0, 63)),
                        min_size=1, max_size=60))
    def prop(ops):
        _Harness().run(ops)

    prop()


def test_alloc_exhaustion_and_reuse_is_deterministic():
    p = SharedPagedPools(8, 4)
    a = p.alloc(5, 0)
    b = p.alloc(3, 1)
    assert p.alloc(1, 2) is None and p.free_pages == 0
    p.free(a)
    c = p.alloc(5, 3)
    assert np.array_equal(np.sort(c), np.sort(a)), \
        "freed ids must be the ones reused (lowest-first determinism)"
    p.free(b)
    p.free(c)
    assert p.free_pages == 8


def test_attach_emits_geometry_event_and_plane_accounting():
    """pool.attach reports the layered geometry (layer count, leaf-name
    set, migration planes) so a trace reader can interpret tier.move's
    pages_moved without the config in hand."""
    from repro import obs
    from repro.obs import telemetry

    prev = telemetry.get()
    r = obs.install(obs.Recorder(enabled=True))
    try:
        p = SharedPagedPools(N_LOGICAL, HBM)
        p.attach_layered([(1, {"ckv": (4, 5), "krope": (4, 2)}),
                          (2, {"state": (7,)})])
        ev = r.events("pool.attach")
        assert len(ev) == 1
        assert ev[0]["layers"] == 2
        assert set(ev[0]["leaves"].split(",")) == {"ckv", "krope", "state"}
        assert ev[0]["planes"] == 2 == p.move_planes
    finally:
        obs.install(prev)


def test_layered_leaves_never_cross_contaminate():
    """Scatters into one geometry's leaf leave every other layer's
    storage bit-identical — the mixed-geometry pool is partitioned."""
    import jax.numpy as jnp
    from repro.memtier.tiering import (PAGE_DROP, write_pages_batched,
                                       write_state_pages)

    ps = 4
    specs = [
        (2, {"k": (ps, 2, 3), "v": (ps, 2, 3)}),   # plain attention
        (1, {"ckv": (ps, 5), "krope": (ps, 2)}),   # MLA compressed
        (3, {"state": (7,)}),                      # recurrent state
    ]
    p = SharedPagedPools(N_LOGICAL, HBM)
    p.attach_layered(specs)
    assert p.layer_leaves == (("k", "v"), ("ckv", "krope"), ("state",))
    assert p.move_planes == 2
    kv = p.kv_view()
    # shape law: host [R, n_logical, *trail], hbm [R, hbm, *trail];
    # absent leaves are None, never zero-sized placeholders
    for li, (r, leaves) in enumerate(specs):
        for name in ("k", "v", "ckv", "krope", "state"):
            host, hbm = kv[f"{name}_host"][li], kv[f"{name}_hbm"][li]
            if name in leaves:
                assert host.shape == (r, N_LOGICAL) + leaves[name]
                assert hbm.shape == (r, HBM) + leaves[name]
            else:
                assert host is None and hbm is None

    gids = p.alloc(3, 0)
    slots = p.assign_slots(gids)
    # token-paged write into the attention and MLA layers only
    pad = lambda x: jnp.concatenate(
        [jnp.asarray(x, jnp.int32), jnp.full((1,), PAGE_DROP, jnp.int32)]
    )[None]                                         # [J=1, n_max=3]
    leaves = {
        "k": [jnp.ones((2, 1, 2 * ps, 2, 3)), None, None],
        "v": [2 * jnp.ones((2, 1, 2 * ps, 2, 3)), None, None],
        "ckv": [None, 3 * jnp.ones((1, 1, 2 * ps, 5)), None],
        "krope": [None, 4 * jnp.ones((1, 1, 2 * ps, 2)), None],
    }
    kv = write_pages_batched(kv, leaves,
                             pad(gids[:2]), pad(slots[:2]))
    kv = write_state_pages(kv, [None, None,
                                5 * jnp.ones((3, 1, 7))],
                           jnp.asarray(gids[2:], jnp.int32),
                           jnp.asarray(slots[2:], jnp.int32))
    # every write landed where addressed...
    assert float(kv["k_host"][0][:, gids[:2]].min()) == 1.0
    assert float(kv["v_hbm"][0][:, slots[:2]].min()) == 2.0
    assert float(kv["ckv_host"][1][:, gids[:2]].min()) == 3.0
    assert float(kv["krope_hbm"][1][:, slots[:2]].min()) == 4.0
    assert float(kv["state_host"][2][:, gids[2]].min()) == 5.0
    # ...and nowhere else: other pages of the written leaves stay zero
    other = np.setdiff1d(np.arange(N_LOGICAL), gids[:2])
    assert float(jnp.abs(kv["k_host"][0][:, other]).max()) == 0.0
    sother = np.setdiff1d(np.arange(N_LOGICAL), [gids[2]])
    assert float(jnp.abs(kv["state_host"][2][:, sother]).max()) == 0.0
