"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens.

Backbone only: the EnCodec tokenizer and T5 text encoder are stubs;
``input_specs`` provides token ids (vocab 2048) and a conditioning
sequence [B, 64, d_model] consumed by per-layer cross-attention.
"""
from repro.models.config import ModelConfig

COND_LEN = 64


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=2048,
        segments=((("attn.xattn",), 48),),
        mlp_kind="gelu", tie_embeddings=False,
        cond_len=COND_LEN, cond_dim=2048,
        rope_theta=10_000.0, max_seq_len=32768)
