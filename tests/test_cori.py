"""Cori frequency generator + tuner: Eq. 1 / Eq. 2 math and tuning logic.

Property-style coverage runs as deterministic ``pytest.mark.parametrize``
cases over seeded random inputs (no optional ``hypothesis`` dependency --
the test substrate must collect on a bare jax+pytest install)."""
import numpy as np
import pytest

from repro.core import (ReuseHistogram, Tuner, candidate_periods,
                        dominant_reuse, loop_duration_histogram,
                        ordered_candidates, trials_to_best)


def _hist(values, counts, width=1000):
    return ReuseHistogram(np.asarray(values, float), np.asarray(counts, float),
                          width)


def test_dominant_reuse_single_bin():
    assert dominant_reuse(_hist([20000], [15])) == 20000


def test_dominant_reuse_eq1_hand_computed():
    # reuses [1000, 3000], repeats [10, 5], N=2 -> weights (N-i): [1, 0]
    # DR = (1*10*1000 + 0*5*3000) / (1*10 + 0) = 1000
    assert dominant_reuse(_hist([1000, 3000], [10, 5])) == 1000.0
    # Three bins: weights [2,1,0]
    # DR = (2*4*100 + 1*2*500 + 0) / (2*4 + 1*2) = (800+1000)/10 = 180
    assert dominant_reuse(_hist([100, 500, 900], [4, 2, 7])) == 180.0


def test_dominant_reuse_favours_short():
    """The (N-i) weight shifts DR towards short reuses: DR must be below the
    plain repeat-weighted mean whenever >1 bin exists."""
    h = _hist([1000, 2000, 8000], [5, 5, 5])
    plain = np.average(h.values, weights=h.counts)
    assert dominant_reuse(h) < plain


@pytest.mark.parametrize("seed", range(50))
def test_dominant_reuse_bounded(seed):
    """DR is a weighted average, so it must lie within the reuse range."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 21))
    values = rng.choice(np.arange(1, 10 ** 6), size=n, replace=False
                        ).astype(float)
    counts = rng.integers(1, 1001, size=n).astype(float)
    dr = dominant_reuse(_hist(values, counts))
    lo, hi = values.min(), values.max()
    tol = 1e-9 * max(1.0, hi)
    assert lo - tol <= dr <= hi + tol


@pytest.mark.parametrize("seed", range(10))
def test_dominant_reuse_permutation_invariant(seed):
    """Eq. 1 sorts internally: bin order in the histogram must not matter."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(2, 12))
    values = rng.choice(np.arange(1, 10 ** 5), size=n, replace=False
                        ).astype(float)
    counts = rng.integers(1, 500, size=n).astype(float)
    dr = dominant_reuse(_hist(values, counts))
    perm = rng.permutation(n)
    dr_perm = dominant_reuse(_hist(values[perm], counts[perm]))
    assert dr == pytest.approx(dr_perm, rel=1e-12)


def test_candidate_periods_eq2():
    c = candidate_periods(dr=1000.0, runtime=10000.0)
    np.testing.assert_allclose(c, [1000, 2000, 3000, 4000, 5000])
    # shortest (highest frequency) first
    assert (np.diff(c) > 0).all()


def test_candidate_periods_dr_above_half_runtime():
    c = candidate_periods(dr=8000.0, runtime=10000.0)
    np.testing.assert_allclose(c, [5000.0])


def test_candidate_periods_thinned_tail_keeps_endpoints():
    c = candidate_periods(dr=10.0, runtime=100000.0, max_candidates=16)
    assert len(c) <= 16
    assert c[0] == 10.0
    assert c[-1] <= 50000.0
    assert (np.diff(c) > 0).all()


def test_tuner_stops_on_no_improvement():
    # runtime curve: improves until 3, then worsens -> stop after patience=2
    curve = {1.0: 100, 2.0: 80, 3.0: 60, 4.0: 65, 5.0: 70, 6.0: 40}
    tuner = Tuner(lambda p: curve[p], patience=2)
    res = tuner.run([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    assert res.chosen_period == 3.0
    assert res.trials == 5  # never reaches the 6.0 decoy


def test_tuner_max_trials():
    tuner = Tuner(lambda p: 1.0 / p, max_trials=3)
    res = tuner.run([1, 2, 3, 4, 5])
    assert res.trials == 3


def test_tuner_empty_candidates_raises():
    tuner = Tuner(lambda p: 1.0)
    with pytest.raises(ValueError, match="empty candidate ladder"):
        tuner.run([])


def test_candidate_periods_endpoints_and_min_period():
    # DR below min_period snaps up to min_period
    c = candidate_periods(dr=0.25, runtime=100.0, min_period=1.0)
    assert c[0] == 1.0
    # ladder never exceeds Runtime/2
    c = candidate_periods(dr=7.0, runtime=100.0)
    assert c[-1] <= 50.0
    assert c[0] == 7.0


def test_trials_to_best():
    assert trials_to_best([5, 4, 3, 3.004, 7]) == 3
    assert trials_to_best([1.0]) == 1
    assert trials_to_best([2.0, 1.0]) == 2


def test_ordered_candidates():
    right = ordered_candidates(1000, 100, "base-right")
    left = ordered_candidates(1000, 100, "base-left")
    assert right[0] == 100 and right[-1] == 500
    np.testing.assert_array_equal(left, right[::-1])
    rnd = ordered_candidates(1000, 100, "base-random", seed=0)
    assert sorted(rnd.tolist()) == sorted(right.tolist())


def test_loop_duration_proxy_matches_trace_histogram():
    """SIV-A: loop durations approximate the reuse-distance histogram.  For
    backprop both collectors must give a DR within the same periodic band."""
    from repro.core import generate, reuse_distance_histogram
    tr = generate("backprop")
    dr_trace = dominant_reuse(reuse_distance_histogram(tr.pages, 1000))
    dr_loops = dominant_reuse(loop_duration_histogram(tr.loop_durations, 1000))
    assert abs(dr_trace - dr_loops) / dr_trace < 0.15


# ---------------------------------------------------------------------------
# degenerate / hostile inputs (regression: adversarial-traffic hardening PR)
# ---------------------------------------------------------------------------


def test_dominant_reuse_degenerate_weight_on_longest():
    """Eq. 1's (N - i) weights zero out the last (longest) reuse; when every
    *other* bin has zero repeats the denominator is 0 and all surviving
    weight sits on the longest reuse -- the degenerate branch must return
    reuse[-1], not the shortest bin."""
    assert dominant_reuse(_hist([10, 50], [0, 7])) == 50.0
    assert dominant_reuse(_hist([5, 30, 900], [0, 0, 3])) == 900.0


def test_tuner_nan_runtime_never_wins():
    """A NaN trial must not become best_rt (it would poison every later
    comparison) nor leak out of best_runtime_tried."""
    curve = {1.0: float("nan"), 2.0: 50.0, 3.0: 60.0, 4.0: 70.0}
    res = Tuner(lambda p: curve[p], patience=3).run([1.0, 2.0, 3.0, 4.0])
    assert res.chosen_period == 2.0
    assert res.chosen_runtime == 50.0
    assert res.best_runtime_tried == 50.0


def test_tuner_inf_runtime_never_wins():
    curve = {1.0: float("inf"), 2.0: 5.0}
    res = Tuner(lambda p: curve[p], patience=3).run([1.0, 2.0])
    assert res.chosen_period == 2.0
    assert res.best_runtime_tried == 5.0


def test_tuner_all_non_finite_reports_inf():
    """Every trial failing must surface as an *infinite* chosen runtime (a
    comparable sentinel), never as an adopted NaN measurement."""
    res = Tuner(lambda p: float("nan"), patience=2).run([1.0, 2.0, 3.0])
    assert res.chosen_period == 1.0
    assert np.isinf(res.chosen_runtime) and not np.isnan(res.chosen_runtime)
    assert np.isinf(res.best_runtime_tried)
