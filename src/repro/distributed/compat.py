"""jax version shim: the post-0.4 ``jax.shard_map`` API on jax 0.4.x.

The repo (and its tests) are written against the stable top-level API --
``jax.shard_map(..., check_vma=..., axis_names=...)`` and the
``jax.set_mesh`` context manager -- while CI and this container pin
jax 0.4.37, where shard_map still lives in ``jax.experimental.shard_map``
with the older parameter names:

    check_vma=bool      ->  check_rep=bool   (same meaning: verify that
                            unmapped outputs are replicated)
    axis_names={...}    ->  auto=frozenset(mesh.axis_names) - axis_names
                            (new API names the MANUAL axes; old API names
                            the AUTO complement)

``install()`` publishes the adapters as ``jax.shard_map`` /
``jax.set_mesh`` when (and only when) the running jax lacks them, so the
same call sites -- including test subprocesses that import any repro
module -- run unchanged on either side of the API break.  On jax >= the
rename, ``install()`` is a no-op and the native symbols win.
"""
from __future__ import annotations

import contextlib
import functools

import jax

__all__ = ["shard_map", "set_mesh", "install"]


def _shard_map_04x(f=None, *, mesh=None, in_specs=None, out_specs=None,
                   check_vma=True, axis_names=None, **kw):
    """``jax.shard_map`` signature, executed via 0.4.x
    ``jax.experimental.shard_map.shard_map``."""
    from jax.experimental.shard_map import shard_map as _sm
    if f is None:                      # used as a decorator factory
        return functools.partial(
            _shard_map_04x, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs, check_vma=check_vma,
            axis_names=axis_names, **kw)
    auto = frozenset()
    if axis_names is not None and mesh is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=bool(check_vma), auto=auto)


@contextlib.contextmanager
def _set_mesh_04x(mesh):
    """``jax.set_mesh`` stand-in: 0.4.x shard_map takes the mesh
    explicitly, so the context only needs to scope it syntactically."""
    yield mesh


def _axis_size_04x(axis_name):
    """``jax.lax.axis_size`` stand-in: inside a 0.4.x manual-axes body,
    ``jax.core.axis_frame(name)`` IS the (static) axis size."""
    import jax.core as _core
    return int(_core.axis_frame(axis_name))


def install() -> None:
    """Publish the adapters on the ``jax`` module where missing."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_04x
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _set_mesh_04x
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = _axis_size_04x


install()

shard_map = jax.shard_map
set_mesh = jax.set_mesh
