"""Qwen3-14B [hf:Qwen]: dense GQA with per-head qk-norm."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
        d_ff=17408, vocab_size=151936,
        segments=((("attn",), 40),),
        mlp_kind="swiglu", qk_norm=True, tie_embeddings=False,
        rope_theta=1_000_000.0, max_seq_len=32768)
