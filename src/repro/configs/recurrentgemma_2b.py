"""RecurrentGemma-2B [arXiv:2402.19427]: RG-LRU + local attention, 2:1.

Griffin pattern (rec, rec, local-attn) x 8 + (rec, rec) = 26 layers.
long_500k runs: RG-LRU state is O(1); local attention window 2048.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
        d_ff=7680, vocab_size=256000,
        segments=((("rglru", "rglru", "local"), 8), (("rglru", "rglru"), 1)),
        window_size=2048, lru_width=2560, mlp_kind="swiglu",
        tie_embeddings=True, rope_theta=10_000.0, max_seq_len=1_048_576,
        supports_long_context=True)
