"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps.

Property-style coverage runs as deterministic ``pytest.mark.parametrize``
cases over seeded random inputs (no optional ``hypothesis`` dependency)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# page_hist
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_pages,tile_accesses", [(512, 100), (1024, 1000),
                                                     (2048, 4096)])
def test_page_hist_matches_ref(num_pages, tile_accesses):
    key = jax.random.PRNGKey(num_pages)
    ids = jax.random.randint(key, (tile_accesses,), 0, num_pages, jnp.int32)
    hot = jax.random.uniform(jax.random.PRNGKey(1), (num_pages,)) * 3
    for alpha, thr in [(0.5, 1.0), (0.9, 0.5)]:
        c1, h1, m1 = ops.page_hist(ids, hot, alpha=alpha, threshold=thr,
                                   impl="interpret")
        c2, h2, m2 = ref.page_hist_ref(ids, hot, alpha=alpha, threshold=thr)
        np.testing.assert_allclose(c1, c2, atol=1e-6)
        np.testing.assert_allclose(h1, h2, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_page_hist_padding_ignored():
    ids = jnp.array([3, 3, -1, -1, 7], jnp.int32)
    hot = jnp.zeros((512,))
    c, h, m = ops.page_hist(ids, hot, impl="interpret")
    assert float(c[3]) == 2.0 and float(c[7]) == 1.0
    assert float(c.sum()) == 3.0


@pytest.mark.parametrize("seed", range(10))
def test_page_hist_property(seed):
    rng = np.random.default_rng(seed)
    num_pages = 512
    n = int(rng.integers(10, 400))
    ids = jnp.asarray(rng.integers(0, num_pages, n), jnp.int32)
    hot = jnp.asarray(rng.random(num_pages), jnp.float32)
    c, h, m = ops.page_hist(ids, hot, impl="interpret")
    assert float(c.sum()) == n                       # counts conserve accesses
    c2, h2, m2 = ref.page_hist_ref(ids, hot)
    np.testing.assert_allclose(c, c2, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,kv,d", [(256, 4, 4, 64), (512, 4, 2, 64),
                                      (256, 8, 1, 128)])
def test_flash_attention_matches_ref(s, h, kv, d, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, s, h, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, kv, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, kv, d), dtype)
    o = ops.flash_attention(q, k, v, bq=128, bkv=128, impl="interpret")
    r = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol)


def test_flash_attention_sliding_window():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 256, 4, 64))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 256, 4, 64))
    o = ops.flash_attention(q, k, v, window=64, bq=64, bkv=64,
                            impl="interpret")
    r = ref.flash_attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_flash_attention_noncausal():
    q = jax.random.normal(jax.random.PRNGKey(6), (1, 128, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(7), (1, 128, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(8), (1, 128, 2, 64))
    o = ops.flash_attention(q, k, v, causal=False, bq=64, bkv=64,
                            impl="interpret")
    r = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,kv,d,page", [(4, 4, 64, 16), (8, 2, 64, 32),
                                         (8, 1, 128, 16)])
def test_paged_attention_matches_ref(h, kv, d, page, dtype):
    b, n_pages, p_phys = 3, 8, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, d), dtype)
    kp = jax.random.normal(jax.random.PRNGKey(1), (p_phys, page, kv, d), dtype)
    vp = jax.random.normal(jax.random.PRNGKey(2), (p_phys, page, kv, d), dtype)
    pt = jax.random.permutation(
        jax.random.PRNGKey(3), p_phys)[: b * n_pages].reshape(b, n_pages)
    lengths = jnp.array([n_pages * page, n_pages * page - 7, page + 3],
                        jnp.int32)
    o = ops.paged_attention(q, kp, vp, pt, lengths, impl="interpret")
    r = ref.paged_attention_ref(q, kp, vp, pt, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol)


@pytest.mark.parametrize("h,kv", [(4, 4), (8, 2), (8, 1)])  # GQA ratios
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (3, 0.0), (0, 5.0),
                                            (8, 5.0)])
def test_paged_attention_kernel_mass_matches_oracle(h, kv, window, softcap):
    """The mass emitted from the kernel's own online-softmax accumulators
    (the fused telemetry output) equals the reference oracle's per-page
    attention-probability mass -- across sliding windows, tanh softcap and
    every GQA ratio, including ragged -1-padded tables."""
    b, n_pages, p_phys, page, d = 3, 5, 24, 4, 16
    key = jax.random.PRNGKey(h * 100 + window)
    q = jax.random.normal(key, (b, h, d))
    kp = jax.random.normal(jax.random.fold_in(key, 1), (p_phys, page, kv, d))
    vp = jax.random.normal(jax.random.fold_in(key, 2), (p_phys, page, kv, d))
    pt = jnp.asarray([[2, 7, 11, 3, 9],
                      [5, 1, 20, -1, -1],          # ragged short row
                      [8, 4, 6, 12, 17]], jnp.int32)
    lengths = jnp.asarray([n_pages * page - 2, 3 * page - 1, 2 * page + 3],
                          jnp.int32)
    out, mass = ops.paged_attention(q, kp, vp, pt, lengths, window=window,
                                    softcap=softcap, return_mass=True,
                                    impl="interpret")
    ref_o, ref_m = ops.paged_attention(q, kp, vp, pt, lengths, window=window,
                                       softcap=softcap, return_mass=True,
                                       impl="reference")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o), atol=1e-5)
    np.testing.assert_allclose(np.asarray(mass), np.asarray(ref_m),
                               atol=1e-5)
    # head-normalised: every in-length row's mass sums to ~1
    np.testing.assert_allclose(np.asarray(mass).sum(axis=1),
                               np.ones(b), atol=1e-5)


@pytest.mark.parametrize("scheduler", ["reactive", "predictive"])
def test_sim_scan_pallas_matches_jax_bitwise(scheduler):
    """The fused ``kernels.sim_step`` sweep (rank-based top-k selection in
    VMEM scratch) is bit-identical to the vmapped lax.scan path."""
    from repro.core import sim, traces

    rng = np.random.default_rng(7)
    tr = traces.Trace("toy", rng.integers(0, 20, 3000).astype(np.int64), 20,
                      np.asarray([50]))
    bins = sim.bin_trace(tr, block=50)
    a = sim.sweep(bins, [100, 250, 600, 1500], scheduler=scheduler)
    b = sim.sweep(bins, [100, 250, 600, 1500], scheduler=scheduler,
                  impl="interpret")
    assert set(a) == set(b)
    for k in a:
        assert a[k].runtime == b[k].runtime
        assert a[k].migrations == b[k].migrations
        assert a[k].fast_hits == b[k].fast_hits


def test_paged_attention_page_permutation_invariance():
    """Physically permuting pages (with the table updated) cannot change the
    output -- the invariant the tiering runtime relies on when it migrates
    pages between tiers."""
    b, h, kv, d, page, n_pages, p_phys = 2, 4, 2, 64, 16, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    kp = jax.random.normal(jax.random.PRNGKey(1), (p_phys, page, kv, d))
    vp = jax.random.normal(jax.random.PRNGKey(2), (p_phys, page, kv, d))
    pt = jnp.arange(b * n_pages, dtype=jnp.int32).reshape(b, n_pages)
    lengths = jnp.full((b,), n_pages * page, jnp.int32)
    o1 = ops.paged_attention(q, kp, vp, pt, lengths, impl="interpret")
    perm = jax.random.permutation(jax.random.PRNGKey(3), p_phys)
    inv = jnp.argsort(perm)
    o2 = ops.paged_attention(q, kp[perm], vp[perm], inv[pt], lengths,
                             impl="interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
