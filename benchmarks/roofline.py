"""Roofline analysis (deliverable g): three terms per (arch x shape) from
the dry-run's compiled artifacts, single-pod mesh.

    compute   = HLO_FLOPs_per_chip   / 197e12   (bf16 peak, TPU v5e)
    memory    = HLO_bytes_per_chip   / 819e9    (HBM bandwidth)
    collective= coll_bytes_per_chip  / 50e9     (ICI per-link)

FLOPs / bytes / collective bytes come from the *cost variant* lowering
(layer and grad-accum loops unrolled -- XLA's cost analysis counts while
bodies once, so the scanned deploy variant undercounts; see dryrun.py).
Cost analysis is per-partition for SPMD executables, hence "per chip".

MODEL_FLOPS = 6*N*D (train; N=active params for MoE) or 2*N*D (fwd-only),
per chip.  The ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch waste.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import save_json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent / "out" / "dryrun"


def _model_flops_per_chip(rec: dict) -> float:
    n = rec["active_params"]
    d = rec["tokens_per_step"]
    mult = 6.0 if rec["step_kind"] == "train" else 2.0
    return mult * n * d / rec["devices"]


def analyze(records: Optional[List[dict]] = None) -> List[dict]:
    if records is None:
        records = []
        for p in sorted(DRYRUN_DIR.glob("*__single.json")):
            records.append(json.loads(p.read_text()))
    rows = []
    for r in records:
        cv = r.get("cost_variant") or {k: r[k] for k in
                                       ("flops", "bytes_accessed",
                                        "collective_bytes_total")}
        flops = cv["flops"]
        byts = cv["bytes_accessed"]
        coll = cv["collective_bytes_total"]
        t_c = flops / PEAK_FLOPS
        t_m = byts / HBM_BW
        t_n = coll / ICI_BW
        bound = max(t_c, t_m, t_n)
        dom = {t_c: "compute", t_m: "memory", t_n: "collective"}[bound]
        mf = _model_flops_per_chip(r)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "step": r["step_kind"],
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "dominant": dom,
            "bound_s": bound,
            "model_flops_per_chip": mf,
            "hlo_flops_per_chip": flops,
            "useful_flop_ratio": mf / max(flops, 1.0),
            "roofline_fraction": (mf / PEAK_FLOPS) / max(bound, 1e-30),
            "hbm_gb_per_chip": (r["argument_bytes"] + r["temp_bytes"]) / 1e9,
            "fits_hbm_16g": (r["argument_bytes"] + r["temp_bytes"]) < 16e9,
        })
    return rows


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | step | compute s | memory s | collective s | "
           "dominant | useful/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = "".join(
        f"| {r['arch']} | {r['shape']} | {r['step']} | {r['compute_s']:.3e} "
        f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} "
        f"| {r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.3f} |\n"
        for r in rows)
    return hdr + body


def run(quick: bool = False):
    rows = analyze()
    if not rows:
        return {"rows": [], "note": "no dry-run records yet"}
    save_json("roofline", {"rows": rows})
    (pathlib.Path(__file__).resolve().parent / "out"
     / "roofline.md").write_text(markdown_table(rows))
    return {"rows": rows}


if __name__ == "__main__":
    rr = run()
    print(markdown_table(rr["rows"]))
