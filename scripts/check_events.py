#!/usr/bin/env python
"""Event-taxonomy checker (CI): instrumentation and docs cannot drift.

Cross-checks three views of the flight-recorder event taxonomy:

  1. the registry -- ``src/repro/obs/events.py`` (loaded standalone, so
     this runs in the dependency-free docs CI job);
  2. the emit sites -- every ``emit("<type>", ...)`` string literal under
     ``src/`` must name a registered type (the Recorder also enforces
     this at runtime; this catches sites tests never execute);
  3. the docs -- every registered type must appear in the taxonomy table
     of ``docs/observability.md``, and every ``type`` the table lists
     must still be registered.

Also verifies each emit site's keyword arguments against the registered
field tuple, and that no event field shadows the ``seq``/``t``/``type``
envelope.

    python scripts/check_events.py [root]
"""
from __future__ import annotations

import ast
import importlib.util
import pathlib
import re
import sys

DOC = "docs/observability.md"
DOC_TYPE = re.compile(r"^\|\s*`([a-z_]+(?:\.[a-z_]+)+)`\s*\|", re.M)


def load_events(root: pathlib.Path):
    """Load the registry without importing the repro package (the docs CI
    job has no numpy/jax installed)."""
    path = root / "src" / "repro" / "obs" / "events.py"
    spec = importlib.util.spec_from_file_location("_obs_events", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.EVENTS, mod.RESERVED_FIELDS


def emit_sites(root: pathlib.Path):
    """Yield (file, lineno, etype, kwarg_names) for every ``X.emit("...")``
    call with a string-literal first argument under src/."""
    for py in sorted((root / "src").rglob("*.py")):
        tree = ast.parse(py.read_text(), filename=str(py))
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "emit"
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                continue
            kwargs = tuple(k.arg for k in node.keywords if k.arg)
            yield (py.relative_to(root), node.lineno,
                   node.args[0].value, kwargs)


def check(root: pathlib.Path) -> int:
    errors = []
    events, reserved = load_events(root)

    for name, ev in events.items():
        for f in ev.fields:
            if f in reserved:
                errors.append(f"registry: {name} field {f!r} shadows the "
                              "envelope")

    # -- emit sites vs registry ---------------------------------------------
    n_sites = 0
    emitted = set()
    for fname, lineno, etype, kwargs in emit_sites(root):
        n_sites += 1
        emitted.add(etype)
        if etype not in events:
            errors.append(f"{fname}:{lineno}: emit of unregistered event "
                          f"type {etype!r}")
            continue
        unknown = set(kwargs) - set(events[etype].fields)
        if unknown:
            errors.append(f"{fname}:{lineno}: {etype} emitted with "
                          f"unregistered field(s) {sorted(unknown)}")
    # metrics.* records are written by the exporters, never emit()ed
    never = [n for n in events
             if n not in emitted and events[n].domain != "metrics"]
    if never:
        errors.append(f"registered but never emitted in src/: {never} "
                      "(drop them or instrument)")

    # -- registry vs docs table ---------------------------------------------
    doc = (root / DOC).read_text()
    documented = set(DOC_TYPE.findall(doc))
    for name in events:
        if name not in documented:
            errors.append(f"{DOC}: registered event {name!r} missing from "
                          "the taxonomy table")
    for name in documented:
        if name not in events:
            errors.append(f"{DOC}: taxonomy table lists {name!r}, which is "
                          "not registered in repro/obs/events.py")

    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"checked {n_sites} emit sites against {len(events)} registered "
          f"event types and {len(documented)} documented: "
          f"{len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    root = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 \
        else pathlib.Path(__file__).resolve().parent.parent
    raise SystemExit(check(root))
