"""Distribution tests on fake devices (subprocess with forced device count).

Covers: sharded train step == single-device numerics, MoE shard_map ==
dense oracle, int8 compressed cross-pod psum, sharding-rule resolution.
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro.configs as C


def _run(code: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           # force the CPU backend: containers with libtpu baked in would
           # otherwise spend minutes per subprocess probing TPU metadata
           "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharding_rules_resolution():
    from jax.sharding import PartitionSpec as P

    out = _run("""
        import jax
        from repro.distributed import sharding as SH
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # qwen-style: 40 heads don't divide 4 -> head_dim fallback
        s = SH.param_spec(("embed", "heads", "head_dim"), (64, 39, 128), mesh)
        assert s == P("data", None, "model"), s
        s = SH.param_spec(("embed", "heads", "head_dim"), (64, 40, 128), mesh)
        assert s == P("data", "model", None), s
        s = SH.param_spec(("vocab", "embed"), (1000, 64), mesh)
        assert s == P("model", "data"), s
        # batch over (pod, data) with joint divisibility
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        # SP: seq shards over model when divisible
        s = SH.act_spec(("batch", "seq", "embed"), (8, 16, 64), mesh3)
        assert s == P(("pod", "data"), "model", None), s
        s = SH.act_spec(("batch", "seq", "embed"), (8, 15, 64), mesh3)
        assert s == P(("pod", "data"), None, None), s
        s = SH.act_spec(("batch",), (2,), mesh3)    # only one axis fits
        assert s == P("pod"), s
        print("RULES-OK")
    """)
    assert "RULES-OK" in out


def test_moe_shard_map_matches_dense_oracle():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.models import moe as M
        cfg = dataclasses.replace(
            C.reduced("olmoe-1b-7b"),
            moe=dataclasses.replace(C.reduced("olmoe-1b-7b").moe,
                                    capacity_factor=8.0))  # no drops
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p, _ = M.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                              jnp.float32)
        y_dense, aux_d = M.moe_apply_dense(p, cfg, x)
        with jax.set_mesh(mesh):
            y_sm, aux_s = M.moe_apply_shard_map(p, cfg, x, mesh)
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_sm),
                                   atol=2e-5)
        np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-5)
        print("MOE-OK")
    """)
    assert "MOE-OK" in out


def test_compressed_psum_numerics():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import (compressed_psum,
                                                   compressed_psum_ef)
        mesh = jax.make_mesh((4,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

        def f(xs):
            return compressed_psum(xs, "pod")

        y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("pod"),
                                  out_specs=P("pod")))(x)
        exact = jnp.mean(x, axis=0)
        got = np.asarray(y[0])
        # int8 quantisation error bound: gmax/127 per element (pre-mean)
        bound = float(jnp.abs(x).max()) / 127 + 1e-6
        assert np.abs(got - np.asarray(exact)).max() <= bound
        # error feedback reduces the residual over repeated reductions
        def g(xs, ef):
            return compressed_psum_ef(xs, ef, "pod")
        ef = jnp.zeros_like(x)
        y2, ef2 = jax.jit(jax.shard_map(g, mesh=mesh,
                                        in_specs=(P("pod"), P("pod")),
                                        out_specs=(P("pod"), P("pod"))))(x, ef)
        assert float(jnp.abs(ef2).max()) <= bound
        print("PSUM-OK")
    """)
    assert "PSUM-OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.distributed import sharding as SH
        from repro.launch.mesh import make_host_mesh
        from repro.train import optim as O, step as S
        from repro.data.pipeline import DataConfig, batch_at
        cfg = C.reduced("qwen3-14b")
        ocfg = O.OptConfig(lr=1e-3)
        dcfg = DataConfig(seed=0, global_batch=4, seq_len=32)
        batch = {k: jnp.asarray(v) for k, v in batch_at(dcfg, cfg, 0).items()}
        # single device
        st1, _ = S.init_state(jax.random.PRNGKey(0), cfg, ocfg)
        st1b, m1 = jax.jit(S.make_train_step(cfg, ocfg))(st1, batch)
        # 2x4 mesh
        mesh = make_host_mesh(data=2, model=4)
        shard = SH.make_shard_fn(mesh)
        st2, _ = S.init_state(jax.random.PRNGKey(0), cfg, ocfg)
        st2b, m2 = jax.jit(S.make_train_step(cfg, ocfg, mesh=mesh,
                                             shard=shard))(st2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-3)
        for a, b in zip(jax.tree.leaves(st1b["params"]),
                        jax.tree.leaves(st2b["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=3e-3)
        print("SHARDED-OK")
    """)
    assert "SHARDED-OK" in out


def test_pod_grad_compression_step_runs():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.distributed import sharding as SH
        from repro.launch.mesh import make_host_mesh
        from repro.train import optim as O, step as S
        from repro.data.pipeline import DataConfig, batch_at
        cfg = C.reduced("stablelm-12b")
        ocfg = O.OptConfig(lr=1e-3)
        mesh = make_host_mesh(data=2, model=2, pod=2)
        shard = SH.make_shard_fn(mesh)
        dcfg = DataConfig(seed=0, global_batch=8, seq_len=32)
        batch = {k: jnp.asarray(v) for k, v in batch_at(dcfg, cfg, 0).items()}
        st, _ = S.init_state(jax.random.PRNGKey(0), cfg, ocfg)
        fn = jax.jit(S.make_train_step(cfg, ocfg, mesh=mesh, shard=shard,
                                       grad_compression=True))
        st2, m = fn(st, batch)
        base = jax.jit(S.make_train_step(cfg, ocfg, mesh=mesh, shard=shard))
        st3, m0 = base(st, batch)
        # compressed-DP loss equals plain loss (loss computed pre-reduce)
        np.testing.assert_allclose(float(m["loss"]), float(m0["loss"]),
                                   rtol=2e-3)
        # params after one compressed step stay close to exact-DP params
        diffs = [float(jnp.abs(a.astype(jnp.float32)
                               - b.astype(jnp.float32)).max())
                 for a, b in zip(jax.tree.leaves(st2["params"]),
                                 jax.tree.leaves(st3["params"]))]
        assert max(diffs) < 5e-3, max(diffs)
        print("PODCOMP-OK")
    """, devices=8)
    assert "PODCOMP-OK" in out
