"""Pipelined macro serving loop: overlap must never change the tokens.

Covers the pipelined-loop tentpole: the DecisionWorker hand-off protocol
(ordered generations, exception propagation, close semantics, a
stress-hammered fake dispatch thread), pipelined-vs-synchronous token
parity including chunked long-prompt admission under staggered arrival
(the async-decision determinism contract: overlap changes *when* work
happens, never *what* is computed), the epoch-keyed page-table upload
cache, and the batched-transfer miss pricing in TrafficMonitor.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import OnlineTuner
from repro.memtier import SharedPagedPools, TierConfig, TieringManager
from repro.serve.pipeline import DecisionWorker
from repro.serve.sched import TrafficMonitor


# ---------------------------------------------------------------------------
# DecisionWorker: the hand-off protocol, without a model
# ---------------------------------------------------------------------------


def test_decision_worker_orders_generations():
    with DecisionWorker(lambda p: p * 2) as w:
        gens = [w.submit(i) for i in range(8)]
        assert gens == list(range(8)), "generations number submissions"
        # out-of-order waits resolve: results are keyed, not streamed
        for g in reversed(gens):
            result, waited = w.wait(g)
            assert result == g * 2
            assert waited >= 0.0


def test_decision_worker_propagates_exceptions():
    def fn(p):
        if p == "boom":
            raise ValueError("boom payload")
        return p

    with DecisionWorker(fn) as w:
        ok = w.submit("fine")
        bad = w.submit("boom")
        assert w.wait(ok)[0] == "fine"
        with pytest.raises(ValueError, match="boom payload"):
            w.wait(bad)
        # the worker survives a failed generation
        again = w.submit("fine")
        assert w.wait(again)[0] == "fine"


def test_decision_worker_close_and_timeout():
    w = DecisionWorker(lambda p: p)
    g = w.submit(1)
    assert w.wait(g)[0] == 1
    with pytest.raises(TimeoutError):
        w.wait(g + 1, timeout=0.01)   # never submitted
    w.close()
    with pytest.raises(RuntimeError):
        w.submit(2)
    w.close()                          # idempotent


def test_decision_worker_handoff_stress():
    """Hammer the submit/wait hand-off from a fake dispatch thread: many
    generations, strict alternation exactly as the pipelined loop drives
    it (submit -> overlap work -> wait), plus a burst phase with several
    generations in flight.  Every result must match its payload."""
    def fn(p):
        # vary service time so the dispatch thread races ahead and
        # behind the worker in turn
        time.sleep((p % 3) * 1e-4)
        return ("done", p)

    failures = []

    def dispatch(n):
        try:
            with DecisionWorker(fn) as w:
                # phase 1: strict alternation (the pipelined loop's shape)
                for i in range(n):
                    g = w.submit(i)
                    result, _ = w.wait(g, timeout=10.0)
                    assert result == ("done", i), result
                # phase 2: a burst of in-flight generations
                gens = [w.submit(100 + i) for i in range(16)]
                for i, g in enumerate(gens):
                    result, _ = w.wait(g, timeout=10.0)
                    assert result == ("done", 100 + i), result
        except BaseException as e:      # surface into the test thread
            failures.append(e)

    threads = [threading.Thread(target=dispatch, args=(50,))
               for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    assert not failures, failures


# ---------------------------------------------------------------------------
# TrafficMonitor: batched-transfer miss pricing
# ---------------------------------------------------------------------------


def _mini_monitor():
    pools = SharedPagedPools.create(16, 8)
    mgr = TieringManager(16, TierConfig(page_size=16, hbm_pages=8,
                                        period_steps=4))
    return TrafficMonitor(pools, mgr)


def test_on_step_charges_fetches_at_fetch_cost():
    """Demand fetches are priced at ``fetch_cost`` (the pools batch every
    ensure_resident call into one gathered transfer), NOT at the
    synchronous mid-decode ``miss_penalty``."""
    mass = np.zeros(16, np.float32)
    base, fetched = _mini_monitor(), _mini_monitor()
    base.on_step(mass, n_active=1)
    fetched.on_step(mass, n_active=1, fetched=5)
    mgr = fetched.manager
    assert mgr.misses - base.manager.misses == 5
    extra = mgr.modeled_time - base.manager.modeled_time
    assert extra == pytest.approx(5 * mgr.cfg.fetch_cost)
    assert mgr.cfg.fetch_cost < mgr.cfg.miss_penalty


def test_plan_step_accounts_like_on_macro_step():
    """The worker half (plan, no pool mutation) and the synchronous
    boundary must charge identically from the same snapshot -- cost is
    charged at plan time so sync and async account the same."""
    rng = np.random.default_rng(0)
    sync_m, pipe_m = _mini_monitor(), _mini_monitor()
    for s in range(6):
        mass = rng.random(16).astype(np.float32)
        sync_m.on_macro_step(mass, n_active=2.0, n_tokens=4, fetched=3)
        pools = pipe_m.pools
        period, plan = pipe_m.plan_step(
            mass, n_active=2.0, n_tokens=4, fetched=3,
            resident=pools.slot_of >= 0,
            n_free=int((pools.page_of_slot < 0).sum()),
            active=pools.allocated_mask, planes=2)
        pipe_m.apply_decision(plan)
        assert period == sync_m.manager.period
    assert pipe_m.manager.modeled_time == sync_m.manager.modeled_time
    assert pipe_m.manager.misses == sync_m.manager.misses
    np.testing.assert_array_equal(pipe_m.pools.slot_of,
                                  sync_m.pools.slot_of)


# ---------------------------------------------------------------------------
# pipelined ContinuousBatcher: token parity with the synchronous loop
# ---------------------------------------------------------------------------


def _serving_stack(cfg, *, n_logical=48, hbm=16, page=4):
    pools = SharedPagedPools.create(n_logical, hbm, page_size=page,
                                    kv_heads=cfg.num_kv_heads,
                                    head_dim=cfg.head_dim)
    mgr = TieringManager(n_logical, TierConfig(page_size=page,
                                               hbm_pages=hbm,
                                               period_steps=2))
    tuner = OnlineTuner(n_logical, default_period=2, profile_steps=8,
                        trial_steps=4)
    return TrafficMonitor(pools, mgr, tuner)


def _drive(params, cfg, reqs, *, pipeline, admit_chunk_tokens=None):
    """Run one batcher over the staggered request set; returns
    (rid -> tokens, monitor)."""
    from repro.serve.sched import ContinuousBatcher, Request

    mon = _serving_stack(cfg)
    b = ContinuousBatcher(params, cfg, max_active=2, max_len=32,
                          page_size=4, monitor=mon, pipeline=pipeline,
                          admit_chunk_tokens=admit_chunk_tokens)
    try:
        for at, req in reqs:
            if at == 0:
                b.submit(Request(**req))
        for t in range(1, 80):
            for at, req in reqs:
                if at == t:         # staggered admission mid-flight
                    b.submit(Request(**req))
            b.step()
            if b.idle:
                break
        assert b.idle, "must drain"
        got = {r.rid: list(r.tokens) for r in b.completed}
        assert mon.pools.free_pages == mon.pools.n_logical, \
            "every page must come back to the pool"
    finally:
        b.close()
    return got, mon


def test_pipelined_token_parity_with_synchronous():
    """The tentpole bar: the pipelined loop (async decisions, lazy
    same-boundary admission, overlap prefetch) emits rid-for-rid
    token-identical
    streams to the synchronous macro loop AND to per-request generate,
    under staggered admission, row reuse and mixed temperatures; chunked
    long-prompt admission preserves the same streams."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import model as mdl
    from repro.serve.engine import generate

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    plens = (6, 9, 5, 14)          # 14 > chunk width: chunked admission
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in plens]
    steps = [6, 4, 7, 5]
    temps = [0.0, 0.7, 0.7, 0.0]
    reqs = [(0 if i < 2 else 2,
             dict(rid=i, prompt=prompts[i], max_new_tokens=steps[i],
                  key=jax.random.PRNGKey(10 + i), temperature=temps[i]))
            for i in range(4)]

    sync, _ = _drive(params, cfg, reqs, pipeline=False)
    pipe, _ = _drive(params, cfg, reqs, pipeline=True)
    chunk, _ = _drive(params, cfg, reqs, pipeline=True,
                      admit_chunk_tokens=4)
    assert pipe == sync, "pipelined loop must be token-identical"
    assert chunk == sync, "chunked admission must be token-identical"
    for i in range(4):             # dense reference: generate per request
        ref = np.asarray(generate(params, cfg,
                                  jnp.asarray(prompts[i])[None],
                                  steps=steps[i], temperature=temps[i],
                                  key=jax.random.PRNGKey(10 + i))
                         )[0].tolist()
        assert pipe[i] == ref, f"request {i} diverged from generate"


def test_pipelined_table_upload_cache():
    """The epoch-keyed table cache: boundaries where tiering moved no
    page and no row changed skip the rebuild+upload (counted), and the
    pipelined run emits its closed stage/decision event taxonomy."""
    import jax
    import repro.configs as C
    from repro.models import model as mdl
    from repro.obs import telemetry as _obs
    from repro.serve.sched import ContinuousBatcher, Request

    cfg = C.reduced("gemma3-12b")
    params, _ = mdl.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    rec = _obs.install(_obs.Recorder(enabled=True))
    try:
        mon = _serving_stack(cfg)
        b = ContinuousBatcher(params, cfg, max_active=2, max_len=32,
                              page_size=4, monitor=mon, pipeline=True,
                              admit_chunk_tokens=4)
        for i, n in enumerate((6, 14)):
            b.submit(Request(
                rid=i, max_new_tokens=6,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=n).astype(np.int32)))
        b.run(max_steps=60)
        b.close()
        counters = rec.summary()["counters"]
        assert counters.get("pool.table_upload.performed", 0) >= 1
        assert counters.get("pool.table_upload.skipped", 0) >= 1, \
            "quiet boundaries must reuse the staged upload"
        types = {e["type"] for e in rec.events()}
        assert {"serve.pipeline.stage", "serve.pipeline.decision",
                "serve.pipeline.admit_chunk"} <= types
        stages = {e["stage"] for e in rec.events("serve.pipeline.stage")}
        assert stages == {"decision_wait", "prefetch", "tables", "admit"}
    finally:
        _obs.install(_obs.Recorder())
