"""xLSTM-1.3B [arXiv:2405.04517]: 7:1 mLSTM:sLSTM blocks, no FFN sublayer.

Attention-free: the KV-tiering technique is inapplicable (DESIGN.md
SArch-applicability); long_500k runs (recurrent state is O(1) per step).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        d_model=2048, num_heads=4, num_kv_heads=4, head_dim=512,
        d_ff=0, vocab_size=50304,
        segments=(((("mlstm",) * 7 + ("slstm",)), 6),),
        tie_embeddings=True, max_seq_len=1_048_576,
        supports_long_context=True)
