"""Training driver: data pipeline + train step + checkpoint/restart + FT.

Runs any ``--arch`` (reduced or full config) on the local device mesh.
This is the process the ``repro.ft.supervisor`` relaunches on failure:
at startup it restores the newest checkpoint and resumes the *exact*
deterministic data stream from the restored step.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1
  REPRO_FAIL_AT_STEP=20 PYTHONPATH=src python -m repro.launch.train ...
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, batch_at
from repro.distributed import sharding as SH
from repro.ft.monitor import FailureInjector, Heartbeat, StepTimer
from repro.launch.mesh import make_host_mesh
from repro.models import model as mdl
from repro.train import optim, step as tstep


def build(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    return ap.parse_args(argv)


def main(argv=None):
    args = build(argv)
    cfg = C.reduced(args.arch) if args.reduced else C.get(args.arch)
    ocfg = optim.OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                           decay_steps=args.steps)
    dcfg = DataConfig(seed=args.seed, global_batch=args.batch,
                      seq_len=args.seq)

    mesh = None
    shard = lambda x, n: x
    if args.data_mesh * args.model_mesh > 1:
        mesh = make_host_mesh(data=args.data_mesh, model=args.model_mesh)
        shard = SH.make_shard_fn(mesh)

    state, specs = tstep.init_state(jax.random.PRNGKey(args.seed), cfg, ocfg)
    step_fn = jax.jit(tstep.make_train_step(cfg, ocfg, mesh=mesh, shard=shard,
                                            accum_steps=args.accum))

    start = 0
    workdir = pathlib.Path(args.ckpt_dir) if args.ckpt_dir else None
    if workdir:
        last = ckpt.latest_step(workdir)
        if last is not None:
            state = ckpt.restore(workdir, last, state)
            start = last
            print(f"[train] restored step {start} from {workdir}")
    saver = ckpt.AsyncCheckpointer(workdir) if workdir else None
    injector = FailureInjector(workdir or ".")
    timer = StepTimer()
    hb = Heartbeat((workdir or pathlib.Path(".")) / "heartbeat")

    losses = []
    with hb:
        for i in range(start, args.steps):
            injector.check(i)
            batch = {k: jnp.asarray(v)
                     for k, v in batch_at(dcfg, cfg, i).items()}
            timer.start()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            timer.stop(i)
            losses.append(loss)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"[train] step {i} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if saver and (i + 1) % args.ckpt_every == 0:
                saver.save(i + 1, state)
    if saver:
        saver.save(args.steps, state)
        saver.wait()
    report = {"final_loss": losses[-1], "first_loss": losses[0],
              "steps_run": len(losses), "start": start,
              "stragglers": timer.stragglers}
    print("[train] done:", json.dumps(report))
    if args.metrics_out:
        pathlib.Path(args.metrics_out).write_text(json.dumps(
            {**report, "losses": losses}))
    return report


if __name__ == "__main__":
    main()
