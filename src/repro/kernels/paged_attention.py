"""Pallas TPU kernel: decode attention over a paged KV working set.

The TPU-native consumer of the Cori-tuned tiering runtime: KV lives in
fixed-size pages; a per-sequence page table indirects into the physical
page pool (the HBM working set managed by ``repro.memtier``).  The page
table is a *scalar-prefetch* operand -- its values drive the BlockSpec
index_map, so each grid step DMAs exactly the physical page it needs
(hardware page-gather; no materialised gather HLO).

Grid: (batch, pages_per_seq); online softmax carries (m, l, acc) in VMEM
scratch across the page axis, exactly like flash attention but with the kv
tile = one page and block indices taken from the page table.

Since the fully-paged decode refactor, *every* attention layer of the
serving engine reads its KV through this kernel, so it supports the whole
layer mix, not just the monitor layer:

  * ``window > 0`` -- sliding-window (local) layers: only positions in
    ``[length - window, length)`` are attended.  Callers still pass the
    full page table; out-of-window pages are masked, not skipped, so one
    table layout serves every layer of a multi-layer pool.
  * ``softcap > 0`` -- tanh logit capping (Gemma-style), applied before
    masking exactly as in the dense layers.

Multi-request tables are ragged: rows shorter than ``pages_per_seq`` are
padded with ``-1`` (bucket-rounded allocations leave tail pages unused).
The jitted wrapper (``repro.kernels.ops.paged_attention``) clamps those to
0 -- they are masked by ``lengths`` -- so the index_map never DMAs out of
bounds.

Besides the context the kernel emits the **per-page attention mass** as a
second output: f32[B, pages_per_seq], head-normalised (each in-length row
sums to ~1).  This is the "accessed bits" signal the Cori-tuned tiering
runtime consumes -- emitting it from the online-softmax accumulators makes
telemetry free (one extra [H, pages] VMEM scratch, no second pass over the
KV pages).  Per page the kernel keeps the running exp-sum under the SAME
max/correction cascade as the context accumulator, so at the flush step
``mass[pi] = sum_h p_scr[h, pi] / l[h] / H`` equals the softmax
probability mass the reference oracle assigns to page ``pi``.

q: [B, H, D]; k_pages/v_pages: [P_phys, page, KV, D];
page_table: int32[B, pages_per_seq]; lengths: int32[B].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table, lengths, q_ref, k_ref, v_ref, o_ref, mass_ref,
            m_scr, l_scr, acc_scr, p_scr, *, page: int, n_pages: int,
            scale: float, window: int, softcap: float):
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        p_scr[...] = jnp.zeros_like(p_scr)

    q = q_ref[0]                                   # [H, D]
    k = k_ref[0]                                   # [page, KV, D]
    v = v_ref[0]
    h, d = q.shape
    kvh = k.shape[1]
    rep = h // kvh
    length = lengths[b]

    # token positions covered by this logical page
    pos = pi * page + jax.lax.iota(jnp.int32, page)
    valid = pos < length                           # [page]
    if window > 0:
        # sliding-window layer: the decoding token sits at length - 1, so
        # the attended span is [length - window, length)
        valid &= pos >= length - window

    qg = q.reshape(kvh, rep, d)
    logits = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale   # [kvh, rep, page]
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(valid[None, None, :], logits, NEG_INF)

    m_prev = m_scr[...]                            # [kvh, rep, 1]... flat [h,1]
    lg = logits.reshape(h, page)
    m_cur = jnp.max(lg, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(lg - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    pg = p.reshape(kvh, rep, page)
    ctx = jax.lax.dot_general(
        pg.astype(v.dtype), v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)        # [kvh, rep, d]
    acc_scr[...] = acc_scr[...] * corr + ctx.reshape(h, d)
    # per-page exp-sum under the same correction cascade as the context
    # accumulator: column pi gets this page's sum, prior columns re-scale
    page_col = (jax.lax.iota(jnp.int32, n_pages) == pi).astype(jnp.float32)
    p_scr[...] = p_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True) \
        * page_col[None, :]
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(pi == n_pages - 1)
    def _flush():
        l_safe = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        mass_ref[0] = jnp.sum(p_scr[...] / l_safe, axis=0) / h


def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    window: int = 0, softcap: float = 0.0,
                    interpret: bool = False):
    """Decode attention over paged KV.

    Returns (out [B, H, D], mass f32[B, pages_per_seq]) -- the per-page
    head-normalised attention mass is emitted from the kernel's own
    softmax accumulators (no second pass over the pages)."""
    b, h, d = q.shape
    p_phys, page, kvh, _ = k_pages.shape
    n_pages = page_table.shape[1]
    assert h % kvh == 0
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(_kernel, page=page, n_pages=n_pages,
                               scale=scale, window=window, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_pages),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bi, pi, pt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, page, kvh, d),
                         lambda bi, pi, pt, ln: (pt[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, page, kvh, d),
                         lambda bi, pi, pt, ln: (pt[bi, pi], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d), lambda bi, pi, pt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, n_pages), lambda bi, pi, pt, ln: (bi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
            pltpu.VMEM((h, n_pages), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, h, d), q.dtype),
                   jax.ShapeDtypeStruct((b, n_pages), jnp.float32)],
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)


def _mla_kernel(page_table, lengths, qa_ref, qr_ref, ckv_ref, kr_ref,
                o_ref, mass_ref, m_scr, l_scr, acc_scr, p_scr, *,
                page: int, n_pages: int, scale: float):
    """Absorbed-matrix MLA decode over compressed pages.

    Same online-softmax + fused per-page mass cascade as ``_kernel``, but
    the page holds one *compressed* row per token -- ckv [page, R] shared
    across every head (not roped) plus krope [page, K] roped positional
    keys -- so the logits are the sum of two head x page dots and the
    "values" are the ckv rows themselves (the caller up-projects with
    W_uv outside the kernel).
    """
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        p_scr[...] = jnp.zeros_like(p_scr)

    qa = qa_ref[0]                                 # [H, R]
    qr = qr_ref[0]                                 # [H, K]
    ckv = ckv_ref[0]                               # [page, R]
    kr = kr_ref[0]                                 # [page, K]
    h = qa.shape[0]
    length = lengths[b]

    pos = pi * page + jax.lax.iota(jnp.int32, page)
    valid = pos < length                           # [page]

    logits = (jax.lax.dot_general(
        qa, ckv, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
        + jax.lax.dot_general(
        qr, kr, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)) * scale   # [H, page]
    logits = jnp.where(valid[None, :], logits, NEG_INF)

    m_prev = m_scr[...]                            # [H, 1]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    ctx = jax.lax.dot_general(
        p.astype(ckv.dtype), ckv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # [H, R]
    acc_scr[...] = acc_scr[...] * corr + ctx
    page_col = (jax.lax.iota(jnp.int32, n_pages) == pi).astype(jnp.float32)
    p_scr[...] = p_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True) \
        * page_col[None, :]
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(pi == n_pages - 1)
    def _flush():
        l_safe = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        mass_ref[0] = jnp.sum(p_scr[...] / l_safe, axis=0) / h


def paged_attention_mla(q_abs, q_rope, ckv_pages, krope_pages, page_table,
                        lengths, *, scale: float, interpret: bool = False):
    """MLA decode over compressed paged rows.

    q_abs: [B, H, R]; q_rope: [B, H, K]; ckv_pages: [P_phys, page, R];
    krope_pages: [P_phys, page, K].  ``scale`` is 1/sqrt(qk_nope + qk_rope)
    (the uncompressed head dim, not derivable from compressed shapes).
    Returns (ctx [B, H, R] in the compressed space, mass f32[B, n_pages])."""
    b, h, rdim = q_abs.shape
    kdim = q_rope.shape[2]
    _, page, _ = ckv_pages.shape
    n_pages = page_table.shape[1]

    kernel = functools.partial(_mla_kernel, page=page, n_pages=n_pages,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_pages),
        in_specs=[
            pl.BlockSpec((1, h, rdim), lambda bi, pi, pt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, h, kdim), lambda bi, pi, pt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, page, rdim),
                         lambda bi, pi, pt, ln: (pt[bi, pi], 0, 0)),
            pl.BlockSpec((1, page, kdim),
                         lambda bi, pi, pt, ln: (pt[bi, pi], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, rdim), lambda bi, pi, pt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, n_pages), lambda bi, pi, pt, ln: (bi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, rdim), jnp.float32),
            pltpu.VMEM((h, n_pages), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, h, rdim), q_abs.dtype),
                   jax.ShapeDtypeStruct((b, n_pages), jnp.float32)],
        interpret=interpret,
    )(page_table, lengths, q_abs, q_rope, ckv_pages, krope_pages)
