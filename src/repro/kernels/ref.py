"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def page_hist_ref(ids, hotness, *, alpha: float = 0.5, threshold: float = 1.0):
    """ids: int32[P] (pad -1); hotness: f32[num_pages]."""
    num_pages = hotness.shape[0]
    counts = jnp.zeros((num_pages,), jnp.float32).at[
        jnp.clip(ids, 0, num_pages - 1)].add(
        jnp.where(ids >= 0, 1.0, 0.0))
    new_hot = alpha * counts + (1 - alpha) * hotness
    return counts, new_hot, new_hot >= threshold


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B,S,H,D]; k/v: [B,T,KV,D]."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    kr = jnp.repeat(k, h // kv, axis=2)
    vr = jnp.repeat(v, h // kv, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                        preferred_element_type=jnp.float32) / np.sqrt(d)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), vr)


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                        window: int = 0, softcap: float = 0.0,
                        return_mass: bool = False):
    """q: [B,H,D]; pages: [P,page,KV,D]; page_table: [B,n]; lengths: [B].

    ``window > 0`` restricts attention to positions [length-window, length)
    (sliding-window layers); ``softcap > 0`` applies tanh logit capping.
    With ``return_mass`` also returns the per-page attention-probability
    mass f32[B, n], *head-normalised* (each row sums to ~1): the "accessed
    bits" signal the fully-paged serving monitor aggregates across layers.

    The serving loop no longer calls this to compute the mass -- the
    Pallas kernel emits it from its own online-softmax accumulators
    (fused telemetry).  This oracle is the allclose target that pins the
    kernel's fused output (tests/test_kernels.py, parametrized over
    window / softcap / GQA).
    """
    b, h, d = q.shape
    _, page, kvh, _ = k_pages.shape
    n = page_table.shape[1]
    k = k_pages[page_table]                     # [B, n, page, KV, D]
    v = v_pages[page_table]
    k = k.reshape(b, n * page, kvh, d)
    v = v.reshape(b, n * page, kvh, d)
    kr = jnp.repeat(k, h // kvh, axis=2)
    vr = jnp.repeat(v, h // kvh, axis=2)
    logits = jnp.einsum("bhd,bthd->bht", q, kr,
                        preferred_element_type=jnp.float32) / np.sqrt(d)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    pos = jnp.arange(n * page)[None, :]
    valid = pos < lengths[:, None]
    if window > 0:
        valid &= pos >= (lengths[:, None] - window)
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bht,bthd->bhd", w.astype(vr.dtype), vr)
    if not return_mass:
        return out
    mass = w.sum(axis=1).reshape(b, n, page).sum(axis=-1) / h   # [B, n]
    return out, mass


def paged_attention_mla_ref(q_abs, q_rope, ckv_pages, krope_pages,
                            page_table, lengths, *, scale: float,
                            return_mass: bool = False):
    """MLA compressed-row paged decode (absorbed-matrix form).

    q_abs: [B,H,R] -- W_uk-absorbed no-pe queries in the kv_lora space;
    q_rope: [B,H,K] -- roped positional queries; ckv_pages: [P,page,R]
    compressed KV rows (shared across heads, *not* roped); krope_pages:
    [P,page,K] roped positional keys; page_table: [B,n]; lengths: [B].
    ``scale`` is 1/sqrt(qk_nope_dim + qk_rope_dim) -- the *uncompressed*
    head dim, which is not derivable from the compressed shapes.

    Returns the context in the compressed space, [B,H,R] (the caller
    up-projects with W_uv), plus the head-normalised per-page mass
    f32[B,n] when ``return_mass`` -- the same "accessed bits" signal as
    ``paged_attention_ref``.
    """
    b, h, rdim = q_abs.shape
    _, page, _ = ckv_pages.shape
    n = page_table.shape[1]
    ckv = ckv_pages[page_table].reshape(b, n * page, rdim)
    krope = krope_pages[page_table].reshape(b, n * page, -1)
    logits = (jnp.einsum("bhr,btr->bht", q_abs, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhk,btk->bht", q_rope, krope,
                           preferred_element_type=jnp.float32)) * scale
    pos = jnp.arange(n * page)[None, :]
    valid = pos < lengths[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bht,btr->bhr", w.astype(ckv.dtype), ckv)
    if not return_mass:
        return out
    mass = w.sum(axis=1).reshape(b, n, page).sum(axis=-1) / h   # [B, n]
    return out, mass
