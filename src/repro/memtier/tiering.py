"""Cori-tuned HBM <-> host KV-page tiering (the paper's technique, adapted).

Mapping (DESIGN.md S3):
    DRAM            -> HBM working set      (hbm_pages physical slots)
    PMEM            -> host backing store   (all logical pages)
    page scheduler  -> ``TieringManager.maybe_tier`` every ``period`` steps
    accessed bits   -> per-page attention mass from the decode step
    move_pages()    -> ``migrate`` (gather/scatter on the physical pools)
    Cori            -> ``repro.core.cori`` tuning ``period`` from the
                       attention-reuse histogram (step domain)

The page-selection rule is the paper's verbatim: EMA hotness ranks pages,
top-capacity hot pages swap in against LRU residents, swaps capped by
capacity.  Costs are modeled with the same structure as ``core.sim`` but
with TPU-tier constants (HBM vs PCIe-host), since this container has no
real TPU clock: a decode step pays 1 unit per resident-page touch,
``miss_penalty`` per non-resident touch (on-demand host fetch), plus
migration and wakeup costs per tiering period.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cori, reuse
from repro.kernels import ops

__all__ = ["TierConfig", "TieringManager", "PagedPools"]


@dataclasses.dataclass(frozen=True)
class TierConfig:
    page_size: int = 16            # tokens per KV page
    hbm_pages: int = 0             # working-set capacity (physical slots)
    period_steps: int = 8          # tiering period (what Cori tunes)
    ema_alpha: float = 0.5
    access_threshold: float = 0.05  # attention mass to count as "accessed"
    # modeled costs (units: one HBM page-read)
    miss_penalty: float = 32.0     # on-demand host fetch (PCIe ~25GB/s vs HBM)
    mig_cost: float = 16.0         # async page migration
    wakeup_cost: float = 4.0       # scheduler wakeup per period


@dataclasses.dataclass
class PagedPools:
    """Physical KV page pools for one representative layer group.

    host pools hold every logical page; the HBM pool holds the resident
    working set.  ``slot_of[logical] == -1`` means host-only."""
    k_host: jnp.ndarray            # [n_logical, page, kv, d]
    v_host: jnp.ndarray
    k_hbm: jnp.ndarray             # [hbm_pages, page, kv, d]
    v_hbm: jnp.ndarray
    slot_of: np.ndarray            # int32[n_logical] -> hbm slot | -1
    page_of_slot: np.ndarray       # int32[hbm_pages] -> logical | -1

    @classmethod
    def create(cls, k_pages, v_pages, hbm_pages: int):
        """Interleaved initial residency (paper SII-B initial placement)."""
        from repro.core.sim import interleaved_indices
        n = k_pages.shape[0]
        init = interleaved_indices(n, hbm_pages).astype(np.int32)
        slot_of = np.full((n,), -1, np.int32)
        slot_of[init] = np.arange(hbm_pages)
        return cls(
            k_host=k_pages, v_host=v_pages,
            k_hbm=k_pages[init], v_hbm=v_pages[init],
            slot_of=slot_of,
            page_of_slot=init.copy())


@jax.jit
def _migrate(pool_hbm, pool_host, slots, logicals):
    """Copy host pages `logicals` into HBM `slots` (the move_pages analogue;
    on real hardware this is the pinned_host->device DMA)."""
    return pool_hbm.at[slots].set(pool_host[logicals])


class TieringManager:
    """Periodic page scheduler over a PagedPools working set."""

    def __init__(self, n_logical: int, cfg: TierConfig,
                 access_log_len: int = 65536):
        self.cfg = cfg
        self.n = n_logical
        self.hotness = np.zeros(n_logical, np.float64)
        self.last_access = np.full(n_logical, -1.0)
        self.step = 0
        # accessed page ids per step, bounded: the manager lives inside the
        # serving loop, and the online path reads reuse from the tuner's
        # StreamingReuseCollector, not from this log (which feeds the
        # offline `reuse_histogram`/`cori_candidates` flow)
        self.access_log: "collections.deque[np.ndarray]" = collections.deque(
            maxlen=access_log_len)
        self.counts_since_tier = np.zeros(n_logical, np.float64)
        # live tiering period (what online Cori drives); counted against the
        # steps elapsed since the last tier so period changes apply cleanly
        # mid-run
        self.period = max(1, int(cfg.period_steps))
        self._since_tier = 0
        # accounting
        self.migrations = 0
        self.modeled_time = 0.0
        self.data_moved_pages = 0
        self.hits = 0
        self.misses = 0

    def set_period(self, period_steps: int) -> None:
        """Change the tiering period live (the online-Cori control knob)."""
        self.period = max(1, int(period_steps))

    def _tier_due(self) -> bool:
        if self._since_tier < self.period:
            return False
        self._since_tier = 0
        return True

    # -- monitor -----------------------------------------------------------
    def on_step(self, page_mass: np.ndarray, resident: np.ndarray):
        """page_mass: f32[n_logical] attention mass this decode step;
        resident: bool[n_logical]."""
        accessed = page_mass >= self.cfg.access_threshold
        ids = np.nonzero(accessed)[0].astype(np.int32)
        self.access_log.append(ids)
        self.counts_since_tier[accessed] += 1.0
        self.last_access[accessed] = self.step
        hits = accessed & resident
        misses = accessed & ~resident
        self.hits += int(hits.sum())
        self.misses += int(misses.sum())
        self.modeled_time += hits.sum() * 1.0 + misses.sum() * self.cfg.miss_penalty
        self.step += 1
        self._since_tier += 1

    # -- the page scheduler (paper SII-B swap rule) --------------------------
    def _rank_desired(self, resident: np.ndarray) -> np.ndarray:
        """EMA-update hotness and rank the desired working set (the paper's
        swap rule): hotness primary, recency secondary, residency tertiary."""
        a = self.cfg.ema_alpha
        self.hotness = a * self.counts_since_tier + (1 - a) * self.hotness
        self.counts_since_tier[:] = 0.0
        score = (self.hotness * 1e6
                 + (self.last_access + 1) / (self.step + 1)
                 + 0.5 * resident)
        desired = np.argsort(-score, kind="stable")[: self.cfg.hbm_pages]
        desired_set = np.zeros(self.n, bool)
        desired_set[desired] = True
        return desired_set

    def maybe_tier(self, pools: PagedPools) -> PagedPools:
        if self.step == 0 or not self._tier_due():
            return pools
        cfg = self.cfg
        resident = pools.slot_of >= 0
        desired_set = self._rank_desired(resident)
        evict = np.nonzero(resident & ~desired_set)[0]
        bring = np.nonzero(desired_set & ~resident)[0]
        n_mig = min(len(evict), len(bring))
        evict, bring = evict[:n_mig], bring[:n_mig]
        if n_mig:
            slots = pools.slot_of[evict].copy()
            pools.slot_of[evict] = -1
            pools.slot_of[bring] = slots
            pools.page_of_slot[slots] = bring
            pools = dataclasses.replace(
                pools,
                k_hbm=_migrate(pools.k_hbm, pools.k_host, jnp.asarray(slots),
                               jnp.asarray(bring)),
                v_hbm=_migrate(pools.v_hbm, pools.v_host, jnp.asarray(slots),
                               jnp.asarray(bring)))
        self.migrations += int(n_mig)
        self.data_moved_pages += 2 * int(n_mig)
        self.modeled_time += n_mig * cfg.mig_cost + cfg.wakeup_cost
        return pools

    def maybe_tier_symbolic(self, resident: np.ndarray) -> bool:
        """Tiering over symbolic residency (no physical pools): same swap
        rule and accounting as ``maybe_tier``, used for fast period trials.
        Mutates ``resident`` in place; returns whether a tier happened."""
        if self.step == 0 or not self._tier_due():
            return False
        desired_set = self._rank_desired(resident)
        n_mig = int((desired_set & ~resident).sum())
        self.migrations += n_mig
        self.data_moved_pages += 2 * n_mig
        self.modeled_time += n_mig * self.cfg.mig_cost + self.cfg.wakeup_cost
        resident[:] = desired_set
        return True

    # -- Cori integration ----------------------------------------------------
    def reuse_histogram(self, bin_width: int = 4) -> reuse.ReuseHistogram:
        """Reuse distances in the decode-step domain from the access log."""
        last = np.full(self.n, -1)
        gaps: List[int] = []
        for t, ids in enumerate(self.access_log):
            prev = last[ids]
            gaps.extend((t - prev[prev >= 0]).tolist())
            last[ids] = t
        h = reuse.loop_duration_histogram(np.asarray(gaps, np.int64),
                                          bin_width=bin_width)
        return reuse.prune_insignificant(h)

    def cori_candidates(self, horizon_steps: int) -> np.ndarray:
        hist = self.reuse_histogram()
        dr = cori.dominant_reuse(hist)
        return cori.candidate_periods(dr, float(horizon_steps),
                                      min_period=1.0)

