"""Hybrid-memory simulator: JAX scan vs pure-python oracle + invariants."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (SimConfig, Trace, bin_trace, generate, simulate,
                        simulate_reference)


def _small_trace(seed=0):
    return generate("backprop", seed=seed, num_pages=256, sweeps=6,
                    accesses_per_page=3)


@pytest.mark.parametrize("scheduler", ["reactive", "predictive"])
@pytest.mark.parametrize("period", [100, 700, 2300])
def test_scan_matches_reference(scheduler, period):
    bins = bin_trace(_small_trace())
    a = simulate(bins, period, scheduler)
    b = simulate_reference(bins, period, scheduler)
    assert a.migrations == b.migrations
    assert a.fast_hits == b.fast_hits
    np.testing.assert_allclose(a.runtime, b.runtime, rtol=1e-5)


def test_runtime_lower_bound():
    """Runtime can never beat every access hitting fast memory."""
    bins = bin_trace(_small_trace())
    for p in [100, 1000, 3000]:
        r = simulate(bins, p, "predictive")
        assert r.runtime >= r.num_accesses * SimConfig().lat_fast


def test_predictive_beats_reactive_on_strides():
    """Oracle knowledge of the next period can only help on a strided
    pattern (paper SIII-C: reactive breaks the reuse)."""
    bins = bin_trace(_small_trace())
    p = 1000
    pred = simulate(bins, p, "predictive")
    reac = simulate(bins, p, "reactive")
    assert pred.runtime <= reac.runtime


def test_short_period_overhead_dominates():
    """Very short periods reveal monitoring+movement overheads (SIII-C)."""
    bins = bin_trace(_small_trace())
    shortest = simulate(bins, 100, "reactive")
    mid = simulate(bins, 2000, "reactive")
    assert shortest.runtime > mid.runtime


def test_fast_hits_bounded_by_capacity_share():
    """With uniform sweeps, hitrate can't exceed 1.0; data moved is capped
    by capacity per period."""
    cfg = SimConfig()
    bins = bin_trace(_small_trace())
    r = simulate(bins, 500, "reactive", cfg)
    assert 0.0 <= r.fast_hitrate <= 1.0
    capacity = cfg.fast_capacity(bins.num_pages)
    num_periods = -(-bins.num_accesses // 500)
    assert r.migrations <= capacity * num_periods


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_property_random_traces(data):
    """Invariants over random traces: scan==oracle, bounded hitrate,
    nonnegative overhead decomposition."""
    n_pages = data.draw(st.integers(8, 64))
    n = data.draw(st.integers(200, 2000))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    pages = rng.integers(0, n_pages, size=n).astype(np.int32)
    tr = Trace("rand", pages, n_pages, np.array([n]))
    bins = bin_trace(tr, block=50)
    period = data.draw(st.sampled_from([50, 100, 250]))
    sched = data.draw(st.sampled_from(["reactive", "predictive"]))
    a = simulate(bins, period, sched)
    b = simulate_reference(bins, period, sched)
    np.testing.assert_allclose(a.runtime, b.runtime, rtol=1e-4)
    assert a.migrations == b.migrations
    assert 0.0 <= a.fast_hitrate <= 1.0
    assert a.runtime >= n * 1.0


def test_capacity_respected_in_placement():
    """The simulator never claims more fast hits than a 100% hitrate and the
    reference's fast set is exactly the configured capacity."""
    tr = _small_trace()
    bins = bin_trace(tr)
    cfg = SimConfig(fast_frac=0.5)
    r = simulate(bins, 1000, "predictive", cfg)
    assert r.fast_hits <= r.num_accesses
    assert r.fast_hitrate > 0.3  # 50% capacity must produce real hits


def test_period_snapping():
    bins = bin_trace(_small_trace())
    r = simulate(bins, 149, "reactive")
    assert r.period_requests == 100
    r = simulate(bins, 151, "reactive")
    assert r.period_requests == 200
