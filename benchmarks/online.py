"""Online-Cori benchmark: closed-loop tuning on a phase-shifted workload.

The serving mix flips mid-run from zipf random retrieval (best served by a
long tiering period) to a drifting attention-sink pattern (best served by a
very short one).  Reports, for the online tuner vs the offline
tune-once-on-phase-A Cori and the fixed-period ladder:

  * time-to-converge (decode steps until the last HOLD was entered),
  * total modeled time over the whole run,
  * steady-state per-step cost over the final window (the paper-style
    "did you end up at the right frequency" metric).

    PYTHONPATH=src python -m benchmarks.online
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import save_json
from repro.memtier import TierConfig, cori_tune_period, online_replay, replay
from repro.memtier import workload as W

CFG = TierConfig(hbm_pages=16, period_steps=8)
FIXED = (1, 2, 4, 8, 16, 32, 64, 200)
STEADY_WINDOW = 100


def _total_and_window(wl: np.ndarray, period: int, lo: int
                      ) -> "tuple[float, float]":
    """(total cost, per-step cost over [lo, end)) of a fixed-period replay.
    One full run plus one prefix run -- the replay is deterministic, so the
    window cost is an exact prefix difference."""
    cfg = dataclasses.replace(CFG, period_steps=period)
    total = replay(wl, cfg).modeled_time
    head = replay(wl[:lo], cfg).modeled_time
    return total, (total - head) / (wl.shape[0] - lo)


def run(quick: bool = False):
    phase = 300 if quick else 600
    n = 64
    wl = np.concatenate([W.random_lookup(phase, n, seed=0),
                         W.attention_sink(phase, n, seed=1, drift_every=1)])
    steps = wl.shape[0]
    lo, hi = steps - STEADY_WINDOW, steps

    mgr, tuner = online_replay(wl, CFG)
    online_steady = float(np.mean(np.asarray(tuner.cost_log)[-STEADY_WINDOW:]))

    # offline baseline: Cori tunes once on the first phase, holds the period
    off_res, off_dr = cori_tune_period(wl[:phase], CFG)
    off_period = max(1, int(round(off_res.chosen_period)))
    off_total, off_steady = _total_and_window(wl, off_period, lo)

    fixed = {}
    for p in FIXED:
        total, steady = _total_and_window(wl, p, lo)
        fixed[str(p)] = {"total": total, "steady": steady}
    best_steady = min(v["steady"] for v in fixed.values())
    best_total = min(v["total"] for v in fixed.values())

    out = {
        "steps": steps,
        "online": {
            "total": mgr.modeled_time,
            "steady": online_steady,
            "final_period": tuner.period,
            "time_to_converge_steps": tuner.converged_at,
            "tune_cycles": tuner.retunes,
            "period_history": tuner.history,
        },
        "offline_phase_a": {
            "period": off_period,
            "dominant_reuse": off_dr,
            "total": off_total,
            "steady": off_steady,
        },
        "fixed": fixed,
        "online_vs_best_fixed_steady": online_steady / best_steady,
        "online_vs_best_fixed_total": mgr.modeled_time / best_total,
        "online_vs_offline_steady": online_steady / off_steady,
    }
    save_json("online", out)
    return out


if __name__ == "__main__":
    r = run()
    o = r["online"]
    print(f"online: period={o['final_period']} converged at step "
          f"{o['time_to_converge_steps']} after {o['tune_cycles']} cycles")
    print(f"steady-state cost/step: online {o['steady']:.2f} | offline "
          f"{r['offline_phase_a']['steady']:.2f} "
          f"(period {r['offline_phase_a']['period']})")
    for p, v in r["fixed"].items():
        print(f"    fixed {p:>3s}: steady {v['steady']:8.2f} total "
              f"{v['total']:10.0f}")
    print(f"online vs best fixed (steady): "
          f"{r['online_vs_best_fixed_steady']:.3f}x; vs offline: "
          f"{r['online_vs_offline_steady']:.3f}x")
