"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import pathlib
import time

OUT = pathlib.Path(__file__).resolve().parent / "out"

APPS = ["backprop", "quicksilver", "lud", "cpd", "pennant", "kmeans",
        "hotspot", "bfs", "bptree"]
SCHEDS = ["reactive", "predictive"]


def save_json(name: str, payload) -> pathlib.Path:
    OUT.mkdir(parents=True, exist_ok=True)
    p = OUT / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p


def load_json(name: str):
    p = OUT / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0

    @property
    def us(self) -> float:
        return self.seconds * 1e6
