"""Cori-tuned HBM <-> host KV-page tiering (the paper's technique, adapted).

Mapping (DESIGN.md S3):
    DRAM            -> HBM working set      (hbm_pages physical slots)
    PMEM            -> host backing store   (all logical pages)
    page scheduler  -> ``TieringManager.maybe_tier`` every ``period`` steps
    accessed bits   -> per-page attention mass from the decode step
    move_pages()    -> ``migrate`` (gather/scatter on the physical pools)
    Cori            -> ``repro.core.cori`` tuning ``period`` from the
                       attention-reuse histogram (step domain)

The page-selection rule is the paper's verbatim: EMA hotness ranks pages,
top-capacity hot pages swap in against LRU residents, swaps capped by
capacity.  Costs are modeled with the same structure as ``core.sim`` but
with TPU-tier constants (HBM vs PCIe-host), since this container has no
real TPU clock: a decode step pays 1 unit per resident-page touch,
``miss_penalty`` per non-resident touch (on-demand host fetch), plus
migration and wakeup costs per tiering period.

Invariants the serving scheduler relies on (pinned by tests/test_sched.py
and tests/test_memtier.py):

  * **Page-ID recycling contract.**  A logical page ID freed by
    ``SharedPagedPools.free`` may be handed to a different request by the
    next ``alloc``.  Every consumer of page IDs must therefore be told
    about the free *before* the ID recycles: ``TieringManager.release``
    clears hotness/recency, ``OnlineTuner.forget_pages`` invalidates the
    reuse chain, and the pool itself drops residency and owner.  A
    recycled ID always starts cold, host-only and unowned.
  * **Active-mask semantics.**  ``maybe_tier(active=...)`` ranks only
    pages some request currently owns; unallocated IDs can never enter
    the working set even when capacity exceeds the allocated footprint.
    With ``active=None`` (single-request pools) every ID is rankable and
    the rule reduces bit-exactly to the paper's paired-swap at fixed
    footprint.
  * **One slot table, many layers.**  In the fully-paged serving path the
    pools carry one KV leaf per attention layer
    (``attach_layered_kv``), but residency is per *logical page*: a page
    is resident for all layers or none, and every migration
    (``migrate_slots``) moves all layers' bytes for that page together.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cori, reuse
from repro.ft.inject import MigrationError, NULL_PLAN
from repro.kernels import ops
from repro.obs import telemetry as _obs

__all__ = ["TierConfig", "TieringManager", "PagedPools", "SharedPagedPools",
           "bucket_pages", "write_pages_batched", "write_state_pages"]


def bucket_pages(n_pages: int, cap: Optional[int] = None) -> int:
    """Shape-bucketed allocation size: round a page count up to the next
    power of two, capped at ``cap`` (the cache-row capacity in pages).

    Buckets bound the number of distinct allocation shapes (so jitted
    decode functions and pool scatter patterns are reused across request
    lengths) at a bounded fragmentation cost: a request never holds more
    than 2x its exact page need, and never more than one full row."""
    if n_pages <= 0:
        raise ValueError(f"cannot bucket {n_pages} pages")
    if cap is not None and n_pages > cap:
        raise ValueError(f"{n_pages} pages exceed the {cap}-page row cap")
    b = 1 << (n_pages - 1).bit_length()
    return min(b, cap) if cap is not None else b


@dataclasses.dataclass(frozen=True)
class TierConfig:
    page_size: int = 16            # tokens per KV page
    hbm_pages: int = 0             # working-set capacity (physical slots)
    period_steps: int = 8          # tiering period (what Cori tunes)
    ema_alpha: float = 0.5
    access_threshold: float = 0.05  # attention mass to count as "accessed"
    # modeled costs (units: one HBM page-read)
    miss_penalty: float = 32.0     # on-demand host fetch (PCIe ~25GB/s vs HBM)
    mig_cost: float = 16.0         # async page migration
    wakeup_cost: float = 4.0       # scheduler wakeup per period
    # a demand-fetch issued through ``ensure_resident`` moves all its
    # pages in ONE gathered host->HBM transfer, so a fetched page is
    # cheaper than a mid-kernel on-demand miss (no per-page latency, the
    # transfer amortises): this is what TrafficMonitor charges per
    # ``fetched`` page
    fetch_cost: float = 24.0


@dataclasses.dataclass
class PagedPools:
    """Physical KV page pools for one representative layer group.

    host pools hold every logical page; the HBM pool holds the resident
    working set.  ``slot_of[logical] == -1`` means host-only."""
    k_host: jnp.ndarray            # [n_logical, page, kv, d]
    v_host: jnp.ndarray
    k_hbm: jnp.ndarray             # [hbm_pages, page, kv, d]
    v_hbm: jnp.ndarray
    slot_of: np.ndarray            # int32[n_logical] -> hbm slot | -1
    page_of_slot: np.ndarray       # int32[hbm_pages] -> logical | -1
    #: bumped whenever slot_of changes (page-table caches key on it)
    slot_epoch: int = 0

    @classmethod
    def create(cls, k_pages, v_pages, hbm_pages: int):
        """Interleaved initial residency (paper SII-B initial placement)."""
        from repro.core.sim import interleaved_indices
        n = k_pages.shape[0]
        init = interleaved_indices(n, hbm_pages).astype(np.int32)
        slot_of = np.full((n,), -1, np.int32)
        slot_of[init] = np.arange(hbm_pages)
        return cls(
            k_host=k_pages, v_host=v_pages,
            k_hbm=k_pages[init], v_hbm=v_pages[init],
            slot_of=slot_of,
            page_of_slot=init.copy())

    def touch_slots(self, slots: np.ndarray) -> None:
        """No-op: the fixed single-request pool has no demand-fetch path,
        so slot recency is meaningless here (SharedPagedPools tracks it)."""

    def migrate_slots(self, slots, logicals) -> None:
        """Copy host pages ``logicals`` into HBM ``slots`` (all pools)."""
        if len(slots) == 0 or self.k_host is None:
            return
        sl, lg = jnp.asarray(slots), jnp.asarray(logicals)
        self.k_hbm = _migrate(self.k_hbm, self.k_host, sl, lg)
        self.v_hbm = _migrate(self.v_hbm, self.v_host, sl, lg)


@jax.jit
def _migrate(pool_hbm, pool_host, slots, logicals):
    """Copy host pages `logicals` into HBM `slots` (the move_pages analogue;
    on real hardware this is the pinned_host->device DMA)."""
    return pool_hbm.at[slots].set(pool_host[logicals])


@jax.jit
def _migrate_stacked(pool_hbm, pool_host, slots, logicals):
    """`_migrate` for layer-stacked pools [R, P, page, KV, D]: one page's
    bytes move for every repeat of the layer slot together."""
    return pool_hbm.at[:, slots].set(pool_host[:, logicals])


@functools.partial(jax.jit, donate_argnums=(0,))
def _migrate_all(kv, slots, logicals):
    """One gathered host->HBM transfer for the WHOLE layered pytree: every
    leaf of every layer gathers its ``logicals`` pages and scatters them
    into ``slots`` inside a single jitted launch (donated, so XLA updates
    the pool buffers in place).  Replaces the per-leaf x per-layer
    ``_migrate_stacked`` loop -- L*leaves dispatches collapse into one,
    which is what makes ``ensure_resident`` cheap enough to run as the
    pipelined prefetch stage.  ``slots``/``logicals`` are padded to a
    power of two to bound recompiles: pad logicals with 0 (the gather is
    harmless), pad slots with ``PAGE_DROP`` so the scatter drops them."""
    out = {k: list(v) for k, v in kv.items()}
    for hk in [k for k in kv if k.endswith("_hbm")]:
        dk = hk[:-4] + "_host"
        for i, h in enumerate(kv[hk]):
            if h is None:
                continue
            out[hk][i] = h.at[:, slots].set(kv[dk][i][:, logicals],
                                            mode="drop")
    return out


class SharedPagedPools:
    """One HBM slot pool shared by *all* in-flight requests' KV pages.

    The multi-request generalisation of ``PagedPools``: logical page IDs
    live in one global space sized ``n_logical`` (the allocator's
    capacity), requests allocate page-aligned runs at admission
    (``alloc``) and return them at retirement (``free``, which also evicts
    any HBM slots they held).  ``slot_of[gid]`` is the per-request
    indirection the paged-attention kernel consumes: a request's page
    table of global IDs maps to physical HBM slots via ``table``.

    Two modes:
      * physical -- ``create(..., like=...)`` allocates host/HBM arrays;
        ``write_page`` mirrors KV data and ``ensure_resident`` demand-
        fetches pages the kernel is about to gather.
      * symbolic -- no arrays (``k_host is None``); only the residency and
        allocation bookkeeping runs.  Used by the traffic simulator where
        thousands of scheduler steps replay without touching KV bytes.

    Unlike ``PagedPools`` (fixed single-request footprint, every slot
    always occupied), slots here can be *free* (``page_of_slot == -1``)
    after a retirement; ``TieringManager.maybe_tier`` fills free slots
    before evicting residents.
    """

    def __init__(self, n_logical: int, hbm_pages: int, *,
                 k_host=None, v_host=None, k_hbm=None, v_hbm=None):
        if hbm_pages > n_logical:
            raise ValueError("HBM slot pool larger than the logical space")
        self.n_logical = int(n_logical)
        self.hbm_pages = int(hbm_pages)
        self.k_host, self.v_host = k_host, v_host
        self.k_hbm, self.v_hbm = k_hbm, v_hbm
        # fully-paged mode: one KV leaf per attention layer slot, all
        # indirected by the SAME slot_of table (see attach_layered_kv)
        self.kv_layers: Optional[Dict[str, List[Optional[jnp.ndarray]]]] = None
        self.layer_meta: Tuple = ()
        #: per-layer leaf-name tuples (set by ``attach_layered``)
        self.layer_leaves: Tuple = ()
        #: leaves moved per page migration (tier.move accounting)
        self.move_planes = 2
        self.slot_of = np.full((n_logical,), -1, np.int32)
        self.page_of_slot = np.full((hbm_pages,), -1, np.int32)
        self.owner_of = np.full((n_logical,), -1, np.int64)
        #: fault-injection plan (chaos harness); inert by default
        self.fault_plan = NULL_PLAN
        #: live capacity in pages -- ``hbm_pages`` normally, lower under an
        #: injected ``pool.squeeze`` (the batcher's pressure logic and the
        #: tiering boundary both budget against this, never above it)
        self.effective_hbm = int(hbm_pages)
        #: migrate retry-with-backoff knobs (the degraded ladder's rung 1)
        self.migrate_retries = 2
        self.retry_backoff_s = 0.001
        #: pages whose fast migration path exhausted its retries serve
        #: pinned-to-host for a cooldown: ``apply_plan`` skips promoting
        #: them and every demand fetch takes the degraded slow path,
        #: priced at ``miss_penalty`` (see ``_pin_until``)
        self._pin_until = np.zeros((n_logical,), np.int64)
        self.pin_cooldown = 64
        #: degraded (retry-exhausted) fetches since the caller last drained
        #: this -- the batcher charges them into the tuner's window
        self.degraded_fetches = 0
        #: bumped on every ``slot_of`` mutation -- page-table caches key
        #: on it to skip the per-boundary rebuild + device upload when no
        #: page moved (see ContinuousBatcher's table cache)
        self.slot_epoch = 0
        # free logical ids, popped lowest-first so reuse is deterministic
        self._free_ids: List[int] = list(range(n_logical - 1, -1, -1))
        # per-slot touch tick for the demand-fetch victim choice
        self._slot_tick = np.zeros((hbm_pages,), np.int64)
        self._tick = 0
        # allocation accounting (bucketed rows: benchmarks compare this
        # peak against the dense max_len provisioning)
        self.allocated_pages = 0
        self.peak_allocated = 0

    # -- constructors --------------------------------------------------------
    @classmethod
    def create(cls, n_logical: int, hbm_pages: int, *,
               page_size: Optional[int] = None, kv_heads: int = 0,
               head_dim: int = 0, dtype=jnp.float32) -> "SharedPagedPools":
        """Physical pools when page geometry is given, symbolic otherwise."""
        if page_size is None:
            return cls(n_logical, hbm_pages)
        shape = (n_logical, page_size, kv_heads, head_dim)
        hshape = (hbm_pages,) + shape[1:]
        return cls(n_logical, hbm_pages,
                   k_host=jnp.zeros(shape, dtype),
                   v_host=jnp.zeros(shape, dtype),
                   k_hbm=jnp.zeros(hshape, dtype),
                   v_hbm=jnp.zeros(hshape, dtype))

    def attach_layered(self, layer_specs: Sequence[Tuple[int, Dict[str,
                       Tuple[int, ...]]]], *, dtype=jnp.float32) -> None:
        """Grow per-layer cache storage for the fully-paged decode path
        from *per-geometry leaf specs*: one ``(repeats, {leaf_name:
        trailing_shape})`` entry per state-bearing layer slot.  A plain
        attention slot attaches ``{"k": (page, KV, D), "v": ...}``; an MLA
        slot attaches compressed ``{"ckv": (page, kv_lora), "krope":
        (page, rope)}`` rows; a recurrent slot attaches one fixed-size
        ``{"state": (state_dim,)}`` page per request.  Every leaf is
        stacked over its slot's ``repeats``: host side
        [R, n_logical, *trailing], HBM side [R, hbm_pages, *trailing].
        All leaves share this pool's single ``slot_of`` table -- a logical
        page is resident for every layer or for none, and migrations move
        all of a page's leaves together.  Layers lacking a leaf hold
        ``None`` in that leaf's per-layer list, so mismatched geometries
        can never cross-contaminate."""
        names: List[str] = []
        for _, leaves in layer_specs:
            for name in leaves:
                if name not in names:
                    names.append(name)
        kv: Dict[str, List[Optional[jnp.ndarray]]] = {}
        for name in names:
            for tier in ("hbm", "host"):
                kv[f"{name}_{tier}"] = []
        for r, leaves in layer_specs:
            for name in names:
                if name in leaves:
                    trail = tuple(int(x) for x in leaves[name])
                    kv[f"{name}_host"].append(
                        jnp.zeros((int(r), self.n_logical) + trail, dtype))
                    kv[f"{name}_hbm"].append(
                        jnp.zeros((int(r), self.hbm_pages) + trail, dtype))
                else:
                    kv[f"{name}_host"].append(None)
                    kv[f"{name}_hbm"].append(None)
        self.kv_layers = kv
        self.layer_meta = tuple(int(r) for r, _ in layer_specs)
        self.layer_leaves = tuple(tuple(leaves) for _, leaves in layer_specs)
        # pages_moved accounting: how many per-page planes (leaves) one
        # logical-page migration moves.  The classic (k, v) geometry is 2.
        self.move_planes = max((len(lv) for lv in self.layer_leaves),
                               default=2)
        if (r := _obs.RECORDER).enabled:
            r.emit("pool.attach", layers=len(self.layer_meta),
                   leaves=",".join(names), planes=self.move_planes)

    def attach_layered_kv(self, layer_repeats: Sequence[int], *,
                          page_size: int, kv_heads: int, head_dim: int,
                          dtype=jnp.float32) -> None:
        """Back-compat wrapper over ``attach_layered`` for the classic
        all-attention geometry: one (k, v) leaf pair per attention layer
        slot, [R, n_logical, page, KV, D] host / [R, hbm_pages, ...] HBM."""
        trail = (int(page_size), int(kv_heads), int(head_dim))
        self.attach_layered([(int(r), {"k": trail, "v": trail})
                             for r in layer_repeats], dtype=dtype)

    def kv_view(self) -> Dict[str, List[jnp.ndarray]]:
        """The layered-KV pytree a jitted paged decode step consumes (and
        returns updated; store it back with ``set_kv``)."""
        if self.kv_layers is None:
            raise ValueError("no layered cache attached (attach_layered)")
        return {k: list(v) for k, v in self.kv_layers.items()}

    def set_kv(self, kv: Dict[str, List[jnp.ndarray]]) -> None:
        self.kv_layers = {k: list(v) for k, v in kv.items()}

    # -- views ---------------------------------------------------------------
    @property
    def physical(self) -> bool:
        return self.k_host is not None or self.kv_layers is not None

    @property
    def resident_mask(self) -> np.ndarray:
        return self.slot_of >= 0

    @property
    def allocated_mask(self) -> np.ndarray:
        return self.owner_of >= 0

    @property
    def free_pages(self) -> int:
        return len(self._free_ids)

    def free_slots(self) -> np.ndarray:
        return np.nonzero(self.page_of_slot < 0)[0].astype(np.int32)

    @property
    def hbm_occupied(self) -> int:
        return int((self.page_of_slot >= 0).sum())

    def host_pinned(self, gids: np.ndarray) -> np.ndarray:
        """bool per gid: pinned to host by a retry-exhausted migration
        (cooldown measured in placement ticks)."""
        return self._pin_until[np.asarray(gids, np.int64)] > self._tick

    def table(self, gids: np.ndarray) -> np.ndarray:
        """Physical HBM slot per global page ID (-1 = host-only)."""
        return self.slot_of[np.asarray(gids, np.int64)]

    # -- allocator -----------------------------------------------------------
    def alloc(self, n_pages: int, owner: int) -> Optional[np.ndarray]:
        """Allocate `n_pages` global page IDs for request `owner`; None when
        the logical space cannot fit the request (caller queues it)."""
        if n_pages > len(self._free_ids):
            return None
        gids = np.asarray([self._free_ids.pop() for _ in range(n_pages)],
                          np.int64)
        self.owner_of[gids] = owner
        self.allocated_pages += n_pages
        self.peak_allocated = max(self.peak_allocated, self.allocated_pages)
        if (r := _obs.RECORDER).enabled:
            r.count("pool.alloc_pages", n_pages)
            r.gauge("pool.allocated_frac",
                    self.allocated_pages / self.n_logical)
        return gids

    def free(self, gids: np.ndarray) -> None:
        """Return a retired request's pages; their HBM slots become free."""
        gids = np.asarray(gids, np.int64)
        slots = self.slot_of[gids]
        held = slots[slots >= 0]
        self.page_of_slot[held] = -1
        self.slot_of[gids] = -1
        if held.size:
            self.slot_epoch += 1
        self.owner_of[gids] = -1
        self._free_ids.extend(sorted(gids.tolist(), reverse=True))
        self.allocated_pages -= int(gids.size)
        if (r := _obs.RECORDER).enabled:
            r.count("pool.free_pages", int(gids.size))
            r.gauge("pool.allocated_frac",
                    self.allocated_pages / self.n_logical)
            r.gauge("pool.hbm_resident_frac",
                    float((self.page_of_slot >= 0).sum()) / self.hbm_pages)

    def demote(self, gids: np.ndarray) -> int:
        """Release the HBM slots of ``gids`` WITHOUT freeing the
        allocation: the preemption primitive.  The host copy is
        write-through (every decode step updates both tiers), so dropping
        the slots moves no data and loses no bytes -- a frozen request's
        cache survives intact and the next ``ensure_resident`` fetches it
        back, which is exactly the Cori-visible data movement preemption
        is supposed to be.  Returns the number of slots released."""
        gids = np.asarray(gids, np.int64)
        slots = self.slot_of[gids]
        held = slots[slots >= 0]
        self.page_of_slot[held] = -1
        self.slot_of[gids] = -1
        if held.size:
            self.slot_epoch += 1
            if (r := _obs.RECORDER).enabled:
                r.gauge("pool.hbm_resident_frac",
                        float((self.page_of_slot >= 0).sum())
                        / self.hbm_pages)
        return int(held.size)

    # -- physical data path --------------------------------------------------
    def write_page(self, gid: int, k_page, v_page) -> None:
        """Write one logical page's KV data (host copy; mirrored to the HBM
        slot when resident, the write-through of a decode-step append).
        Legacy single-layer pools only -- the fully-paged path writes its
        layered leaves inside the jitted decode step instead."""
        if self.k_host is None:
            return
        self.k_host = self.k_host.at[gid].set(k_page)
        self.v_host = self.v_host.at[gid].set(v_page)
        slot = int(self.slot_of[gid])
        if slot >= 0:
            self.k_hbm = self.k_hbm.at[slot].set(k_page)
            self.v_hbm = self.v_hbm.at[slot].set(v_page)

    def touch_slots(self, slots: np.ndarray) -> None:
        """Mark slots recently-used for the demand-fetch victim choice
        (called by the tiering pass so freshly-migrated hot pages are not
        the first LRU victims)."""
        self._tick += 1
        self._slot_tick[np.asarray(slots, np.int64)] = self._tick

    def migrate_slots(self, slots, logicals, *, degraded: bool = False)\
            -> None:
        """Copy host pages ``logicals`` into HBM ``slots`` on EVERY
        physical pool: the legacy monitor-layer pair and, in fully-paged
        mode, each attention layer's leaf (one page's bytes move for all
        layers together -- the page is the migration unit, not the
        (page, layer) pair).

        ``degraded=True`` is the retry-exhausted slow path: it models a
        synchronous per-page copy that cannot fail, so the injected
        transport faults are bypassed (the bytes moved are identical --
        only the modeled price differs, charged by the caller)."""
        if len(slots) == 0:
            return
        if not degraded and (plan := self.fault_plan).enabled:
            if (p := plan.fires("pool.migrate_slow")) is not None:
                time.sleep(float(p.value))
            if plan.fires("pool.migrate_fail") is not None:
                raise MigrationError(
                    f"injected migrate_slots failure ({len(slots)} pages)")
        sl, lg = jnp.asarray(slots), jnp.asarray(logicals)
        if self.k_host is not None:
            self.k_hbm = _migrate(self.k_hbm, self.k_host, sl, lg)
            self.v_hbm = _migrate(self.v_hbm, self.v_host, sl, lg)
        if self.kv_layers is not None:
            # one gathered transfer for every leaf of every layer: pad the
            # index vectors to a power of two so the jitted launch is
            # reused across fetch sizes (dropped-scatter padding)
            sl_np = np.asarray(slots, np.int32)
            lg_np = np.asarray(logicals, np.int32)
            pad = (1 << max(0, int(sl_np.size - 1).bit_length())) - sl_np.size
            if pad > 0:
                sl_np = np.concatenate(
                    [sl_np, np.full(pad, PAGE_DROP, np.int32)])
                lg_np = np.concatenate([lg_np, np.zeros(pad, np.int32)])
            self.set_kv(_migrate_all(self.kv_view(), jnp.asarray(sl_np),
                                     jnp.asarray(lg_np)))

    def _place(self, gids: np.ndarray) -> Tuple[List[int], np.ndarray]:
        """Slot bookkeeping shared by ``ensure_resident`` and
        ``assign_slots``: give every non-resident page in ``gids`` an HBM
        slot (free slots first, then evict the least-recently-ensured
        resident outside ``gids``).  Returns (slots, missing)."""
        gids = np.asarray(gids, np.int64)
        if gids.size > self.hbm_pages:
            raise ValueError(f"{gids.size} pages cannot fit the "
                             f"{self.hbm_pages}-slot HBM pool")
        self._tick += 1
        missing = gids[self.slot_of[gids] < 0]
        # slot choice is sequential (each fetch consumes a slot), but the
        # device copies batch into one gather/scatter per pool
        slots: List[int] = []
        for gid in missing.tolist():
            free = np.nonzero(self.page_of_slot < 0)[0]
            occupied = self.hbm_pages - free.size
            if free.size and occupied < self.effective_hbm:
                slot = int(free[0])
            else:
                # at (squeezed) capacity: evict the least-recently-ensured
                # occupied slot outside the protected set; when every
                # occupied slot is protected (a squeeze below the working
                # set), overflow into a free slot rather than fail
                prot = np.zeros(self.hbm_pages, bool)
                prot[self.slot_of[gids[self.slot_of[gids] >= 0]]] = True
                victims = np.nonzero(~prot & (self.page_of_slot >= 0))[0]
                if victims.size:
                    slot = int(victims[np.argmin(self._slot_tick[victims])])
                    self.slot_of[self.page_of_slot[slot]] = -1
                else:
                    slot = int(free[0])
            self.slot_of[gid] = slot
            self.page_of_slot[slot] = gid
            slots.append(slot)
        if missing.size:
            self.slot_epoch += 1
        self._slot_tick[self.slot_of[gids]] = self._tick
        return slots, missing

    def ensure_resident(self, gids: np.ndarray) -> int:
        """Demand-fetch: make every page in `gids` HBM-resident (free slots
        first, then evict the least-recently-ensured resident outside
        `gids`).  Returns the number of pages fetched -- the caller charges
        them as misses.  Raises if `gids` alone exceed the slot pool.

        A failing ``migrate_slots`` (injected transport fault) is retried
        with exponential backoff; on exhaustion the fetch falls back to
        the degraded slow path -- the bytes still move (token parity is
        never traded away), but the pages pin to host for a cooldown and
        the fetch is counted in ``degraded_fetches`` so the serving loop
        can charge it at ``miss_penalty`` into the tuner's window."""
        slots, missing = self._place(gids)
        if missing.size:
            self._migrate_with_retry(slots, missing)
        if missing.size and (r := _obs.RECORDER).enabled:
            r.count("pool.fetch_misses", int(missing.size))
            r.gauge("pool.hbm_resident_frac",
                    float((self.page_of_slot >= 0).sum()) / self.hbm_pages)
        return int(missing.size)

    def _migrate_with_retry(self, slots, logicals) -> None:
        """``migrate_slots`` with bounded retry-with-backoff, then the
        degraded pinned-to-host fallback (see ``ensure_resident``)."""
        delay = self.retry_backoff_s
        for attempt in range(self.migrate_retries + 1):
            try:
                self.migrate_slots(slots, logicals)
                return
            except MigrationError:
                if attempt < self.migrate_retries and delay > 0:
                    time.sleep(delay)
                    delay *= 2
        self.migrate_slots(slots, logicals, degraded=True)
        lg = np.asarray(logicals, np.int64)
        self._pin_until[lg] = self._tick + self.pin_cooldown
        self.degraded_fetches += int(lg.size)
        if (r := _obs.RECORDER).enabled:
            r.count("pool.degraded_fetches", int(lg.size))

    def assign_slots(self, gids: np.ndarray) -> np.ndarray:
        """``ensure_resident`` without the host->HBM byte copy: the caller
        is about to overwrite the pages' content on BOTH tiers in one
        device scatter (``write_pages_batched``), so migrating the stale
        bytes first would be wasted PCIe traffic.  Returns the HBM slot of
        every page in ``gids`` (all resident on return)."""
        self._place(gids)
        return self.slot_of[np.asarray(gids, np.int64)].copy()


PAGE_DROP = np.int32(2 ** 30)      # out-of-range scatter index => dropped


@functools.partial(jax.jit, donate_argnums=(0,))
def write_pages_batched(kv, new_leaves, gids, slots):
    """On-device prefill scatter: write a packed-prefill step's cache rows
    for EVERY token-paged leaf and EVERY joiner straight into the layered
    page pools, host and HBM tiers together, in one jitted gather/scatter.

    kv:          the layered pool pytree (``SharedPagedPools.kv_view``;
                 donated -- XLA updates the pool buffers in place).
    new_leaves:  {leaf_name: [per-layer arrays or None]}, each array
                 [R, J, smax, *rest]: the batched-prefill cache rows of
                 the J joiners (right-padded to smax).  ``rest`` is the
                 leaf's per-token trailing shape -- (KV, D) for k/v,
                 (kv_lora,) for MLA ckv, (rope,) for krope.
    gids/slots:  int32[J, n_max] logical page ids / HBM slot ids per
                 joiner page; entries >= the pool size (``PAGE_DROP``)
                 are dropped -- the ragged padding of short prompts.

    Replaces the host-side per-request x per-layer x per-tensor ``.at``
    loop: J*L*leaves separate dispatches collapse into one launch, and the
    prefill bytes never take the host detour (on TPU they go HBM->HBM).
    """
    j, n_max = gids.shape
    gidf = gids.reshape(-1)
    slotf = slots.reshape(-1)
    out = {k: list(v) for k, v in kv.items()}
    for name, layers in new_leaves.items():
        for li, new in enumerate(layers):
            if new is None:
                continue
            ps = kv[f"{name}_host"][li].shape[2]
            r, _, smax = new.shape[:3]
            rest = new.shape[3:]
            pad = n_max * ps - smax
            if pad > 0:
                new = jnp.pad(new, ((0, 0), (0, 0), (0, pad))
                              + ((0, 0),) * len(rest))
            pages = new[:, :, : n_max * ps].reshape((r, j * n_max, ps)
                                                    + rest)
            out[f"{name}_host"][li] = out[f"{name}_host"][li].at[
                :, gidf].set(pages, mode="drop")
            out[f"{name}_hbm"][li] = out[f"{name}_hbm"][li].at[
                :, slotf].set(pages, mode="drop")
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def write_state_pages(kv, states, gids, slots):
    """Scatter recurrent state pages into the pool, both tiers at once.
    ``states``: one [R, J, state_dim] leaf (or None) per layer slot;
    ``gids``/``slots``: int32[J] -- each joiner's single state page
    (``PAGE_DROP`` entries are dropped)."""
    out = {k: list(v) for k, v in kv.items()}
    for li, st in enumerate(states):
        if st is None:
            continue
        out["state_host"][li] = out["state_host"][li].at[:, gids].set(
            st, mode="drop")
        out["state_hbm"][li] = out["state_hbm"][li].at[:, slots].set(
            st, mode="drop")
    return out


class TieringManager:
    """Periodic page scheduler over a PagedPools working set."""

    _obs_count = 0          # process-wide id counter for telemetry streams

    def __init__(self, n_logical: int, cfg: TierConfig,
                 access_log_len: int = 65536):
        self.cfg = cfg
        self.n = n_logical
        self.hotness = np.zeros(n_logical, np.float64)
        self.last_access = np.full(n_logical, -1.0)
        self.step = 0
        # accessed page ids per step, bounded: the manager lives inside the
        # serving loop, and the online path reads reuse from the tuner's
        # StreamingReuseCollector, not from this log (which feeds the
        # offline `reuse_histogram`/`cori_candidates` flow)
        self.access_log: "collections.deque[np.ndarray]" = collections.deque(
            maxlen=access_log_len)
        self.counts_since_tier = np.zeros(n_logical, np.float64)
        # live tiering period (what online Cori drives); counted against the
        # steps elapsed since the last tier so period changes apply cleanly
        # mid-run
        self.period = max(1, int(cfg.period_steps))
        self._since_tier = 0
        # accounting
        self.migrations = 0
        self.modeled_time = 0.0
        self.data_moved_pages = 0
        self.hits = 0
        self.misses = 0
        TieringManager._obs_count += 1
        #: short id tagging this instance's telemetry events ("m1", ...)
        self.obs_id = f"m{TieringManager._obs_count}"

    def set_period(self, period_steps: int) -> None:
        """Change the tiering period live (the online-Cori control knob)."""
        self.period = max(1, int(period_steps))

    def _tier_due(self) -> bool:
        if self._since_tier < self.period:
            return False
        self._since_tier = 0
        return True

    # -- monitor -----------------------------------------------------------
    def on_step(self, page_mass: np.ndarray, resident: np.ndarray,
                weight: float = 1.0):
        """page_mass: f32[n_logical] attention mass this decode step;
        resident: bool[n_logical].

        ``weight`` is the number of token-steps this mass sample spans
        (1 on the per-token path; the macro length when accessed bits are
        sampled once per movement period).  Hotness counts and hit/miss
        service costs scale by it, so a page touched every token accrues
        the same modeled cost whether the host observed it once or
        ``weight`` times -- without this, a longer period would look
        cheaper purely because it was sampled less often."""
        accessed = page_mass >= self.cfg.access_threshold
        ids = np.nonzero(accessed)[0].astype(np.int32)
        self.access_log.append(ids)
        self.counts_since_tier[accessed] += weight
        self.last_access[accessed] = self.step
        hits = accessed & resident
        misses = accessed & ~resident
        self.hits += int(weight * hits.sum())
        self.misses += int(weight * misses.sum())
        self.modeled_time += weight * (hits.sum() * 1.0
                                       + misses.sum() * self.cfg.miss_penalty)
        self.step += 1
        self._since_tier += 1

    # -- multi-request bookkeeping -------------------------------------------
    def release(self, ids: np.ndarray) -> None:
        """Forget retired pages (a request left the system): their hotness
        must not keep dead logical IDs ranked into the working set, and a
        recycled ID must start cold.  The bounded ``access_log`` is left
        as-is -- it feeds the offline histogram flow only, which the
        multi-request scheduler does not use (it reads reuse from the
        OnlineTuner's collector, which gets its own ``forget``)."""
        ids = np.asarray(ids, np.int64)
        self.hotness[ids] = 0.0
        self.counts_since_tier[ids] = 0.0
        self.last_access[ids] = -1.0

    # -- the page scheduler (paper SII-B swap rule) --------------------------
    def _rank_desired(self, resident: np.ndarray,
                      active: Optional[np.ndarray] = None) -> np.ndarray:
        """EMA-update hotness and rank the desired working set (the paper's
        swap rule): hotness primary, recency secondary, residency tertiary.
        With an ``active`` mask (multi-request mode) only allocated pages
        are rankable, so the desired set may be smaller than capacity."""
        a = self.cfg.ema_alpha
        self.hotness = a * self.counts_since_tier + (1 - a) * self.hotness
        self.counts_since_tier[:] = 0.0
        score = (self.hotness * 1e6
                 + (self.last_access + 1) / (self.step + 1)
                 + 0.5 * resident)
        desired_set = np.zeros(self.n, bool)
        if active is None:
            desired = np.argsort(-score, kind="stable")[: self.cfg.hbm_pages]
        else:
            ids = np.nonzero(active)[0]
            order = np.argsort(-score[ids], kind="stable")
            desired = ids[order[: self.cfg.hbm_pages]]
        desired_set[desired] = True
        return desired_set

    def _plan_swaps(self, resident: np.ndarray, desired_set: np.ndarray,
                    n_free: int) -> Tuple[np.ndarray, np.ndarray]:
        """(bring, evict) realising the desired set: fill free capacity
        first, then evict lazily (a resident-but-undesired page costs
        nothing to keep and can only save future misses).  Because the
        desired set never exceeds capacity, every desired page is brought
        in.  ``n_free == 0`` reduces to the classic paired-swap rule."""
        bring = np.nonzero(desired_set & ~resident)[0]
        evict = np.nonzero(resident & ~desired_set)[0]
        n_bring = min(len(bring), n_free + len(evict))
        n_evict = max(0, n_bring - n_free)
        return bring[:n_bring], evict[:n_evict]

    def plan_tier(self, resident: np.ndarray, n_free: int,
                  active: Optional[np.ndarray] = None, *,
                  planes: int = 2, force: bool = False
                  ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """The decision half of ``maybe_tier``: gate on the period cadence,
        EMA-rank, plan the swaps, and charge the period's modeled cost --
        all from a residency *snapshot*, never touching a pool.  This is
        what the pipelined serving loop runs on its background decision
        thread (the pools stay owned by the dispatch thread).  Returns
        ``(bring, evict)``, or ``None`` when no boundary is due.  Cost is
        charged at plan time: the plan is deterministic from the snapshot,
        so sync and async modes account identically."""
        if self.step == 0:
            return None
        if force:
            self._since_tier = 0
        elif not self._tier_due():
            return None
        cfg = self.cfg
        desired_set = self._rank_desired(resident, active)
        bring, evict = self._plan_swaps(resident, desired_set, int(n_free))
        n_mig = len(bring)
        self.migrations += int(n_mig)
        # planes x = one plane per leaf of the pool's geometry (k + v for
        # classic attention, ckv + krope for MLA, 1 for state-only pools);
        # evictions move no data (the host copy is write-through, dropping
        # a slot is free)
        self.data_moved_pages += planes * int(n_mig)
        self.modeled_time += n_mig * cfg.mig_cost + cfg.wakeup_cost
        if (r := _obs.RECORDER).enabled:
            r.emit("tier.move", manager=self.obs_id, step=self.step,
                   period=self.period, promoted=int(n_mig),
                   evicted=int(len(evict)), pages_moved=planes * int(n_mig),
                   cost=float(n_mig * cfg.mig_cost + cfg.wakeup_cost))
            r.count("tier.pages_moved", planes * int(n_mig))
        return bring, evict

    def apply_plan(self, pools: PagedPools, bring: np.ndarray,
                   evict: np.ndarray) -> None:
        """Actuate a ``plan_tier`` decision on the live pools, revalidating
        against state that may have moved since the snapshot was taken (in
        async mode requests retire and demand-fetches land between plan
        and apply): bring entries a demand-fetch already made resident and
        evict entries that already left HBM are dropped, and the free-slot
        arithmetic is recomputed against the live pool.  (A bring of a
        since-freed ID is deliberately NOT filtered: the sync rule can
        promote score-zero unallocated IDs into spare capacity, and the
        write-through invariant makes the stale copy harmless.)  On the
        synchronous path the snapshot IS the live state and the
        revalidation passes everything through unchanged."""
        resident = pools.slot_of >= 0
        bring = np.asarray(bring, np.int64)
        evict = np.asarray(evict, np.int64)
        bring = bring[~resident[bring]]
        evict = evict[resident[evict]]
        if hasattr(pools, "host_pinned"):
            # retry-exhausted pages sit out the promotion plan until their
            # cooldown lapses (they still demand-fetch via the degraded
            # path when the kernel needs them)
            bring = bring[~pools.host_pinned(bring)]
        free_slots = np.nonzero(pools.page_of_slot < 0)[0]
        n_free = len(free_slots)
        if hasattr(pools, "effective_hbm"):
            # a capacity squeeze shrinks usable spare slots; swaps against
            # evictions stay allowed (occupancy does not grow)
            occupied = pools.page_of_slot.size - n_free
            n_free = min(n_free, max(0, pools.effective_hbm - occupied))
        n_bring = min(len(bring), n_free + len(evict))
        n_evict = max(0, n_bring - n_free)
        bring, evict = bring[:n_bring], evict[:n_evict]
        n_mig = len(bring)
        if not n_mig:
            return
        evict_slots = pools.slot_of[evict].copy()
        slots = np.concatenate([
            free_slots[: n_mig - len(evict)],
            evict_slots]).astype(pools.slot_of.dtype)
        pools.slot_of[evict] = -1
        pools.slot_of[bring] = slots
        pools.page_of_slot[slots] = bring
        pools.slot_epoch = getattr(pools, "slot_epoch", 0) + 1
        pools.touch_slots(slots)   # shared pools track slot recency
        try:
            pools.migrate_slots(slots, bring)
        except MigrationError as e:
            # roll the slot bookkeeping back: the promoted pages stay
            # host-resident (a later demand fetch will retry them through
            # the backoff path) and the evicted residents keep their slots
            pools.slot_of[bring] = -1
            pools.page_of_slot[slots] = -1
            pools.slot_of[evict] = evict_slots
            pools.page_of_slot[evict_slots] = evict
            pools.slot_epoch += 1
            if (r := _obs.RECORDER).enabled:
                r.emit("tier.move_failed", manager=self.obs_id,
                       step=self.step, pages=int(n_mig), attempts=1,
                       detail=str(e))
                r.count("tier.moves_failed")

    def maybe_tier(self, pools: PagedPools,
                   active: Optional[np.ndarray] = None,
                   force: bool = False) -> PagedPools:
        """``force=True`` tiers regardless of the step cadence -- the
        macro-step serving loop wakes the host exactly once per movement
        period, so every wakeup IS a tiering boundary."""
        n_free = int((pools.page_of_slot < 0).sum())
        if hasattr(pools, "effective_hbm"):
            occupied = pools.page_of_slot.size - n_free
            n_free = min(n_free, max(0, pools.effective_hbm - occupied))
        plan = self.plan_tier(pools.slot_of >= 0, n_free, active,
                              planes=int(getattr(pools, "move_planes", 2)),
                              force=force)
        if plan is not None:
            self.apply_plan(pools, *plan)
        return pools

    def maybe_tier_symbolic(self, resident: np.ndarray,
                            active: Optional[np.ndarray] = None) -> bool:
        """Tiering over symbolic residency (no physical pools): same swap
        rule and accounting as ``maybe_tier``, used for fast period trials
        and the traffic simulator.  Mutates ``resident`` in place; returns
        whether a tier happened."""
        if self.step == 0 or not self._tier_due():
            return False
        desired_set = self._rank_desired(resident, active)
        n_free = self.cfg.hbm_pages - int(resident.sum())
        bring, evict = self._plan_swaps(resident, desired_set, n_free)
        n_mig = len(bring)
        self.migrations += n_mig
        self.data_moved_pages += 2 * n_mig
        self.modeled_time += n_mig * self.cfg.mig_cost + self.cfg.wakeup_cost
        if (r := _obs.RECORDER).enabled:
            r.emit("tier.move", manager=self.obs_id, step=self.step,
                   period=self.period, promoted=int(n_mig),
                   evicted=int(len(evict)), pages_moved=2 * int(n_mig),
                   cost=float(n_mig * self.cfg.mig_cost
                              + self.cfg.wakeup_cost))
            r.count("tier.pages_moved", 2 * int(n_mig))
        resident[evict] = False
        resident[bring] = True
        return True

    # -- Cori integration ----------------------------------------------------
    def reuse_histogram(self, bin_width: int = 4) -> reuse.ReuseHistogram:
        """Reuse distances in the decode-step domain from the access log."""
        last = np.full(self.n, -1)
        gaps: List[int] = []
        for t, ids in enumerate(self.access_log):
            prev = last[ids]
            gaps.extend((t - prev[prev >= 0]).tolist())
            last[ids] = t
        h = reuse.loop_duration_histogram(np.asarray(gaps, np.int64),
                                          bin_width=bin_width)
        return reuse.prune_insignificant(h)

    def cori_candidates(self, horizon_steps: int) -> np.ndarray:
        hist = self.reuse_histogram()
        dr = cori.dominant_reuse(hist)
        return cori.candidate_periods(dr, float(horizon_steps),
                                      min_period=1.0)

