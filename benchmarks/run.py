"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = the figure's headline
number).  Results are also written as JSON under ``benchmarks/out/`` for
EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

from benchmarks.common import Timer


def smoke() -> None:
    """Fast bit-rot check (CI): tiny-shape runs of the benchmarks wired to
    the serving/tuning path -- online, sweep and traffic -- asserting each
    one's headline invariant still holds.  Results go to a temp dir
    (``REPRO_BENCH_OUT``) so the smoke can never diff against -- or
    clobber -- locally generated results under benchmarks/out/."""
    if "REPRO_BENCH_OUT" not in os.environ:
        os.environ["REPRO_BENCH_OUT"] = tempfile.mkdtemp(
            prefix="repro-bench-smoke-")
    print(f"# results -> {os.environ['REPRO_BENCH_OUT']}", file=sys.stderr)
    print("name,us_per_call,derived")

    from benchmarks import online
    with Timer() as t:
        on = online.run(quick=True)
    print(f"smoke_online,{t.us:.0f},"
          f"vs_best_fixed_steady={on['online_vs_best_fixed_steady']:.3f}")
    assert on["online"]["time_to_converge_steps"] is not None, \
        "online tuner never converged"

    from benchmarks import sweep
    with Timer() as t:
        sw = sweep.run(quick=True)
    err = max(v["max_rel_err"] for v in sw.values())
    print(f"smoke_sweep,{t.us:.0f},max_rel_err={err:.1e}")
    assert err < 1e-6, "batched sweep diverged from the loop oracle"

    from benchmarks import traffic
    with Timer() as t:
        tr = traffic.run(quick=True)
    print(f"smoke_traffic,{t.us:.0f},"
          f"vs_best_fixed_steady={tr['online_vs_best_fixed_steady']:.3f};"
          f"token_identical={tr['token_parity']['token_identical']};"
          f"mem_reduction={tr['cache_memory']['reduction']:.2f}")
    assert tr["token_parity"]["token_identical"], \
        "fully-paged decode diverged from per-request generate"
    assert tr["requests"]["completed"] > 0, "no traffic completed"
    assert tr["cache_memory"]["reduction"] >= 0.25, \
        "bucketed paged rows must cut peak cache memory by >= 25% vs the " \
        f"dense max_len provisioning (got {tr['cache_memory']['reduction']:.1%})"

    # hostile traffic: the hardened tuner must ride out flash crowds,
    # correlated bursts and diurnal swings within 1.15x of the best fixed
    # period in EVERY phase, and a poisoned TRIAL sweep must revert to
    # the last attested period (results land in BENCH_hostile.json)
    with Timer() as t:
        ho = traffic.hostile(quick=True)
    pt = ho["poisoned_trial"]
    print(f"smoke_hostile,{t.us:.0f},max_regret={ho['max_regret']:.3f};"
          f"guard_reverted={pt['reverted']};"
          f"tune_cycles={ho['tuner']['tune_cycles']}")
    assert ho["max_regret"] <= 1.15, \
        "hostile traffic shook the tuner: per-phase regret must stay " \
        f"<= 1.15x best fixed (got {ho['max_regret']:.3f}x)"
    assert pt["reverted"], \
        "poisoned TRIAL sweep must abort and revert to the last " \
        f"attested period (got {pt})"

    # the flight recorder must have captured the hostile run: a JSONL
    # event log with the full tuner decision timeline, replayable by
    # ``python -m repro.obs.report`` (uploaded as a CI artifact)
    from repro import obs
    from repro.obs import report as obs_report
    assert ho["metrics"]["schema"] == obs.SCHEMA, \
        f"benchmark metrics schema drifted: {ho['metrics'].get('schema')}"
    events = obs.read_jsonl(ho["events_jsonl"])
    transitions = [e for e in events if e["type"] == "tuner.transition"]
    assert transitions, "hostile event log carries no tuner transitions"
    trace = obs_report.decision_trace(events)
    assert any("->" in ln for ln in trace), \
        "decision trace failed to reconstruct the tuner timeline"
    print(f"smoke_obs,0,events={len(events) - 1};"
          f"transitions={len(transitions)};trace_lines={len(trace)}")

    # serving throughput: the macro-step hot loop must not regress below
    # the per-token paged path, with the four-way bit-parity bar intact
    # (results land in BENCH_serving.json for cross-PR tracking)
    with Timer() as t:
        sp = traffic.serving_perf(quick=True)
    print(f"smoke_serving,{t.us:.0f},"
          f"macro_speedup={sp['speedup_macro_vs_per_token']:.2f}x;"
          f"macro_tok_s={sp['modes']['macro']['tokens_per_sec']:.0f};"
          f"parity={sp['token_identical_all_modes']}")
    assert sp["token_identical_all_modes"], \
        "macro/paged/dense decode diverged from per-request generate"
    assert (sp["modes"]["macro"]["tokens_per_sec"]
            >= sp["modes"]["paged"]["tokens_per_sec"]), \
        "macro-step decode must be at least as fast as the per-token " \
        f"paged path (got {sp['speedup_macro_vs_per_token']:.2f}x)"
    # the overlap and telemetry wall-clock bars bind where overlap (and
    # a clean paired measurement) is physically possible -- >= 2 cores.
    # A single-core host time-slices the scan, the boundary work and the
    # recorder on one core, so both floors widen to no-material-
    # regression (see benchmarks/traffic.py and docs/serving.md)
    multicore = sp["overlap_parallel_substrate"]
    ov_floor = 1.0 if multicore else 0.90
    print(f"smoke_overlap,0,"
          f"speedup={sp['speedup_overlap_vs_sync']:.3f};"
          f"pipelined_parity={sp['parity_vs_generate']['pipelined']}")
    assert sp["parity_vs_generate"]["pipelined"], \
        "the pipelined loop diverged from per-request generate"
    assert sp["speedup_overlap_vs_sync"] >= ov_floor, \
        "the pipelined loop must not serve slower than the synchronous " \
        f"macro loop (got {sp['speedup_overlap_vs_sync']:.2f}x, " \
        f"floor {ov_floor:.2f}x)"
    ov = sp["telemetry_overhead"]
    oh_floor = 0.97 if multicore else 0.90
    print(f"smoke_telemetry,0,overhead_ratio={ov['ratio']:.3f};"
          f"enabled_tok_s={ov['enabled_tok_s']:.0f}")
    assert ov["ratio"] >= oh_floor, \
        "telemetry-enabled macro-loop throughput regressed vs disabled " \
        f"(got {ov['ratio']:.3f}, floor {oh_floor:.2f})"

    # paged MLA admission: compressed-row deepseek pages out of the same
    # slot pool, token-identical and >= 1.5x leaner than dense rows
    # (results land in traffic_mla.json for cross-PR tracking)
    with Timer() as t:
        m = traffic.mla(quick=True)
    print(f"smoke_mla,{t.us:.0f},"
          f"page_reduction={m['page_reduction_x']:.2f}x;"
          f"parity={m['token_identical']}")
    assert m["token_identical"], \
        "paged MLA decode diverged from per-request generate"
    assert m["page_reduction_x"] >= 1.5, \
        "paged MLA admission must provision >= 1.5x fewer pages than " \
        f"dense rows (got {m['page_reduction_x']:.2f}x)"

    # overload: graceful degradation (TTL shedding, bounded queue,
    # pressure preemption) must RAISE in-deadline goodput over the
    # FIFO-forever baseline, never trading token fidelity (results land
    # in BENCH_overload.json for cross-PR tracking)
    with Timer() as t:
        ovl = traffic.overload(quick=True)
    dg = ovl["modes"]["degraded"]
    print(f"smoke_overload,{t.us:.0f},"
          f"goodput_ratio={ovl['goodput_ratio_degraded_vs_baseline']:.2f}x;"
          f"shed_rate={dg['shed_rate']:.2f};"
          f"preemptions={dg['preemptions']};"
          f"parity={ovl['degraded_completed_token_parity']}")
    assert ovl["degraded_completed_token_parity"], \
        "graceful degradation must never trade token fidelity"
    assert ovl["goodput_ratio_degraded_vs_baseline"] >= 1.2, \
        "degradation must raise in-deadline goodput >= 1.2x over the " \
        "FIFO-forever baseline under overload " \
        f"(got {ovl['goodput_ratio_degraded_vs_baseline']:.2f}x)"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of apps/steps (CI-speed)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape smoke of online/sweep/traffic only "
                         "(benchmark bit-rot check for CI)")
    args = ap.parse_args(argv)
    q = args.quick
    if args.smoke:
        smoke()
        return

    print("name,us_per_call,derived")

    from benchmarks import fig1
    with Timer() as t:
        s1 = fig1.run(quick=q)
    print(f"fig1_perf_gap,{t.us:.0f},"
          f"cori_slack={s1['mean_cori_slowdown']:.4f};"
          f"worst_fixed_gap={s1['worst_fixed_gap']:.3f}")

    from benchmarks import fig3
    with Timer() as t:
        s3 = fig3.run(quick=q)
    drs = ";".join(f"{a}:{d['dominant_reuse']:.0f}" for a, d in s3.items())
    print(f"fig3_reuse_histograms,{t.us:.0f},{drs}")

    from benchmarks import fig5
    with Timer() as t:
        s5 = fig5.run(quick=q)
    print(f"fig5_tuning_trials,{t.us:.0f},"
          f"trial_reduction={s5['trial_reduction']:.2f}x;"
          f"cori={s5['cori_mean_trials']:.1f};"
          f"base={s5['baseline_mean_trials']:.1f}")

    from benchmarks import fig6
    with Timer() as t:
        s6 = fig6.run(quick=q)
    ok = all(d["sub_dr_moves_more_data"] for d in s6.values())
    print(f"fig6_system_validation,{t.us:.0f},sub_dr_moves_more_data={ok}")

    from benchmarks import tiering
    with Timer() as t:
        st = tiering.run(quick=q)
    worst = max(v["cori_vs_best_fixed"] for v in st.values())
    print(f"tiering_serving_cori,{t.us:.0f},max_vs_best_fixed={worst:.2f}x")

    from benchmarks import sweep
    with Timer() as t:
        sw = sweep.run(quick=q)
    worst_sw = min(v["speedup"] for v in sw.values())
    err = max(v["max_rel_err"] for v in sw.values())
    print(f"sweep_batched,{t.us:.0f},min_speedup={worst_sw:.1f}x;"
          f"max_rel_err={err:.1e}")

    from benchmarks import online
    with Timer() as t:
        on = online.run(quick=q)
    print(f"online_cori,{t.us:.0f},"
          f"vs_best_fixed_steady={on['online_vs_best_fixed_steady']:.3f};"
          f"converge_steps={on['online']['time_to_converge_steps']};"
          f"cycles={on['online']['tune_cycles']}")

    from benchmarks import traffic
    with Timer() as t:
        tr = traffic.run(quick=q)
    print(f"traffic_sched,{t.us:.0f},"
          f"vs_best_fixed_steady={tr['online_vs_best_fixed_steady']:.3f};"
          f"token_identical={tr['token_parity']['token_identical']};"
          f"completed={tr['requests']['completed']}")

    with Timer() as t:
        ho = traffic.hostile(quick=q)
    print(f"traffic_hostile,{t.us:.0f},max_regret={ho['max_regret']:.3f};"
          f"guard_reverted={ho['poisoned_trial']['reverted']};"
          f"tune_cycles={ho['tuner']['tune_cycles']};"
          f"guard_trips={ho['tuner']['guard_trips']}")

    with Timer() as t:
        sp = traffic.serving_perf(quick=q)
    print(f"serving_macro,{t.us:.0f},"
          f"macro_speedup={sp['speedup_macro_vs_per_token']:.2f}x;"
          f"macro_tok_s={sp['modes']['macro']['tokens_per_sec']:.0f};"
          f"parity={sp['token_identical_all_modes']}")

    with Timer() as t:
        ovl = traffic.overload(quick=q)
    print(f"serving_overload,{t.us:.0f},"
          f"goodput_ratio={ovl['goodput_ratio_degraded_vs_baseline']:.2f}x;"
          f"shed_rate={ovl['modes']['degraded']['shed_rate']:.2f};"
          f"parity={ovl['degraded_completed_token_parity']}")

    from benchmarks import roofline
    with Timer() as t:
        rr = roofline.run(quick=q)
    n = len(rr["rows"])
    if n:
        best = max(r["roofline_fraction"] for r in rr["rows"])
        print(f"roofline_terms,{t.us:.0f},cells={n};best_fraction={best:.3f}")
    else:
        print(f"roofline_terms,{t.us:.0f},cells=0 (run repro.launch.dryrun)")


if __name__ == "__main__":
    main()
